"""Serving demo: prefill + batched greedy decode with a reduced gemma3-style
model (sliding-window + global KV caches).

    PYTHONPATH=src python examples/serve_decode.py
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.train import serve


def main():
    cfg = registry.load_config("gemma3-12b").reduced()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    B, prompt_len, gen = 4, 12, 16
    max_seq = 64
    rng = jax.random.PRNGKey(1)
    prompt = jax.random.randint(rng, (B, prompt_len), 0, cfg.vocab)

    cache, logits = serve.sequential_prefill(params, cfg, prompt, max_seq)
    last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    cache, toks = serve.decode_tokens(params, cfg, cache, last, prompt_len,
                                      gen)
    print("prompt tokens:", prompt[0, :8].tolist(), "...")
    print("generated    :", toks[0].tolist())
    assert toks.shape == (B, gen)
    print("ok: batched decode with ring-buffer local cache + global cache")


if __name__ == "__main__":
    main()

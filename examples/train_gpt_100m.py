"""End-to-end driver: train the paper's GPT (~100M params) for a few hundred
steps on the synthetic pipeline; checkpoints + loss curve.

    PYTHONPATH=src python examples/train_gpt_100m.py [--steps 300]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.checkpoint import save_checkpoint
from repro.data.pipeline import SyntheticTextDataset
from repro.models import registry
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_gpt_ckpt")
    args = ap.parse_args()

    cfg = registry.load_config("gpt")
    # ~100M-scale: keep the paper's GPT dims, shorter context for CPU demo
    print(f"model: {cfg.name}  params={registry.n_params(cfg)/1e6:.1f}M")
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=20),
                       microbatches=2)   # grad accumulation (verified path)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    ds = SyntheticTextDataset(vocab=cfg.vocab, seq_len=args.seq,
                              batch=args.batch, seed=0)
    t0 = time.time()
    first = last = None
    for step in range(args.steps):
        batch = ds.batch_at(step)
        params, opt, metrics = step_fn(params, opt, batch)
        if step == 0:
            first = float(metrics["loss"])
        if step % 50 == 0 or step == args.steps - 1:
            last = float(metrics["loss"])
            print(f"step {step:4d} loss {last:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({time.time()-t0:.0f}s)")
    save_checkpoint(args.ckpt, args.steps, {"params": params})
    print(f"loss {first:.3f} -> {last:.3f}; checkpoint at {args.ckpt}")
    assert last < first, "loss did not decrease"


if __name__ == "__main__":
    main()

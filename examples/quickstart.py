"""Quickstart: verify a tensor-parallel transformer layer with GraphGuard,
then catch an injected distribution bug — via the typed ``repro.api``.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro.api import verify

# 1. A correct Megatron-style TP transformer layer: refinement holds and we
#    get an executable certificate R_o (report.certificate is the live
#    object; report.r_o the stringified relation).
report = verify("tp_layer", degree=2)
assert report.verdict == "certificate" and report.ok
print("\n[1] TP layer verified — certificate maps the sequential output to",
      list(report.r_o.values())[0], "\n")

# 2. Paper bug 4: a rotated expert-to-shard mapping — each rank applies its
#    neighbour's expert weights and GraphGuard localizes the matmul.
report = verify("ep_moe", bug="sharded_expert")
if report.verdict == "refinement_error":
    loc = report.localization
    print("[2] injected bug detected at G_s operator "
          f"#{loc['op_index']} `{loc['op_name']}` (output `{loc['out_name']}`)")
    print("    nearest candidate:", loc.get("diagnostic", {}).get("expr"))
else:
    print("[2] UNEXPECTED: bug not detected")
    sys.exit(1)

"""Quickstart: verify a tensor-parallel transformer layer with GraphGuard,
then catch an injected distribution bug.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import RefinementError
from repro.launch.verify import run_case

# 1. A correct Megatron-style TP transformer layer: refinement holds and we
#    get an executable certificate R_o.
cert = run_case("tp_layer", degree=2)
print("\n[1] TP layer verified — certificate maps the sequential output to",
      list(cert.r_o.values())[0], "\n")

# 2. Paper bug 4: a rotated expert-to-shard mapping — each rank applies its
#    neighbour's expert weights and GraphGuard localizes the matmul.
try:
    run_case("ep_moe", bug="sharded_expert")
    print("[2] UNEXPECTED: bug not detected")
except RefinementError as e:
    print("[2] injected bug detected:\n", e)

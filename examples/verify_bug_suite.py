"""Reproduce the paper's §6.2 case study: all six real-world bug classes.

    PYTHONPATH=src python examples/verify_bug_suite.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import (capture, capture_spmd, check_refinement,
                        expand_spmd, RefinementError)
from repro.dist.strategies import BUG_CASES

for bug, (builder, raises) in BUG_CASES.items():
    seq_fn, dist_fn, axes, specs, avals, names = builder(degree=2, bug=bug)
    gs = capture(seq_fn, avals, names)
    cap = capture_spmd(dist_fn, axes, specs, avals, names)
    gd, r_i = expand_spmd(cap)
    try:
        cert = check_refinement(gs, gd, r_i)
        status = ("detected via unexpected R_o: "
                  + str(list(cert.r_o.values())[0])) if not raises \
            else "NOT DETECTED (unexpected)"
    except RefinementError as e:
        status = "detected: " + str(e).splitlines()[0]
    print(f"bug {bug:16s} -> {status}")

"""Reproduce the paper's §6.2 case study over every registered bug class
(the paper's six plus the FSDP / pipeline / 2D-mesh families), driven
through the ``repro.api`` suite runner.

    PYTHONPATH=src python examples/verify_bug_suite.py
"""
import sys
sys.path.insert(0, "src")

from repro.api import Suite, list_bugs

# One task per registered bug, each under its host case at degree 2.
bugs = list_bugs()
suite = Suite(cases=sorted({host for host, _ in bugs.values()}),
              degrees=(2,), bugs=sorted(bugs))
result = suite.run(workers=0)

for report in result:
    if report.bug is None:
        continue                      # host clean runs ride along; skip
    if report.verdict == "refinement_error":
        status = "detected: " + report.localization["op_name"] + \
            f" at G_s op #{report.localization['op_index']}"
    elif report.verdict == "certificate" and \
            report.expected == "unexpected_relation":
        status = "detected via unexpected R_o: " + \
            str(list(report.r_o.values())[0])
    else:
        status = f"NOT DETECTED (unexpected verdict {report.verdict})"
    print(f"bug {report.bug:16s} -> {status}")

sys.exit(0 if result.ok else 1)

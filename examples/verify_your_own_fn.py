"""Verify YOUR OWN shard_map function — no registry, no hand-built terms.

The generic jaxpr frontend (``repro.core.from_jaxpr`` +
``repro.api.verify_functions``) traces any sequential/distributed function
pair you wrote, so verification is one call:

    PYTHONPATH=src python examples/verify_your_own_fn.py

The same task also runs through the CLI:

    PYTHONPATH=src python -m repro.launch.verify \
        --fn examples/verify_your_own_fn.py:make_task --json
"""
import sys

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

DEGREE = 2          # tensor-parallel ranks
SEQ, D_MODEL, D_FF = 4, 8, 8


# -- 1. the model you trust: a plain sequential MLP -------------------------

def seq_mlp(x, w1, w2):
    """The sequential reference: y = tanh(x @ w1) @ w2."""
    return jnp.tanh(x @ w1) @ w2


# -- 2. the distributed implementation you wrote ----------------------------
# Megatron-style tensor parallelism: w1 column-sharded, w2 row-sharded, so
# each rank holds partial sums that a psum over the `tp` axis assembles.

def dist_mlp(x, w1, w2):
    """Per-rank TP implementation: partial matmuls + psum over `tp`."""
    h = jnp.tanh(x @ w1)          # x replicated, w1 column shard
    return jax.lax.psum(h @ w2, "tp")


def dist_mlp_buggy(x, w1, w2):
    """A classic mistake: 'averaging' the psum as if shards were replicas.

    The per-rank products are *partial sums*, not copies — dividing by the
    rank count halves the result.  (The same bug class as HF's
    gradient-accumulation rescale regression.)
    """
    h = jnp.tanh(x @ w1)
    return jax.lax.psum(h @ w2, "tp") / DEGREE      # BUG: not an average!


def make_task():
    """Task description for the CLI: ``--fn <this file>:make_task``."""
    avals = [jax.ShapeDtypeStruct(s, jnp.float32)
             for s in ((SEQ, D_MODEL), (D_MODEL, D_FF), (D_FF, D_MODEL))]
    return {
        "fn_seq": seq_mlp,
        "fn_dist": dist_mlp,
        "mesh": {"tp": DEGREE},
        "in_specs": (P(), P(None, "tp"), P("tp", None)),
        "avals": avals,
        "name": "my_tp_mlp",
    }


def main():
    sys.path.insert(0, "src")
    from repro.api import verify_functions

    task = make_task()

    # the correct implementation certifies: R_o maps each sequential output
    # to a clean expression over per-rank outputs
    report = verify_functions(**task)
    assert report.verdict == "certificate", report
    print("[1] your TP MLP verified — certificate:")
    for k, v in report.r_o.items():
        print(f"      {k} = {v}")

    # the buggy variant is caught and localized — no test data needed
    report = verify_functions(**{**task, "fn_dist": dist_mlp_buggy,
                                 "name": "my_tp_mlp_buggy"})
    assert report.verdict == "refinement_error", report
    loc = report.localization
    print(f"\n[2] buggy variant rejected — localized at G_s operator "
          f"#{loc['op_index']} `{loc['op_name']}` (output `{loc['out_name']}`)")

    # code outside the term vocabulary fails *loudly*, naming the primitive
    # and your source line — not with a confusing downstream verdict
    def dist_sorted(x, w1, w2):
        return jnp.sort(jax.lax.psum(jnp.tanh(x @ w1) @ w2, "tp"), axis=0)

    report = verify_functions(**{**task, "fn_dist": dist_sorted,
                                 "name": "my_sorted_mlp"})
    assert report.verdict == "error" and "sort" in report.error, report
    print(f"\n[3] unsupported code is named at its source:\n"
          f"      {report.error.splitlines()[0]}")


if __name__ == "__main__":
    main()

#!/usr/bin/env python
"""Perf gate: compare a fresh ``benchmarks/run.py --smoke`` run against the
checked-in ``BENCH_verify.json`` medians and fail on regression of the
relation-inference hot path.

    python benchmarks/run.py --smoke        # writes BENCH_verify_smoke.json
    python scripts/check_bench.py [--tolerance 1.5]

Every case in the baseline's smoke sections (see ``SECTION_METRICS``)
must be present in the fresh run and its gated metric must stay under
``max(baseline, --min-ms) * tolerance`` — the ``--min-ms`` floor keeps
sub-millisecond cases from tripping the gate on scheduler noise.  The
tolerance (default 1.5x, overridable via ``$BENCH_TOLERANCE``) absorbs the
single-repeat smoke run landing on a noisy CI runner; a real hot-path
regression (the PR-1/PR-2 optimizations were 1.4-4x) clears it easily.

A second, tolerance-free gate checks ``lemma_fires`` and
``explain_steps`` with exact equality for every case that records them
in both artifacts: saturation and proof-chain reconstruction are
deterministic, so a changed count means the engine did different work
(or the reconstructed proofs changed shape) — a behaviour change
smuggled in as a perf delta — and no amount of runner noise excuses it.

Exit codes: 0 ok, 1 regression/missing case, 2 missing input file.
"""
import argparse
import json
import os
import sys

# the sections a --smoke run produces, each with its gated metric:
# fig4/fig5/modelcheck/gradcheck gate the relation-inference hot path
# (modelcheck's infer_ms sums over the model's unique obligations;
# gradcheck's over a train strategy's per-parameter obligations), and
# runtime gates the warm-cache re-verification latency — the pre-launch
# "nothing changed, re-verify" path the persistent cache exists for
SECTION_METRICS = {
    "fig4": "infer_ms",
    "fig5": "infer_ms",
    "modelcheck": "infer_ms",
    "gradcheck": "infer_ms",
    "servecheck": "infer_ms",
    "runtime": "warm_wall_ms",
}


def collect(bench: dict) -> dict:
    """{"section/case": metric value} for every timed case in the smoke
    sections (each section contributes its own gated metric)."""
    out = {}
    for sec, metric in SECTION_METRICS.items():
        for case, rec in bench.get(sec, {}).items():
            if isinstance(rec, dict) and metric in rec:
                out[f"{sec}/{case}"] = float(rec[metric])
    return out


def collect_lemma_fires(bench: dict) -> dict:
    """{"section/case": lemma_fires} wherever the artifact records it."""
    return _collect_exact(bench, "lemma_fires")


def collect_explain_steps(bench: dict) -> dict:
    """{"section/case": explain_steps} wherever the artifact records it."""
    return _collect_exact(bench, "explain_steps")


def _collect_exact(bench: dict, field: str) -> dict:
    out = {}
    for sec in SECTION_METRICS:
        for case, rec in bench.get(sec, {}).items():
            if isinstance(rec, dict) and field in rec:
                out[f"{sec}/{case}"] = int(rec[field])
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Fail when a fresh smoke benchmark regresses the "
                    "inference hot path vs the checked-in baseline.")
    ap.add_argument("--baseline", default="BENCH_verify.json",
                    help="checked-in full benchmark artifact")
    ap.add_argument("--fresh", default="BENCH_verify_smoke.json",
                    help="artifact written by `benchmarks/run.py --smoke`")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("BENCH_TOLERANCE", "1.5")),
                    help="allowed slowdown factor (default 1.5, or "
                         "$BENCH_TOLERANCE)")
    ap.add_argument("--min-ms", type=float, default=5.0,
                    help="noise floor: baselines below this compare "
                         "against min-ms instead (default 5.0 — the "
                         "millisecond fig4 cases flap 2-3x under "
                         "container scheduler noise; the heavyweight "
                         "sections carry the real regression signal)")
    args = ap.parse_args(argv)
    if args.tolerance <= 0:
        ap.error("--tolerance must be positive")

    for path in (args.baseline, args.fresh):
        if not os.path.exists(path):
            print(f"[bench-gate] missing {path} — run "
                  f"`benchmarks/run.py{' --smoke' if path == args.fresh else ''}`"
                  f" first", file=sys.stderr)
            return 2
    with open(args.baseline) as f:
        base = collect(json.load(f))
    with open(args.fresh) as f:
        fresh = collect(json.load(f))
    if not base:
        print(f"[bench-gate] baseline {args.baseline} has no smoke-section "
              f"cases — regenerate it with `make bench`", file=sys.stderr)
        return 2

    failures = []
    for case in sorted(base):
        if case not in fresh:
            failures.append(f"{case}: missing from fresh run "
                            f"(section errored or case was removed)")
            continue
        limit = max(base[case], args.min_ms) * args.tolerance
        status = "ok"
        if fresh[case] > limit:
            status = "REGRESSED"
            failures.append(
                f"{case}: {fresh[case]:.2f} ms vs baseline "
                f"{base[case]:.2f} ms (limit {limit:.2f} ms at "
                f"{args.tolerance:g}x)")
        print(f"[bench-gate] {case:28s} base={base[case]:9.2f} ms  "
              f"fresh={fresh[case]:9.2f} ms  {status}")
    for case in sorted(set(fresh) - set(base)):
        print(f"[bench-gate] {case:28s} new case "
              f"({fresh[case]:.2f} ms) — not gated until `make bench` "
              f"refreshes the baseline")

    # determinism gates: exact equality, no tolerance — only for cases
    # recording the count in BOTH artifacts, so older baselines phase in
    # as `make bench` refreshes them.  lemma_fires catches the engine
    # doing different work; explain_steps catches the reconstructed
    # proofs changing shape (chain canonicalization is deterministic).
    with open(args.baseline) as f:
        base_full = json.load(f)
    with open(args.fresh) as f:
        fresh_full = json.load(f)
    for field, collector, why in (
            ("lemma_fires", collect_lemma_fires,
             "saturation is deterministic, the engine's behaviour changed"),
            ("explain_steps", collect_explain_steps,
             "chain reconstruction is deterministic, the proofs changed "
             "shape")):
        base_n = collector(base_full)
        fresh_n = collector(fresh_full)
        for case in sorted(set(base_n) & set(fresh_n)):
            if base_n[case] != fresh_n[case]:
                failures.append(
                    f"{case}: {field} {fresh_n[case]} vs baseline "
                    f"{base_n[case]} — {why}")
            else:
                print(f"[bench-gate] {case:28s} "
                      f"{field}={base_n[case]} deterministic ok")

    if failures:
        print(f"[bench-gate] FAIL: {len(failures)} hot-path regression(s):",
              file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(f"[bench-gate] ok: {len(base)} case(s) within "
          f"{args.tolerance:g}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())

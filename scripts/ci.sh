#!/usr/bin/env sh
# CI driver mirroring the Makefile targets: scripts/ci.sh [verify|quick|bench-smoke]
set -eu
cd "$(dirname "$0")/.."
target="${1:-verify}"
case "$target" in
  verify)      PYTHONPATH=src python -m pytest -x -q ;;
  quick)       PYTHONPATH=src python -m pytest -x -q -m "not slow" ;;
  bench-smoke) python benchmarks/run.py --smoke ;;
  *) echo "unknown target: $target (verify|quick|bench-smoke)" >&2; exit 2 ;;
esac

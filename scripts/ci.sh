#!/usr/bin/env sh
# CI driver mirroring the Makefile targets:
#   scripts/ci.sh [verify|quick|bench-smoke|bench-gate|bug-suite|suite|golden]
set -eu
cd "$(dirname "$0")/.."
target="${1:-verify}"
case "$target" in
  verify)      PYTHONPATH=src python -m pytest -x -q ;;
  quick)       PYTHONPATH=src python -m pytest -x -q -m "not slow" ;;
  bench-smoke) PYTHONPATH=src python benchmarks/run.py --smoke ;;
  # perf gate: fresh --smoke medians vs the checked-in BENCH_verify.json
  bench-gate)  PYTHONPATH=src python benchmarks/run.py --smoke
               python scripts/check_bench.py ;;
  # paper §6.2 bug case study: every registered bug class must be detected
  bug-suite)   PYTHONPATH=src python examples/verify_bug_suite.py ;;
  # full clean-case matrix at degree 2 via the suite runner, diffed against
  # the checked-in golden (verdicts + R_o certificates, no timings)
  suite)       PYTHONPATH=src python -m repro.api --degrees 2 \
                 --workers 4 --check tests/golden/suite_degree2.json ;;
  # deterministically regenerate tests/golden/*.json after a strategy change
  golden)      PYTHONPATH=src python -m repro.api --update-golden \
                 --workers 4 ;;
  # whole-model smoke: gpt@dp2xtp2 certifies; injected bug localizes.
  # rc must be exactly 1 (bug detected AND localized to its block) — rc 2
  # means a harness problem (mis-localization / crash), which must fail.
  modelcheck-smoke)
               PYTHONPATH=src python -m repro.launch.verify \
                 --model gpt --plan dp2xtp2
               rc=0
               PYTHONPATH=src python -m repro.launch.verify \
                 --model gpt --plan dp2xtp2 --inject-bug wrong_spec \
                 --bug-layer 3 || rc=$?
               if [ "$rc" -ne 1 ]; then
                 echo "injected bug not localized (rc=$rc, want 1)" >&2
                 exit 1
               fi ;;
  # train-step smoke: dp_accum certifies per-parameter; the injected
  # gradient bug localizes to its parameter.  rc must be exactly 1 (bug
  # detected AND localized) — rc 2 means mis-localization, which must fail.
  gradcheck-smoke)
               PYTHONPATH=src python -m repro.launch.verify \
                 --train dp_accum
               rc=0
               PYTHONPATH=src python -m repro.launch.verify \
                 --train dp_accum --inject-bug accum_no_rescale || rc=$?
               if [ "$rc" -ne 1 ]; then
                 echo "injected grad bug not localized (rc=$rc, want 1)" >&2
                 exit 1
               fi ;;
  # serving-path smoke: tp_decode certifies (decode chain refines prefill);
  # the injected stale-cache-shard bug localizes to its decode step.  rc
  # must be exactly 1 (bug detected AND localized) — rc 2 means
  # mis-localization, which must fail.
  servecheck-smoke)
               PYTHONPATH=src python -m repro.launch.verify \
                 --serve tp_decode
               rc=0
               PYTHONPATH=src python -m repro.launch.verify \
                 --serve tp_decode --inject-bug stale_cache_shard || rc=$?
               if [ "$rc" -ne 1 ]; then
                 echo "injected serve bug not localized (rc=$rc, want 1)" >&2
                 exit 1
               fi ;;
  # fault-tolerance gate: injected crashes/exits/hangs/cache corruption
  # must be contained, attributed to the afflicted task only, and survived
  # with byte-identical certificates elsewhere
  chaos-smoke) PYTHONPATH=src python scripts/chaos_smoke.py ;;
  # persistent-cache gate: cold commits, warm hits byte-identically, torn
  # journal lines recovered with only the damaged entry re-proved
  cache-smoke) PYTHONPATH=src python scripts/cache_smoke.py ;;
  # generic-frontend smoke: the bring-your-own-function example runs end to
  # end (clean certificate, localized bug, source-located unsupported
  # primitive) and the same task resolves through the --fn CLI path
  fn-smoke)    PYTHONPATH=src python examples/verify_your_own_fn.py
               PYTHONPATH=src python -m repro.launch.verify \
                 --fn examples/verify_your_own_fn.py:make_task --json \
                 > /dev/null ;;
  # observability gate: a traced pooled run must produce a Perfetto-loadable
  # trace that the inspector can diagnose (last line names the top lemma)
  obs-smoke)   PYTHONPATH=src python -m repro.launch.verify \
                 --serve tp_decode --workers 2 \
                 --trace /tmp/graphguard_trace.json --metrics
               PYTHONPATH=src python -m repro.obs report \
                 /tmp/graphguard_trace.json | grep "top lemma: " ;;
  # proof-provenance gate: clean certificates explain + replay outside
  # the e-graph; injected smoke bugs produce failure-frontier narratives
  # naming the stuck op; explain-off runs stay byte-identical
  explain-smoke)
               PYTHONPATH=src python scripts/explain_smoke.py ;;
  # docs gates: lemma catalog completeness, CLI --help drift, docstring
  # coverage over repro.core + repro.api + repro.obs (no external linters)
  docs-check)  python scripts/check_cli_docs.py
               python scripts/check_docstrings.py
               PYTHONPATH=src python -m pytest -x -q tests/test_docs.py ;;
  *) echo "unknown target: $target (verify|quick|bench-smoke|bench-gate|bug-suite|suite|golden|modelcheck-smoke|gradcheck-smoke|servecheck-smoke|chaos-smoke|cache-smoke|fn-smoke|obs-smoke|explain-smoke|docs-check)" >&2
     exit 2 ;;
esac

#!/usr/bin/env sh
# CI driver mirroring the Makefile targets:
#   scripts/ci.sh [verify|quick|bench-smoke|suite]
set -eu
cd "$(dirname "$0")/.."
target="${1:-verify}"
case "$target" in
  verify)      PYTHONPATH=src python -m pytest -x -q ;;
  quick)       PYTHONPATH=src python -m pytest -x -q -m "not slow" ;;
  bench-smoke) python benchmarks/run.py --smoke ;;
  # full clean-case matrix at degree 2 via the suite runner, diffed against
  # the checked-in golden (verdicts + R_o certificates, no timings)
  suite)       PYTHONPATH=src python -m repro.api --degrees 2 \
                 --workers 4 --check tests/golden/suite_degree2.json ;;
  *) echo "unknown target: $target (verify|quick|bench-smoke|suite)" >&2
     exit 2 ;;
esac

#!/usr/bin/env python
"""Keep docs/CLI.md's --help block in sync with the real CLI.

Regenerates the ``verify --help`` text (with COLUMNS pinned so argparse
wrapping is deterministic) and compares it against the marked block in
docs/CLI.md.  CI runs this in check mode and fails on drift; after
changing flags, run::

    python scripts/check_cli_docs.py --update
"""
import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC = os.path.join(ROOT, "docs", "CLI.md")
BEGIN, END = "<!-- BEGIN VERIFY-HELP -->", "<!-- END VERIFY-HELP -->"


def real_help() -> str:
    env = dict(os.environ, COLUMNS="80",
               PYTHONPATH=os.path.join(ROOT, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.verify", "--help"],
        capture_output=True, text=True, env=env, cwd=ROOT, check=True).stdout
    # argparse names the prog after the script file; normalize it
    return out.replace("usage: verify.py", "usage: repro.launch.verify")


def render(help_text: str) -> str:
    return f"{BEGIN}\n```text\n{help_text.rstrip()}\n```\n{END}"


def main(argv) -> int:
    update = "--update" in argv
    with open(DOC, encoding="utf-8") as f:
        doc = f.read()
    pattern = re.compile(re.escape(BEGIN) + ".*?" + re.escape(END),
                         flags=re.DOTALL)
    if not pattern.search(doc):
        print(f"error: {DOC} is missing the {BEGIN} / {END} markers")
        return 2
    fresh = pattern.sub(lambda _: render(real_help()), doc)
    if fresh == doc:
        print("docs/CLI.md --help block is in sync")
        return 0
    if update:
        with open(DOC, "w", encoding="utf-8") as f:
            f.write(fresh)
        print("docs/CLI.md --help block regenerated")
        return 0
    print("error: docs/CLI.md --help block is stale — run "
          "`python scripts/check_cli_docs.py --update`")
    return 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

#!/usr/bin/env python
"""Chaos smoke — prove the runtime's fault tolerance instead of asserting
it (``make chaos-smoke``; see ``repro.runtime.chaos``).

For each injected fault class the driver runs the same small suite matrix
under ``GRAPHGUARD_CHAOS`` and asserts the runtime's contract:

* the run completes and every task has a result (no lost tasks, no
  crashed driver);
* the afflicted task *alone* carries the fault verdict, with the cause
  attributed in its error string (``timeout`` + budget/heartbeat detail
  for hangs; ``error`` + worker exit cause for crashes/hard exits);
* every unafflicted task's certificate is byte-identical to the
  fault-free baseline;
* a cache entry corrupted on commit is skipped and re-proved on the next
  run (``recovered_corrupt``), while undamaged entries hit;
* every injected fault is *visible* in a recorded trace — the supervisor
  emits ``cat: "fault"`` events (``pool.broken``/``task.retry`` for kill
  faults, ``task.timeout`` for hangs, ``chaos.corrupt_cache`` for cache
  corruption), so a post-mortem ``repro.obs report`` can always explain
  what chaos did (see docs/OBSERVABILITY.md).

Exit code 0 only if every assertion holds.
"""
import json
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.abspath(__file__)), "..", "src"))

from repro.api import Suite  # noqa: E402
from repro.obs import trace as obs_trace  # noqa: E402
from repro.runtime import CertificateCache  # noqa: E402
from repro.runtime.chaos import ENV_SEED, ENV_SPEC, ENV_TARGET  # noqa: E402

CASES = ("tp_layer", "sp_rope", "ep_moe", "sp_moe")
DEGREES = (2,)
WORKERS = 2
BUDGET_S = 20.0                          # generous for clean sub-second
HANG_BUDGET_S = 4.0                      # tasks; tight for the hang run

_failures = []


def check(cond, what):
    tag = "ok" if cond else "FAIL"
    print(f"[chaos-smoke]   {tag}: {what}")
    if not cond:
        _failures.append(what)


def set_chaos(spec=None, target=""):
    for var in (ENV_SPEC, ENV_TARGET, ENV_SEED):
        os.environ.pop(var, None)
    if spec is not None:
        os.environ[ENV_SPEC] = spec
        os.environ[ENV_TARGET] = target


def run_suite(timeout_s=BUDGET_S, cache=None):
    with Suite(cases=CASES, degrees=DEGREES) as suite:
        return suite.run(workers=WORKERS, timeout_s=timeout_s,
                         cache=cache if cache is not None else False)


def traced_run(**kw):
    """Run the suite under a fresh tracer; returns (result, events)."""
    tracer = obs_trace.start("chaos-smoke")
    try:
        res = run_suite(**kw)
    finally:
        obs_trace.stop()
    return res, tracer.events


def check_fault_visible(events, names, scenario):
    """The injected fault must leave supervisor-side evidence in the
    trace — worker-side kill events die with the worker, so these are
    the parent's ``cat: "fault"`` events (see docs/OBSERVABILITY.md)."""
    seen = {e["name"] for e in events if e.get("cat") == "fault"}
    hits = seen & set(names)
    check(bool(hits),
          f"{scenario} fault visible in trace "
          f"(want one of {sorted(names)}, fault events: {sorted(seen)})")


def survivors_identical(baseline, result, victim):
    """Every non-victim task must match the baseline byte for byte
    (verdict, expectation, and the full R_o certificate strings)."""
    base, got = baseline.stable_summary(), result.stable_summary()
    clean = [k for k in base if k != victim]
    same = all(json.dumps(base[k], sort_keys=True)
               == json.dumps(got[k], sort_keys=True) for k in clean)
    check(same, f"{len(clean)} unafflicted tasks byte-identical to baseline")


def main():
    set_chaos(None)
    print(f"[chaos-smoke] baseline: {len(CASES)} cases @ deg2, "
          f"{WORKERS} workers")
    baseline = run_suite()
    check(baseline.ok, "fault-free baseline is clean")

    victim = f"{CASES[0]}@deg2"

    print(f"[chaos-smoke] crash:1 targeting {victim} (SIGSEGV on every "
          f"attempt)")
    set_chaos("crash:1", victim)
    res, events = traced_run()
    rep = {r.task_id(): r for r in res}[victim]
    check(len(res) == len(baseline), "every task has a result")
    check(rep.verdict == "error", f"victim verdict is error "
                                  f"(got {rep.verdict})")
    check("SIGSEGV" in (rep.error or ""),
          f"exit cause attributed in error: {rep.error!r}")
    check((rep.runtime or {}).get("attempts", 1) > 1,
          f"bounded retries recorded: {rep.runtime}")
    survivors_identical(baseline, res, victim)
    check_fault_visible(events, ("pool.broken", "task.retry",
                                 "worker.crash", "task.failed"), "crash")

    print(f"[chaos-smoke] exit:1 targeting {victim} (hard os._exit "
          f"mid-task)")
    set_chaos("exit:1", victim)
    res, events = traced_run()
    rep = {r.task_id(): r for r in res}[victim]
    check(rep.verdict == "error", f"victim verdict is error "
                                  f"(got {rep.verdict})")
    check("exit code 3" in (rep.error or ""),
          f"exit cause attributed in error: {rep.error!r}")
    survivors_identical(baseline, res, victim)
    check_fault_visible(events, ("pool.broken", "task.retry",
                                 "worker.crash", "task.failed"), "exit")

    print(f"[chaos-smoke] hang:1 targeting {victim} "
          f"({HANG_BUDGET_S:g}s budget)")
    set_chaos("hang:1", victim)
    res, events = traced_run(timeout_s=HANG_BUDGET_S)
    rep = {r.task_id(): r for r in res}[victim]
    check(rep.verdict == "timeout", f"victim verdict is timeout "
                                    f"(got {rep.verdict})")
    check("budget" in (rep.error or ""),
          f"budget overrun attributed in error: {rep.error!r}")
    check(rep.wall_s >= HANG_BUDGET_S * 0.9,
          f"measured elapsed recorded, not the nominal budget "
          f"({rep.wall_s:.2f}s)")
    survivors_identical(baseline, res, victim)
    check_fault_visible(events, ("task.timeout",), "hang")

    print(f"[chaos-smoke] corrupt_cache:1 targeting {CASES[0]} "
          f"(byte flipped on commit)")
    cache_dir = tempfile.mkdtemp(prefix="graphguard-chaos-cache-")
    try:
        set_chaos("corrupt_cache:1", CASES[0])
        res, events = traced_run(cache=cache_dir)
        check(res.ok, "run with corrupting cache still verifies cleanly")
        check_fault_visible(events, ("chaos.corrupt_cache",),
                            "corrupt_cache")
        set_chaos(None)
        cache = CertificateCache(cache_dir)
        check(cache.recovered_corrupt >= 1,
              f"corrupt journal entry skipped on reload "
              f"({cache.recovered_corrupt} recovered)")
        res2 = run_suite(cache=cache)
        check(res2.cache["hits"] == len(baseline) - 1
              and res2.cache["misses"] == 1,
              f"only the damaged entry re-proved "
              f"(hits={res2.cache['hits']}, misses={res2.cache['misses']})")
        survivors_identical(baseline, res2, None)
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)

    set_chaos(None)
    if _failures:
        print(f"[chaos-smoke] FAILED: {len(_failures)} assertion(s):")
        for f in _failures:
            print(f"  - {f}")
        return 1
    print("[chaos-smoke] PASS: every injected fault was contained, "
          "attributed, and survived with byte-identical certificates")
    return 0


if __name__ == "__main__":
    sys.exit(main())

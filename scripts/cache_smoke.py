#!/usr/bin/env python
"""Persistent-cache smoke — cold vs warm re-verification
(``make cache-smoke``; see ``repro.runtime.cache``).

Asserts the cache's contract end to end on real verification work:

* a cold suite run commits every deterministic verdict (all misses);
* a warm re-run serves every task from the journal (all hits) with
  byte-identical stable summaries (verdicts + R_o certificates);
* a torn tail line (the crash-mid-append case) is skipped on reload and
  only that entry is re-proved;
* the whole-model path (``gpt@dp2xtp2``) re-verifies warm via
  ``canonical_key`` content addressing, and the measured cold/warm walls
  are printed for EXPERIMENTS.md.

Exit code 0 only if every assertion holds.
"""
import json
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.abspath(__file__)), "..", "src"))

from repro.api import Suite  # noqa: E402
from repro.runtime import CertificateCache  # noqa: E402

CASES = ("tp_layer", "sp_rope", "ep_moe", "sp_moe")
DEGREES = (2,)
WORKERS = 2

_failures = []


def check(cond, what):
    tag = "ok" if cond else "FAIL"
    print(f"[cache-smoke]   {tag}: {what}")
    if not cond:
        _failures.append(what)


def run_suite(cache):
    with Suite(cases=CASES, degrees=DEGREES) as suite:
        return suite.run(workers=WORKERS, timeout_s=60.0, cache=cache)


def main():
    os.environ.pop("GRAPHGUARD_CHAOS", None)
    cache_dir = tempfile.mkdtemp(prefix="graphguard-cache-smoke-")
    try:
        n = len(CASES)
        print(f"[cache-smoke] suite: {n} cases @ deg2, cache {cache_dir}")
        cold = run_suite(cache_dir)
        check(cold.ok, "cold run verifies cleanly")
        check(cold.cache["misses"] == n and cold.cache["hits"] == 0,
              f"cold run commits everything (misses={cold.cache['misses']})")

        warm = run_suite(cache_dir)
        check(warm.cache["hits"] == n and warm.cache["misses"] == 0,
              f"warm run all hits (hits={warm.cache['hits']})")
        check(json.dumps(cold.stable_summary(), sort_keys=True)
              == json.dumps(warm.stable_summary(), sort_keys=True),
              "warm certificates byte-identical to cold")

        # crash-mid-append: tear the journal's last line in half — the
        # reload must skip it and the next run re-proves only that entry
        journal = os.path.join(cache_dir, "journal.jsonl")
        with open(journal, "rb") as f:
            lines = f.readlines()
        with open(journal, "wb") as f:
            f.writelines(lines[:-1])
            f.write(lines[-1][:len(lines[-1]) // 2])
        cache = CertificateCache(cache_dir)
        check(cache.recovered_corrupt == 1,
              f"torn tail line skipped on reload "
              f"({cache.recovered_corrupt} recovered)")
        resumed = run_suite(cache)
        check(resumed.cache["hits"] == n - 1
              and resumed.cache["misses"] == 1,
              f"resume re-proves only the torn entry "
              f"(hits={resumed.cache['hits']}, "
              f"misses={resumed.cache['misses']})")
        check(json.dumps(cold.stable_summary(), sort_keys=True)
              == json.dumps(resumed.stable_summary(), sort_keys=True),
              "resumed certificates byte-identical to cold")
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)

    # whole-model path: obligation-level content addressing
    from repro.modelcheck import check_model
    model_dir = tempfile.mkdtemp(prefix="graphguard-cache-smoke-model-")
    try:
        print("[cache-smoke] modelcheck: gpt@dp2xtp2 cold vs warm")
        t0 = time.perf_counter()
        cold_m = check_model("gpt", "dp2xtp2", cache=model_dir)
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm_m = check_model("gpt", "dp2xtp2", cache=model_dir)
        warm_s = time.perf_counter() - t0
        check(cold_m.verdict == "certificate" and cold_m.cache["hits"] == 0,
              f"cold model check proves all "
              f"{cold_m.cache['misses']} obligations")
        check(warm_m.verdict == "certificate"
              and warm_m.cache["misses"] == 0
              and warm_m.cache["hits"] == cold_m.cache["misses"],
              f"warm model check all hits (hits={warm_m.cache['hits']})")
        check(json.dumps(cold_m.stable_summary(), sort_keys=True)
              == json.dumps(warm_m.stable_summary(), sort_keys=True),
              "warm model verdicts byte-identical to cold")
        print(f"[cache-smoke] gpt@dp2xtp2 wall: cold {cold_s*1e3:.0f} ms, "
              f"warm {warm_s*1e3:.0f} ms "
              f"({cold_s / max(warm_s, 1e-9):.1f}x)")
    finally:
        shutil.rmtree(model_dir, ignore_errors=True)

    if _failures:
        print(f"[cache-smoke] FAILED: {len(_failures)} assertion(s):")
        for f in _failures:
            print(f"  - {f}")
        return 1
    print("[cache-smoke] PASS: cold commits, warm hits, torn entries "
          "recovered, certificates byte-identical throughout")
    return 0


if __name__ == "__main__":
    sys.exit(main())

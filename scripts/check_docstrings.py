#!/usr/bin/env python
"""Docstring coverage gate for the documented packages (no dependencies).

The container has no ruff/pydocstyle, so this is a small AST walker
enforcing the subset of the `D` ruleset we care about — every module,
public class, and public top-level function in ``src/repro/core``,
``src/repro/api`` and ``src/repro/obs`` must carry a docstring
(pyproject.toml carries the matching ruff configuration for environments
that do have ruff).

Exit codes: 0 clean, 1 findings (one ``path:line: message`` per line).
"""
import ast
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PACKAGES = [os.path.join("src", "repro", "core"),
            os.path.join("src", "repro", "api"),
            os.path.join("src", "repro", "obs")]


def is_public(name: str) -> bool:
    return not name.startswith("_")


def check_file(path: str) -> list:
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    rel = os.path.relpath(path, ROOT)
    findings = []
    if not ast.get_docstring(tree):
        findings.append(f"{rel}:1: missing module docstring")
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if is_public(node.name) and not ast.get_docstring(node):
                findings.append(f"{rel}:{node.lineno}: missing docstring on "
                                f"public function `{node.name}`")
        elif isinstance(node, ast.ClassDef) and is_public(node.name):
            if not ast.get_docstring(node):
                findings.append(f"{rel}:{node.lineno}: missing docstring on "
                                f"public class `{node.name}`")
    return findings


def main() -> int:
    findings = []
    for pkg in PACKAGES:
        pkg_dir = os.path.join(ROOT, pkg)
        for dirpath, _, files in os.walk(pkg_dir):
            for fname in sorted(files):
                if fname.endswith(".py"):
                    findings.extend(check_file(os.path.join(dirpath, fname)))
    for f in findings:
        print(f)
    if findings:
        print(f"{len(findings)} missing docstring(s)")
        return 1
    print("docstring coverage: core + api + obs clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())

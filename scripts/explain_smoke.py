#!/usr/bin/env python
"""Proof-provenance gate (``make explain-smoke``).

Three legs, mirroring the claims docs/EXPLANATIONS.md makes:

1. **Clean certificates explain and replay.**  Every single-layer case
   in a representative set, one whole-model run, one train strategy and
   one serve strategy are verified with provenance recording on; every
   resulting certificate explanation must pass the independent replay
   checker (:func:`repro.core.explain.check_explanation`) — the lemma
   chain is re-applied numerically on seeded inputs *outside* the
   e-graph.
2. **Injected bugs produce a failure-frontier narrative.**  Each smoke
   bug (``wrong_spec``, ``accum_no_rescale``, ``stale_cache_shard``)
   must yield a frontier that names the stuck operator, and the
   narrative must mention the lemma frontier (fired-but-did-not-close or
   the explicit no-lemma line).
3. **Explanations are free when off.**  A run with ``explain`` off must
   produce byte-identical certificates (R_o + deterministic stats) to
   the explain-on run, and its report JSON must carry no ``explanation``
   key.

Exit codes: 0 all legs pass, 1 any leg fails.
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

FAILURES = []


def _check(ok: bool, what: str) -> None:
    status = "ok" if ok else "FAIL"
    print(f"[explain-smoke] {what}: {status}")
    if not ok:
        FAILURES.append(what)


def _deterministic_stats(stats: dict) -> dict:
    """The stats keys that are byte-stable across runs (no timings)."""
    return {k: stats[k] for k in ("egraph_nodes", "gs_ops", "gd_ops",
                                  "lemma_fires") if k in stats}


def leg_clean_replay() -> None:
    """Leg 1: clean certificates explain, and every chain replays."""
    from repro.api import verify
    from repro.core.explain import check_explanation, explanation_steps
    from repro.gradcheck import check_train
    from repro.modelcheck import check_model
    from repro.servecheck import check_serve

    for case in ("tp_layer", "fsdp_mlp", "sp_moe", "tp_dp_2d"):
        rep = verify(case, engine_opts={"explain": True})
        _check(rep.verdict == "certificate" and rep.explanation is not None,
               f"case {case}: certificate with explanation")
        res = check_explanation(rep.explanation)
        _check(res["ok"], f"case {case}: replay "
               f"({res['checked_steps']} step(s)"
               + (f"; {res['failures'][:1]}" if res["failures"] else "")
               + ")")

    def nested(reports):
        for key in sorted(reports):
            expl = reports[key].get("explanation")
            if expl and expl.get("kind") == "certificate":
                yield key, expl

    m = check_model("gpt", "dp2xtp2", workers=0,
                    engine_opts={"explain": True})
    _check(m.verdict == "certificate", "model gpt@dp2xtp2: certificate")
    for key, expl in nested(m.reports):
        res = check_explanation(expl)
        _check(res["ok"], f"model obligation {key}: replay "
               f"({explanation_steps(expl)} step(s))")

    t = check_train("dp_accum", engine_opts={"explain": True})
    _check(t.verdict == "certificate", "train dp_accum: certificate")
    for key, expl in nested(t.reports):
        _check(check_explanation(expl)["ok"], f"train param {key}: replay")

    s = check_serve("tp_decode", engine_opts={"explain": True})
    _check(s.verdict == "certificate", "serve tp_decode: certificate")
    for key, expl in nested(s.reports):
        _check(check_explanation(expl)["ok"],
               f"serve obligation {key}: replay")


def leg_bug_frontier() -> None:
    """Leg 2: every smoke bug yields a failure-frontier narrative naming
    the stuck op and the lemma frontier."""
    from repro.gradcheck import check_train
    from repro.modelcheck import check_model
    from repro.servecheck import check_serve

    def frontier_of(reports):
        for rep in reports.values():
            expl = rep.get("explanation")
            if expl and expl.get("kind") == "failure_frontier":
                return expl
        return None

    runs = [
        ("model wrong_spec",
         lambda: check_model("gpt", "dp2xtp2", bug="wrong_spec",
                             bug_layer=3, workers=0,
                             engine_opts={"explain": True})),
        ("train accum_no_rescale",
         lambda: check_train("dp_accum", bug="accum_no_rescale",
                             engine_opts={"explain": True})),
        ("serve stale_cache_shard",
         lambda: check_serve("tp_decode", bug="stale_cache_shard",
                             engine_opts={"explain": True})),
    ]
    for name, run in runs:
        rep = run()
        _check(rep.ok, f"bug {name}: detected and localized")
        expl = frontier_of(rep.reports)
        _check(expl is not None, f"bug {name}: failure frontier present")
        if expl is None:
            continue
        stuck = expl.get("stuck_op") or {}
        _check(bool(stuck.get("op_name")),
               f"bug {name}: frontier names stuck op "
               f"`{stuck.get('op_name')}` (#{stuck.get('op_index')})")
        narrative = "\n".join(expl.get("narrative") or ())
        _check("stuck at" in narrative and "lemma" in narrative,
               f"bug {name}: narrative mentions stuck op + lemma frontier")


def leg_off_identical() -> None:
    """Leg 3: explain-off certificates are byte-identical and carry no
    explanation key."""
    from repro.api import verify

    for case in ("tp_layer", "sp_moe"):
        off = verify(case)
        on = verify(case, engine_opts={"explain": True})
        _check("explanation" not in off.to_json(),
               f"case {case}: off-report has no explanation key")
        _check(off.r_o == on.r_o
               and _deterministic_stats(off.stats)
               == _deterministic_stats(on.stats),
               f"case {case}: off/on certificates byte-identical")
        _check(json.dumps(on.explanation, sort_keys=True)
               == json.dumps(verify(
                   case, engine_opts={"explain": True}).explanation,
                   sort_keys=True),
               f"case {case}: explanation deterministic across runs")


def main() -> int:
    leg_clean_replay()
    leg_bug_frontier()
    leg_off_identical()
    if FAILURES:
        print(f"[explain-smoke] FAIL: {len(FAILURES)} check(s) failed",
              file=sys.stderr)
        return 1
    print("[explain-smoke] all legs passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

# CI entry points (see also scripts/ci.sh for environments without make)

PY ?= python
PYTEST ?= $(PY) -m pytest

.PHONY: verify quick bench-smoke bench bench-gate bug-suite suite golden \
	modelcheck-smoke gradcheck-smoke servecheck-smoke chaos-smoke \
	cache-smoke fn-smoke obs-smoke explain-smoke docs-check

# tier-1 gate: full test suite
verify:
	PYTHONPATH=src $(PYTEST) -x -q

# fast gate: skip the heavy per-architecture model smoke tests
quick:
	PYTHONPATH=src $(PYTEST) -x -q -m "not slow"

# verification benchmark sections only, median-of-3 — CI smoke
bench-smoke:
	PYTHONPATH=src $(PY) benchmarks/run.py --smoke

# full benchmark incl. engine ablation; writes BENCH_verify.json
bench:
	PYTHONPATH=src $(PY) benchmarks/run.py

# perf gate: fresh --smoke medians vs the checked-in BENCH_verify.json
# (1.5x default tolerance on the inference hot path; see scripts/check_bench.py)
bench-gate: bench-smoke
	$(PY) scripts/check_bench.py

# reproduce the paper §6.2 bug case study (all registered bug classes)
bug-suite:
	PYTHONPATH=src $(PY) examples/verify_bug_suite.py

# full clean-case matrix at degree 2 via the parallel suite runner, diffed
# against the checked-in golden so a silently-broken strategy fails CI
suite:
	PYTHONPATH=src $(PY) -m repro.api --degrees 2 --workers 4 \
		--check tests/golden/suite_degree2.json

# deterministically regenerate tests/golden/*.json after a strategy change
# (refuses to bake in a failing matrix)
golden:
	PYTHONPATH=src $(PY) -m repro.api --update-golden --workers 4

# whole-model verification smoke: gpt at dp2xtp2 must emit a clean
# whole-model certificate (block-by-block with obligation dedup), and the
# injected per-layer spec bug must be localized to the offending block
modelcheck-smoke:
	PYTHONPATH=src $(PY) -m repro.launch.verify --model gpt --plan dp2xtp2
	PYTHONPATH=src $(PY) -m repro.launch.verify --model gpt --plan dp2xtp2 \
		--inject-bug wrong_spec --bug-layer 3; test $$? -eq 1

# training-step verification smoke: the dp_accum train strategy must emit a
# clean per-parameter gradient certificate (microbatch accumulation through
# the dus_concat lemma), and the injected accumulation-rescale bug must be
# localized to exactly the offending parameter (rc=1)
gradcheck-smoke:
	PYTHONPATH=src $(PY) -m repro.launch.verify --train dp_accum
	PYTHONPATH=src $(PY) -m repro.launch.verify --train dp_accum \
		--inject-bug accum_no_rescale; test $$? -eq 1

# serving-path verification smoke: tp_decode must emit a clean serving
# certificate (decode steps deduped by position class + the prefill-read
# chain closing through dus_concat/dus_unfold), and the injected
# stale-cache-shard bug must be localized to exactly its decode step (rc=1)
servecheck-smoke:
	PYTHONPATH=src $(PY) -m repro.launch.verify --serve tp_decode
	PYTHONPATH=src $(PY) -m repro.launch.verify --serve tp_decode \
		--inject-bug stale_cache_shard; test $$? -eq 1

# fault-tolerance gate: inject worker crashes / hard exits / hangs / cache
# corruption (GRAPHGUARD_CHAOS) and assert every fault is contained,
# attributed to exactly the afflicted task, and survived with byte-identical
# certificates for everything else
chaos-smoke:
	PYTHONPATH=src $(PY) scripts/chaos_smoke.py

# persistent-cache gate: cold run commits, warm run serves byte-identical
# certificates from the journal, a torn tail line is recovered and only
# that entry re-proved
cache-smoke:
	PYTHONPATH=src $(PY) scripts/cache_smoke.py

# generic-frontend smoke: the bring-your-own-function example must run end
# to end (clean certificate, localized bug, source-located unsupported
# primitive) and the same task must resolve through the --fn CLI path
fn-smoke:
	PYTHONPATH=src $(PY) examples/verify_your_own_fn.py
	PYTHONPATH=src $(PY) -m repro.launch.verify \
		--fn examples/verify_your_own_fn.py:make_task --json > /dev/null

# observability gate: a traced pooled run must produce a Perfetto-loadable
# trace that the inspector can diagnose (its last line names the top lemma)
obs-smoke:
	PYTHONPATH=src $(PY) -m repro.launch.verify --serve tp_decode \
		--workers 2 --trace /tmp/graphguard_trace.json --metrics
	PYTHONPATH=src $(PY) -m repro.obs report /tmp/graphguard_trace.json \
		| grep "top lemma: "

# proof-provenance gate: every clean certificate's lemma chain must pass
# the independent replay checker; every injected smoke bug must produce a
# failure-frontier narrative naming the stuck op and the fired lemmas;
# explain-off runs stay byte-identical
explain-smoke:
	PYTHONPATH=src $(PY) scripts/explain_smoke.py

# docs gates: lemma catalog completeness, CLI --help drift, docstring
# coverage over repro.core + repro.api + repro.obs (dependency-free AST
# checker)
docs-check:
	$(PY) scripts/check_cli_docs.py
	$(PY) scripts/check_docstrings.py
	PYTHONPATH=src $(PYTEST) -x -q tests/test_docs.py

# CI entry points (see also scripts/ci.sh for environments without make)

PY ?= python
PYTEST ?= $(PY) -m pytest

.PHONY: verify quick bench-smoke bench bug-suite suite

# tier-1 gate: full test suite
verify:
	PYTHONPATH=src $(PYTEST) -x -q

# fast gate: skip the heavy per-architecture model smoke tests
quick:
	PYTHONPATH=src $(PYTEST) -x -q -m "not slow"

# verification benchmark sections only, single repeat — CI smoke
bench-smoke:
	$(PY) benchmarks/run.py --smoke

# full benchmark incl. engine ablation; writes BENCH_verify.json
bench:
	$(PY) benchmarks/run.py

# reproduce the paper §6.2 six-bug case study
bug-suite:
	PYTHONPATH=src $(PY) examples/verify_bug_suite.py

# full clean-case matrix at degree 2 via the parallel suite runner, diffed
# against the checked-in golden so a silently-broken strategy fails CI
suite:
	PYTHONPATH=src $(PY) -m repro.api --degrees 2 --workers 4 \
		--check tests/golden/suite_degree2.json

"""CLI for trace inspection: ``python -m repro.obs report trace.json``.

Renders the top-N lemma ranking, the per-obligation queue-vs-run
breakdown, the pool timeline, cache/dedup savings, and fault events from
a ``--trace`` artifact (either the Chrome ``trace.json`` or its
``.jsonl`` sibling).  Exits 0 on a readable trace, 1 on an empty one.
"""
from __future__ import annotations

import argparse
import sys

from .inspect import report


def main(argv=None) -> int:
    """Parse ``report PATH [--top N]`` and print the trace report."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Inspect a trace written by `repro.launch.verify "
                    "--trace PATH`.")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rep = sub.add_parser("report", help="render a trace artifact")
    rep.add_argument("path", help="trace.json (Chrome) or .jsonl event log "
                                  "(either may be gzipped: .gz)")
    rep.add_argument("--top", type=int, default=10,
                     help="rows per ranking section (default 10)")
    rep.add_argument("--json", action="store_true",
                     help="emit the report as a JSON object with stable "
                          "key order instead of text")
    args = ap.parse_args(argv)
    return report(args.path, top=args.top, as_json=args.json)


if __name__ == "__main__":
    sys.exit(main())

"""``repro.obs`` — dependency-free tracing + metrics for the verifier.

Two independent facilities, both zero-cost when idle:

* :mod:`repro.obs.trace` — a :class:`Tracer` recording nested spans and
  instant events per process (lock-free appends under the GIL), merged
  across ``SupervisedPool`` workers via the pool's existing Manager
  plumbing, and exported as a Chrome/Perfetto ``trace.json`` plus a
  JSONL event log.
* :mod:`repro.obs.metrics` — a process-local registry of counters and
  bounded histograms (lemma fires, e-graph growth, queue wait vs run
  wall, cache hit ratio, retry/degradation counts).

Inspection: ``python -m repro.obs report trace.json`` renders the top
lemmas by time, the slowest obligations with their queue-vs-run split,
a per-worker pool timeline, cache/dedup savings, and any fault events.

Observability is strictly behaviour-neutral: certificates, goldens, and
stable summaries are byte-identical with tracing on or off (enforced by
``tests/test_obs.py``), and the package is deliberately excluded from
the certificate-cache engine fingerprint.  See ``docs/OBSERVABILITY.md``
for the span taxonomy and metric names.
"""
from . import metrics
from .metrics import REGISTRY
from .trace import (Tracer, complete, counter, current, event, install,
                    span, start, stop)

__all__ = [
    "Tracer", "start", "stop", "current", "install",
    "span", "event", "counter", "complete",
    "metrics", "REGISTRY",
]

"""Structured tracing: nested spans + instant events, Chrome-exportable.

One :class:`Tracer` per process.  Spans are recorded as Chrome trace
"complete" events (``ph: "X"``) with microsecond epoch timestamps; all
timestamps inside a process derive from a single ``(epoch, perf_counter)``
anchor captured at tracer construction, so span nesting within a thread
is well-formed by construction (no clock mixing).  Appends go straight
to a plain list — atomic under the GIL, no locks on the hot path.

Cross-process merging: ``SupervisedPool`` workers install their own
tracer inside the worker shim, wrap the task in a ``task`` span, and
ship the event batch back through the pool's existing ``Manager``
plumbing; the parent tracer :meth:`Tracer.absorb`\\ s them, keeping each
worker's real ``pid`` so the Perfetto timeline shows one track per
worker process.

Module-level :func:`span` / :func:`event` / :func:`counter` /
:func:`complete` dispatch to the installed tracer and are no-ops (a
shared null context manager / an early return) when tracing is off —
instrumented code never needs an ``if`` guard.

Chrome trace event format reference:
https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

_TLS = threading.local()


class _NullSpan:
    """Shared do-nothing context manager returned when tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Context manager recording one nested span on a :class:`Tracer`.

    ``__enter__`` pushes onto a thread-local stack (the depth becomes a
    span attribute); ``__exit__`` pops and emits a single ``X`` event.
    """

    __slots__ = ("tracer", "name", "cat", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, attrs: dict):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.attrs = attrs

    def __enter__(self):
        stack = getattr(_TLS, "stack", None)
        if stack is None:
            stack = _TLS.stack = []
        stack.append(self.name)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        stack = _TLS.stack
        stack.pop()
        args = dict(self.attrs)
        args["depth"] = len(stack)
        self.tracer._emit_x(self.name, self.cat, self._t0, t1, args)
        return False


class Tracer:
    """Per-process span/event recorder with Chrome + JSONL export.

    All events carry epoch-derived microsecond timestamps computed from
    one ``(base_epoch, base_perf)`` anchor, so spans recorded in this
    process nest consistently and merge onto a shared timeline with
    events absorbed from other processes (whose anchors are their own —
    wall clocks on one machine agree to well under typical span widths).
    """

    def __init__(self, process: str = "main"):
        self.process = process
        self.pid = os.getpid()
        self.events: List[dict] = []
        self._base_epoch = time.time()
        self._base_perf = time.perf_counter()
        # Perfetto track naming: one metadata event per producing process.
        self.events.append({"name": "process_name", "ph": "M", "ts": 0.0,
                            "pid": self.pid, "tid": 0,
                            "args": {"name": process}})

    # -- timestamp plumbing -------------------------------------------------
    def _epoch_us(self, perf_t: float) -> float:
        return (self._base_epoch + (perf_t - self._base_perf)) * 1e6

    def _emit_x(self, name: str, cat: str, t0: float, t1: float,
                args: dict) -> None:
        self.events.append({
            "name": name, "cat": cat, "ph": "X",
            "ts": round(self._epoch_us(t0), 3),
            "dur": round((t1 - t0) * 1e6, 3),
            "pid": self.pid, "tid": threading.get_ident() & 0xFFFFFFFF,
            "args": args})

    # -- recording ----------------------------------------------------------
    def span(self, name: str, cat: str = "engine", **attrs: Any) -> _Span:
        """Open a nested span; closes (and records) on ``with`` exit."""
        return _Span(self, name, cat, attrs)

    def event(self, name: str, cat: str = "engine", **attrs: Any) -> None:
        """Record an instant event (Chrome ``ph: "i"``)."""
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "s": "p",
            "ts": round(self._epoch_us(time.perf_counter()), 3),
            "pid": self.pid, "tid": threading.get_ident() & 0xFFFFFFFF,
            "args": attrs})

    def counter(self, name: str, cat: str = "metric",
                **values: float) -> None:
        """Record a Chrome counter sample (``ph: "C"``) — e.g. e-graph
        nodes/classes over time, rendered as a stacked area in Perfetto."""
        self.events.append({
            "name": name, "cat": cat, "ph": "C",
            "ts": round(self._epoch_us(time.perf_counter()), 3),
            "pid": self.pid, "tid": 0, "args": values})

    def span_from(self, name: str, t0_perf: float, t1_perf: float,
                  cat: str = "engine", **attrs: Any) -> None:
        """Record a span from explicit ``perf_counter`` endpoints — for
        code that already times itself (e.g. the engine's phase timers)."""
        self._emit_x(name, cat, t0_perf, t1_perf, dict(attrs))

    def complete(self, name: str, start_epoch_s: float, end_epoch_s: float,
                 cat: str = "pool", **attrs: Any) -> None:
        """Record a span from explicit epoch endpoints.

        Used by the pool supervisor to reconstruct per-task ``queue`` and
        ``run`` intervals from its bookkeeping (submit time, heartbeat
        start, completion) — these wall-clock spans live on the parent
        timeline and are exempt from the perf-anchored nesting guarantee.
        """
        if end_epoch_s < start_epoch_s:
            start_epoch_s = end_epoch_s
        self.events.append({
            "name": name, "cat": cat, "ph": "X",
            "ts": round(start_epoch_s * 1e6, 3),
            "dur": round((end_epoch_s - start_epoch_s) * 1e6, 3),
            "pid": self.pid, "tid": threading.get_ident() & 0xFFFFFFFF,
            "args": attrs})

    def absorb(self, events: List[dict]) -> None:
        """Merge an event batch shipped from another process (worker pids
        are preserved, giving each worker its own Perfetto track)."""
        self.events.extend(events)

    # -- export -------------------------------------------------------------
    def chrome_trace(self) -> dict:
        """The Chrome/Perfetto ``trace.json`` object (displayTimeUnit ms)."""
        evs = sorted(self.events,
                     key=lambda e: (e.get("ph") != "M", e.get("ts", 0.0)))
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def write_chrome(self, path: str) -> None:
        """Write the Chrome/Perfetto trace JSON to ``path`` (gzipped when
        the path ends in ``.gz`` — Perfetto loads those directly)."""
        with _open_text(path, "wt") as f:
            json.dump(self.chrome_trace(), f)

    def write_jsonl(self, path: str) -> None:
        """Write one event per line (ts-sorted) — the grep-friendly log
        (``zcat``-friendly when the path ends in ``.gz``)."""
        evs = sorted((e for e in self.events if e.get("ph") != "M"),
                     key=lambda e: e.get("ts", 0.0))
        with _open_text(path, "wt") as f:
            for e in evs:
                f.write(json.dumps(e) + "\n")


def _open_text(path: str, mode: str):
    """Text-mode open that is transparent to a ``.gz`` suffix."""
    if path.endswith(".gz"):
        import gzip
        return gzip.open(path, mode)
    return open(path, mode.rstrip("t") or "r")


# -- module-level dispatch (no-op when no tracer installed) -----------------
_ACTIVE: Optional[Tracer] = None


def current() -> Optional[Tracer]:
    """The installed tracer, or None when tracing is off."""
    return _ACTIVE


def install(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Install ``tracer`` as the process tracer; returns the previous one."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, tracer
    return prev


def start(process: str = "main") -> Tracer:
    """Create and install a fresh :class:`Tracer` for this process."""
    tracer = Tracer(process)
    install(tracer)
    return tracer


def stop() -> Optional[Tracer]:
    """Uninstall and return the active tracer (idempotent)."""
    return install(None)


def span(name: str, cat: str = "engine", **attrs: Any):
    """Span on the installed tracer; shared null context when off."""
    t = _ACTIVE
    return _NULL_SPAN if t is None else t.span(name, cat, **attrs)


def event(name: str, cat: str = "engine", **attrs: Any) -> None:
    """Instant event on the installed tracer; no-op when off."""
    t = _ACTIVE
    if t is not None:
        t.event(name, cat, **attrs)


def counter(name: str, cat: str = "metric", **values: float) -> None:
    """Counter sample on the installed tracer; no-op when off."""
    t = _ACTIVE
    if t is not None:
        t.counter(name, cat, **values)


def complete(name: str, start_epoch_s: float, end_epoch_s: float,
             cat: str = "pool", **attrs: Any) -> None:
    """Explicit-endpoint span on the installed tracer; no-op when off."""
    t = _ACTIVE
    if t is not None:
        t.complete(name, start_epoch_s, end_epoch_s, cat, **attrs)


def load_events(path: str) -> List[dict]:
    """Load events from a ``trace.json`` (Chrome object) or ``.jsonl`` log.

    Accepts either export format (gzipped or not — a ``.gz`` suffix is
    decompressed transparently) so ``repro.obs report`` works on all.
    """
    with _open_text(path, "rt") as f:
        text = f.read()
    try:
        obj = json.loads(text)
    except json.JSONDecodeError:         # more than one line: JSONL
        return [json.loads(line) for line in text.splitlines()
                if line.strip()]
    if isinstance(obj, dict) and "traceEvents" in obj:
        return list(obj["traceEvents"])
    return [obj] if isinstance(obj, dict) else list(obj)

"""Trace inspection: turn a trace artifact into a run diagnosis.

``python -m repro.obs report trace.json`` renders, from the artifact
alone (no live process needed):

* **top lemmas** — per-lemma fire counts and in-lemma milliseconds
  aggregated from ``saturate.batch`` events, ranked by time;
* **slowest obligations** — per-task ``queue`` (waiting behind pool
  siblings) vs ``run`` (on-worker wall) split from the supervisor's
  pool spans, so a task queued behind a slow sibling is distinguishable
  from a slow task;
* **pool timeline** — one line per process (parent + each worker pid)
  with the tasks it executed;
* **savings** — cache probe hit ratio and scheduler dedup events;
* **faults** — every ``cat: "fault"`` event (chaos injections, broken
  pools, retries, timeouts, degraded fallbacks).

Accepts both export formats (Chrome ``trace.json`` and the ``.jsonl``
event log).  The final line is always ``top lemma: <name>`` — the
``make obs-smoke`` CI gate greps for it.
"""
from __future__ import annotations

from typing import Dict, List

from .trace import load_events


def lemma_totals(events: List[dict]) -> Dict[str, dict]:
    """Aggregate per-lemma ``fires``/``ms`` over ``saturate.batch`` events."""
    totals: Dict[str, dict] = {}
    for e in events:
        if e.get("name") != "saturate.batch":
            continue
        args = e.get("args") or {}
        for name, n in (args.get("fires") or {}).items():
            totals.setdefault(name, {"fires": 0, "ms": 0.0})["fires"] += n
        for name, ms in (args.get("ms") or {}).items():
            totals.setdefault(name, {"fires": 0, "ms": 0.0})["ms"] += ms
    return totals


def obligation_rows(events: List[dict]) -> List[dict]:
    """Per-task queue/run/total milliseconds from the supervisor spans."""
    rows: Dict[str, dict] = {}
    for e in events:
        if e.get("ph") != "X" or e.get("cat") != "pool":
            continue
        key = (e.get("args") or {}).get("key")
        if key is None or e["name"] not in ("queue", "run", "task"):
            continue
        row = rows.setdefault(key, {"key": key, "queue_ms": 0.0,
                                    "run_ms": 0.0, "pids": set()})
        dur_ms = e.get("dur", 0.0) / 1e3
        if e["name"] == "queue":
            row["queue_ms"] += dur_ms
        elif e["name"] == "run":
            row["run_ms"] += dur_ms
        else:  # worker-side "task" span: fallback run wall + worker pid
            row.setdefault("task_ms", 0.0)
            row["task_ms"] += dur_ms
            row["pids"].add(e.get("pid"))
    out = []
    for row in rows.values():
        if not row["run_ms"] and row.get("task_ms"):
            row["run_ms"] = row["task_ms"]
        row["total_ms"] = row["queue_ms"] + row["run_ms"]
        out.append(row)
    out.sort(key=lambda r: -r["total_ms"])
    return out


def pool_timeline(events: List[dict]) -> List[str]:
    """One line per process: which tasks ran there, in ts order."""
    by_pid: Dict[int, List[tuple]] = {}
    names: Dict[int, str] = {}
    for e in events:
        if e.get("ph") == "M" and e.get("name") == "process_name":
            names[e["pid"]] = (e.get("args") or {}).get("name", "?")
        if (e.get("ph") == "X" and e.get("cat") == "pool"
                and e.get("name") == "task"):
            key = (e.get("args") or {}).get("key", "?")
            by_pid.setdefault(e["pid"], []).append((e.get("ts", 0.0), key))
    lines = []
    for pid in sorted(by_pid):
        tasks = " -> ".join(k for _, k in sorted(by_pid[pid]))
        lines.append(f"  pid {pid} ({names.get(pid, 'worker')}): {tasks}")
    return lines


def fault_lines(events: List[dict]) -> List[str]:
    """Every fault-category event, ts-ordered, one line each."""
    rows = [e for e in events if e.get("cat") == "fault"]
    rows.sort(key=lambda e: e.get("ts", 0.0))
    out = []
    for e in rows:
        args = ", ".join(f"{k}={v}" for k, v in sorted(
            (e.get("args") or {}).items()) if k != "depth")
        out.append(f"  {e['name']} [{args}]")
    return out


def explanation_stats(events: List[dict]) -> Dict[str, float]:
    """Aggregate proof-provenance work: how many explanations were built
    (``explain`` instant events from the engine), total chain steps and
    outputs covered, and the milliseconds spent in ``explain.build``
    spans.  Empty dict when the run had ``--explain`` off."""
    stats = {"explanations": 0, "outputs": 0, "steps": 0, "build_ms": 0.0}
    seen = False
    for e in events:
        if e.get("name") == "explain" and e.get("ph") == "i":
            args = e.get("args") or {}
            stats["explanations"] += 1
            stats["outputs"] += int(args.get("outputs", 0))
            stats["steps"] += int(args.get("steps", 0))
            seen = True
        elif e.get("name") == "explain.build" and e.get("ph") == "X":
            stats["build_ms"] += e.get("dur", 0.0) / 1e3
            seen = True
    return stats if seen else {}


def to_json_report(events: List[dict], top: int = 10) -> dict:
    """The machine-readable counterpart of :func:`render` — same
    aggregations, stable key order (serialize with ``sort_keys=True``)."""
    lemmas = sorted(lemma_totals(events).items(),
                    key=lambda kv: (-kv[1]["ms"], -kv[1]["fires"], kv[0]))
    obligations = []
    for row in obligation_rows(events)[:top]:
        r = dict(row)
        r["pids"] = sorted(p for p in r.get("pids", ()) if p is not None)
        obligations.append(r)
    probes = [e for e in events if e.get("name") == "cache.probe"]
    hits = sum(1 for e in probes
               if (e.get("args") or {}).get("result") == "hit")
    dedup = [dict(e.get("args") or {}) for e in events
             if e.get("name") == "dedup"]
    faults = [{"name": e["name"],
               "args": {k: v for k, v in sorted(
                   (e.get("args") or {}).items()) if k != "depth"}}
              for e in sorted((e for e in events if e.get("cat") == "fault"),
                              key=lambda e: e.get("ts", 0.0))]
    spans = [e for e in events if e.get("ph") == "X"]
    return {
        "schema_version": 1,
        "events": len(events),
        "spans": len(spans),
        "processes": len({e.get("pid") for e in events}),
        "lemmas": {name: {"fires": t["fires"], "ms": round(t["ms"], 3)}
                   for name, t in lemmas[:top]},
        "obligations": [{"key": r["key"],
                         "queue_ms": round(r["queue_ms"], 3),
                         "run_ms": round(r["run_ms"], 3),
                         "total_ms": round(r["total_ms"], 3),
                         "pids": r["pids"]} for r in obligations],
        "cache": None if not probes else {
            "probes": len(probes), "hits": hits,
            "hit_ratio": round(hits / len(probes), 4)},
        "dedup": dedup,
        "faults": faults,
        "explanations": explanation_stats(events) or None,
        "top_lemma": lemmas[0][0] if lemmas else "-",
    }


def render(events: List[dict], top: int = 10) -> str:
    """The full text report for one trace (see module docstring)."""
    lines: List[str] = []
    spans = [e for e in events if e.get("ph") == "X"]
    lines.append(f"trace: {len(events)} events, {len(spans)} spans, "
                 f"{len({e.get('pid') for e in events})} process(es)")

    lemmas = sorted(lemma_totals(events).items(),
                    key=lambda kv: (-kv[1]["ms"], -kv[1]["fires"], kv[0]))
    if lemmas:
        lines.append(f"\n-- top lemmas (by in-lemma time, top {top}) --")
        for name, t in lemmas[:top]:
            lines.append(f"  {name:<24} {t['ms']:9.2f} ms  "
                         f"{t['fires']:6d} fires")

    obligations = obligation_rows(events)
    if obligations:
        lines.append(f"\n-- slowest obligations (queue vs run, top {top}) --")
        for row in obligations[:top]:
            lines.append(f"  {row['key']:<32} queue {row['queue_ms']:8.1f} ms"
                         f"  run {row['run_ms']:8.1f} ms")
        timeline = pool_timeline(events)
        if timeline:
            lines.append("\n-- pool timeline --")
            lines.extend(timeline)

    probes = [e for e in events if e.get("name") == "cache.probe"]
    if probes:
        hits = sum(1 for e in probes
                   if (e.get("args") or {}).get("result") == "hit")
        lines.append(f"\n-- cache --\n  probes {len(probes)}, hits {hits}, "
                     f"hit ratio {hits / len(probes):.2f}")
    for e in events:
        if e.get("name") == "dedup":
            a = e.get("args") or {}
            lines.append(f"  dedup [{a.get('subsystem', '?')}]: "
                         f"{a.get('total')} blocks -> {a.get('unique')} "
                         f"obligations")

    xstats = explanation_stats(events)
    if xstats:
        lines.append("\n-- explanations --")
        lines.append(f"  {xstats['explanations']} explanation(s) covering "
                     f"{xstats['outputs']} output(s), "
                     f"{xstats['steps']} chain step(s) total, built in "
                     f"{xstats['build_ms']:.2f} ms")

    faults = fault_lines(events)
    if faults:
        lines.append("\n-- faults --")
        lines.extend(faults)

    top_name = lemmas[0][0] if lemmas else "-"
    lines.append(f"\ntop lemma: {top_name}")
    return "\n".join(lines)


def report(path: str, top: int = 10, as_json: bool = False) -> int:
    """Load ``path`` (trace.json or .jsonl, optionally gzipped) and print
    the report — text by default, the stable-key JSON object under
    ``as_json``.

    Returns a process exit code: 0 on a readable trace, 1 on an empty
    one (nothing to diagnose usually means the run never started).
    """
    import json as _json
    events = load_events(path)
    if not events:
        if as_json:
            print(_json.dumps({"error": "no events", "path": path},
                              sort_keys=True))
        else:
            print(f"{path}: no events")
        return 1
    if as_json:
        print(_json.dumps(to_json_report(events, top=top), indent=2,
                          sort_keys=True))
    else:
        print(render(events, top=top))
    return 0

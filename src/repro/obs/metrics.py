"""Process-local metrics registry: counters + bounded histograms.

Instrumented code grabs an instrument lazily by name —
``REGISTRY.counter("cache.hits").inc()`` — so the registry's contents
reflect exactly what the run exercised.  Histograms keep a fixed-size
deterministic reservoir (first :data:`Histogram.SAMPLE` observations,
then a modular ring) so quantile estimates cost O(1) memory no matter
how hot the path is.

The registry is observational only: nothing in certificates, goldens,
or stable summaries reads it.  ``launch/verify.py --metrics`` prints
:func:`render` to stderr and adds :meth:`MetricsRegistry.snapshot` to
the JSON envelope under the ``metrics`` key (only under the flag, so
the schema-v2 key set stays pinned otherwise).

Metric name inventory (see ``docs/OBSERVABILITY.md``): ``engine.runs``,
``engine.lemma_fires``, ``engine.infer_s``, ``engine.egraph_nodes``,
``engine.frontier_ready``, ``pool.tasks``, ``pool.queue_s``,
``pool.run_s``, ``pool.retries``, ``pool.timeouts``, ``pool.broken``,
``pool.degraded``, ``cache.hits``, ``cache.misses``, ``cache.commits``,
``chaos.injected``.
"""
from __future__ import annotations

from typing import Dict, Optional, Union


class Counter:
    """A monotonically increasing named count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        """Add ``n`` (default 1) to the counter."""
        self.value += n


class Histogram:
    """Summary statistics over observed values with a bounded reservoir.

    Tracks exact count/sum/min/max; p50/p95 come from a deterministic
    sample (first ``SAMPLE`` values, then overwrite at ``count % SAMPLE``)
    so snapshots are reproducible for a given observation sequence.
    """

    SAMPLE = 256

    __slots__ = ("name", "count", "total", "vmin", "vmax", "_sample")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None
        self._sample: list = []

    def observe(self, value: float) -> None:
        """Record one observation."""
        value = float(value)
        if len(self._sample) < self.SAMPLE:
            self._sample.append(value)
        else:
            self._sample[self.count % self.SAMPLE] = value
        self.count += 1
        self.total += value
        self.vmin = value if self.vmin is None else min(self.vmin, value)
        self.vmax = value if self.vmax is None else max(self.vmax, value)

    def _quantile(self, q: float) -> float:
        s = sorted(self._sample)
        return s[min(int(q * len(s)), len(s) - 1)]

    def snapshot(self) -> dict:
        """JSON-ready summary: count/sum/mean/min/max/p50/p95."""
        if not self.count:
            return {"count": 0}
        return {
            "count": self.count,
            "sum": round(self.total, 6),
            "mean": round(self.total / self.count, 6),
            "min": round(self.vmin, 6),
            "max": round(self.vmax, 6),
            "p50": round(self._quantile(0.50), 6),
            "p95": round(self._quantile(0.95), 6),
        }


class MetricsRegistry:
    """Name-keyed collection of counters and histograms."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        """Get (or create) the counter called ``name``."""
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def histogram(self, name: str) -> Histogram:
        """Get (or create) the histogram called ``name``."""
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name)
        return h

    def snapshot(self) -> dict:
        """JSON-ready view of every instrument, sorted by name."""
        return {
            "counters": {k: self._counters[k].value
                         for k in sorted(self._counters)},
            "histograms": {k: self._histograms[k].snapshot()
                           for k in sorted(self._histograms)},
        }

    def reset(self) -> None:
        """Drop every instrument (used at the start of a ``--metrics`` run
        so the report covers exactly that invocation)."""
        self._counters.clear()
        self._histograms.clear()


REGISTRY = MetricsRegistry()


def render(snapshot: Optional[Union[dict, MetricsRegistry]] = None) -> str:
    """Human-readable table of a registry snapshot (default: the global)."""
    if snapshot is None:
        snapshot = REGISTRY
    if isinstance(snapshot, MetricsRegistry):
        snapshot = snapshot.snapshot()
    lines = ["-- metrics --"]
    counters = snapshot.get("counters", {})
    for name in sorted(counters):
        lines.append(f"{name:<28} {counters[name]}")
    for name, h in sorted(snapshot.get("histograms", {}).items()):
        if not h.get("count"):
            continue
        lines.append(
            f"{name:<28} n={h['count']} sum={h['sum']:.4g} "
            f"mean={h['mean']:.4g} p50={h['p50']:.4g} "
            f"p95={h['p95']:.4g} max={h['max']:.4g}")
    if len(lines) == 1:
        lines.append("(no metrics recorded)")
    return "\n".join(lines)

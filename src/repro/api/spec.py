"""Typed task model for the verification API.

``StrategySpec`` is the frozen, fully-materialized description of one
verification task: the sequential fragment G_s, the per-rank SPMD
implementation G_d, the mesh, the input sharding, and the identity /
expectation metadata the registry stamps on it.  It replaces the anonymous
``(seq_fn, dist_fn, mesh_axes, in_specs, avals, names)`` 6-tuples the
strategy builders used to return — but still *iterates* as that 6-tuple,
so legacy unpacking code keeps working:

    seq_fn, dist_fn, axes, specs, avals, names = build_spec("tp_layer")

``BugSpec`` describes one injectable bug class and how its detection
surfaces (paper §6.2): ``expected="refinement_error"`` bugs raise at a
localized operator; ``expected="unexpected_relation"`` bugs (paper bug 5)
produce a *clean but unexpected* certificate the user inspects.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, Optional, Tuple, Union

# A parallelism degree is either a single int (applied to every mesh axis)
# or a tuple with one entry per mesh axis, e.g. ``(4, 2)`` for a 2D
# ``{"dp": 4, "tp": 2}`` mesh.
Degree = Union[int, Tuple[int, ...]]

# Expectation vocabulary (also used by Report.verdict where applicable):
#   certificate          refinement holds, clean R_o certificate
#   incomplete           sound false alarm — documented completeness gap:
#                        the correct implementation raises RefinementError
#   refinement_error     injected bug is localized via RefinementError
#   unexpected_relation  clean certificate whose relation differs from the
#                        user's expectation (paper bug 5 detection mode)
EXPECTATIONS = ("certificate", "incomplete", "refinement_error",
                "unexpected_relation")

# What verdict ``verify()`` should produce for each expectation.
EXPECTED_VERDICT = {
    "certificate": "certificate",
    "incomplete": "refinement_error",
    "refinement_error": "refinement_error",
    "unexpected_relation": "certificate",
}


def normalize_degree(degree: Degree) -> Degree:
    """Canonical degree value: ints stay ints, sequences become tuples,
    and a 1-tuple collapses to its int (so JSON round-trips — where tuples
    come back as lists — and CLI parses agree on one representation)."""
    if isinstance(degree, (tuple, list)):
        t = tuple(int(d) for d in degree)
        return t[0] if len(t) == 1 else t
    return int(degree)


def degree_token(degree: Degree) -> str:
    """Stable string form of a degree: ``4`` -> "4", ``(2, 4)`` -> "2x4"."""
    degree = normalize_degree(degree)
    if isinstance(degree, tuple):
        return "x".join(str(d) for d in degree)
    return str(degree)


def parse_degree(token: str) -> Degree:
    """Inverse of :func:`degree_token` for CLI args: "4" -> 4,
    "2x4" -> (2, 4)."""
    try:
        parts = [int(p) for p in str(token).split("x")]
        if any(p < 1 for p in parts):
            raise ValueError(token)
        return normalize_degree(parts)
    except ValueError:
        raise ValueError(
            f"bad degree {token!r} — expected a positive int like `4` or a "
            f"per-axis tuple like `2x4`") from None


def axis_degrees(degree: Degree, n_axes: int) -> Tuple[int, ...]:
    """Per-axis view of a degree for an ``n_axes``-dimensional mesh: a
    scalar broadcasts to every axis, a tuple must match the axis count."""
    degree = normalize_degree(degree)
    if isinstance(degree, tuple):
        if len(degree) != n_axes:
            raise ValueError(
                f"degree {degree} has {len(degree)} entries for a "
                f"{n_axes}-axis mesh")
        return degree
    return (degree,) * n_axes


def task_id(case: str, degree: Degree, bug: Optional[str] = None) -> str:
    """The one stable matrix key: ``case@degN[+bug]`` (used by specs,
    reports, suite tasks, and the golden file alike).  Per-axis degrees
    render as ``case@degNxM``."""
    base = f"{case}@deg{degree_token(degree)}"
    return f"{base}+{bug}" if bug else base


@dataclass(frozen=True)
class BugSpec:
    """One injectable bug class hosted by a strategy."""
    name: str
    expected: str = "refinement_error"   # or "unexpected_relation"
    description: str = ""

    def __post_init__(self):
        if self.expected not in ("refinement_error", "unexpected_relation"):
            raise ValueError(
                f"bug `{self.name}`: expected must be refinement_error or "
                f"unexpected_relation, got {self.expected!r}")

    @property
    def raises(self) -> bool:
        return self.expected == "refinement_error"


@dataclass(frozen=True)
class StrategySpec:
    """A fully-built verification task (one case at one degree, ± one bug).

    The first six fields mirror the legacy builder tuple; the rest is
    registry-stamped metadata.  Frozen: derive variants with
    ``dataclasses.replace``.
    """
    seq_fn: Callable
    dist_fn: Callable
    mesh_axes: Any                       # {axis name: parallelism degree}
    in_specs: Tuple[Any, ...]            # PartitionSpec per input -> R_i
    avals: Tuple[Any, ...]               # ShapeDtypeStruct per global input
    input_names: Tuple[str, ...]
    # -- identity / expectation metadata (stamped by the registry) ----------
    name: str = ""
    degree: Degree = 0                   # int, or one entry per mesh axis
    bug: Optional[str] = None
    expected: str = "certificate"        # one of EXPECTATIONS
    description: str = ""

    def __post_init__(self):
        object.__setattr__(self, "degree", normalize_degree(self.degree))
        object.__setattr__(self, "in_specs", tuple(self.in_specs))
        object.__setattr__(self, "avals", tuple(self.avals))
        object.__setattr__(self, "input_names", tuple(self.input_names))
        if self.expected not in EXPECTATIONS:
            raise ValueError(f"expected must be one of {EXPECTATIONS}, "
                             f"got {self.expected!r}")

    # -- legacy 6-tuple protocol -------------------------------------------
    def __iter__(self):
        yield self.seq_fn
        yield self.dist_fn
        yield self.mesh_axes
        yield list(self.in_specs)
        yield list(self.avals)
        yield list(self.input_names)

    def as_tuple(self):
        return tuple(self)

    # -----------------------------------------------------------------------
    @property
    def expected_verdict(self) -> str:
        return EXPECTED_VERDICT[self.expected]

    def with_identity(self, **kw) -> "StrategySpec":
        return replace(self, **kw)

    def task_id(self) -> str:
        return task_id(self.name, self.degree, self.bug)

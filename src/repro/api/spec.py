"""Typed task model for the verification API.

``StrategySpec`` is the frozen, fully-materialized description of one
verification task: the sequential fragment G_s, the per-rank SPMD
implementation G_d, the mesh, the input sharding, and the identity /
expectation metadata the registry stamps on it.  It replaces the anonymous
``(seq_fn, dist_fn, mesh_axes, in_specs, avals, names)`` 6-tuples the
strategy builders used to return — but still *iterates* as that 6-tuple,
so legacy unpacking code keeps working:

    seq_fn, dist_fn, axes, specs, avals, names = build_spec("tp_layer")

``BugSpec`` describes one injectable bug class and how its detection
surfaces (paper §6.2): ``expected="refinement_error"`` bugs raise at a
localized operator; ``expected="unexpected_relation"`` bugs (paper bug 5)
produce a *clean but unexpected* certificate the user inspects.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, Optional, Tuple

# Expectation vocabulary (also used by Report.verdict where applicable):
#   certificate          refinement holds, clean R_o certificate
#   incomplete           sound false alarm — documented completeness gap:
#                        the correct implementation raises RefinementError
#   refinement_error     injected bug is localized via RefinementError
#   unexpected_relation  clean certificate whose relation differs from the
#                        user's expectation (paper bug 5 detection mode)
EXPECTATIONS = ("certificate", "incomplete", "refinement_error",
                "unexpected_relation")

# What verdict ``verify()`` should produce for each expectation.
EXPECTED_VERDICT = {
    "certificate": "certificate",
    "incomplete": "refinement_error",
    "refinement_error": "refinement_error",
    "unexpected_relation": "certificate",
}


def task_id(case: str, degree: int, bug: Optional[str] = None) -> str:
    """The one stable matrix key: ``case@degN[+bug]`` (used by specs,
    reports, suite tasks, and the golden file alike)."""
    base = f"{case}@deg{degree}"
    return f"{base}+{bug}" if bug else base


@dataclass(frozen=True)
class BugSpec:
    """One injectable bug class hosted by a strategy."""
    name: str
    expected: str = "refinement_error"   # or "unexpected_relation"
    description: str = ""

    def __post_init__(self):
        if self.expected not in ("refinement_error", "unexpected_relation"):
            raise ValueError(
                f"bug `{self.name}`: expected must be refinement_error or "
                f"unexpected_relation, got {self.expected!r}")

    @property
    def raises(self) -> bool:
        return self.expected == "refinement_error"


@dataclass(frozen=True)
class StrategySpec:
    """A fully-built verification task (one case at one degree, ± one bug).

    The first six fields mirror the legacy builder tuple; the rest is
    registry-stamped metadata.  Frozen: derive variants with
    ``dataclasses.replace``.
    """
    seq_fn: Callable
    dist_fn: Callable
    mesh_axes: Any                       # {axis name: parallelism degree}
    in_specs: Tuple[Any, ...]            # PartitionSpec per input -> R_i
    avals: Tuple[Any, ...]               # ShapeDtypeStruct per global input
    input_names: Tuple[str, ...]
    # -- identity / expectation metadata (stamped by the registry) ----------
    name: str = ""
    degree: int = 0
    bug: Optional[str] = None
    expected: str = "certificate"        # one of EXPECTATIONS
    description: str = ""

    def __post_init__(self):
        object.__setattr__(self, "in_specs", tuple(self.in_specs))
        object.__setattr__(self, "avals", tuple(self.avals))
        object.__setattr__(self, "input_names", tuple(self.input_names))
        if self.expected not in EXPECTATIONS:
            raise ValueError(f"expected must be one of {EXPECTATIONS}, "
                             f"got {self.expected!r}")

    # -- legacy 6-tuple protocol -------------------------------------------
    def __iter__(self):
        yield self.seq_fn
        yield self.dist_fn
        yield self.mesh_axes
        yield list(self.in_specs)
        yield list(self.avals)
        yield list(self.input_names)

    def as_tuple(self):
        return tuple(self)

    # -----------------------------------------------------------------------
    @property
    def expected_verdict(self) -> str:
        return EXPECTED_VERDICT[self.expected]

    def with_identity(self, **kw) -> "StrategySpec":
        return replace(self, **kw)

    def task_id(self) -> str:
        return task_id(self.name, self.degree, self.bug)

"""``verify_functions()`` — bring-your-own-function verification.

The ROADMAP's promised one-liner: hand the library the sequential function
you trust, the distributed (``shard_map``-style, collectives allowed)
implementation you wrote, the mesh and the input ``PartitionSpec``s, and
get back the standard :class:`~repro.api.Report`::

    from repro.api import verify_functions

    report = verify_functions(seq_mlp, dist_mlp, {"tp": 2},
                              in_specs=(P(), P(None, "tp"), P("tp", None)),
                              example_args=(x, w1, w2))
    assert report.verdict == "certificate"

Input shapes come from ``example_args`` (concrete arrays, used only for
their shape/dtype) or ``avals`` (``jax.ShapeDtypeStruct`` per input);
input names default to the sequential function's parameter names.  Both
functions are traced through the strict :mod:`repro.core.from_jaxpr`
frontend, so a primitive the term language cannot model raises
:class:`~repro.core.UnsupportedPrimitive` with the offending primitive and
its source location — surfaced as an ``error`` verdict by
``verify_functions`` and as an exception by the raising flavour
``run_functions``.

The registered strategy suite (``repro.dist.strategies``) doubles as the
golden cross-check for this path: capturing each case's real jax functions
here yields byte-identical certificates to ``run_spec`` on the registered
spec (``tests/test_from_jaxpr.py``).
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Sequence

import jax

from ..core import (Certificate, RefinementError, check_refinement,
                    expand_spmd, normalize_mesh)
from ..core.from_jaxpr import (capture_function, capture_spmd_function,
                               default_input_names)
from .report import Report
from .runner import _engine_opts
from .spec import StrategySpec

__all__ = ["function_spec", "run_functions", "verify_functions"]


def _resolve_avals(avals, example_args) -> tuple:
    if (avals is None) == (example_args is None):
        raise ValueError(
            "pass exactly one of avals= (ShapeDtypeStructs) or "
            "example_args= (concrete arrays, used for shape/dtype only)")
    if avals is not None:
        return tuple(avals)
    return tuple(jax.ShapeDtypeStruct(tuple(a.shape), a.dtype)
                 for a in example_args)


def function_spec(fn_seq: Callable, fn_dist: Callable, mesh,
                  in_specs: Sequence, avals: Optional[Sequence] = None,
                  input_names: Optional[Sequence[str]] = None, *,
                  example_args: Optional[Sequence] = None,
                  name: Optional[str] = None) -> StrategySpec:
    """Build a :class:`StrategySpec` for an ad-hoc function pair.

    The returned spec carries the same fields a registered builder would
    produce (so it runs through ``run_spec``/``verify``/the suite runner
    unchanged); ``name`` defaults to the distributed function's ``__name__``
    and ``degree`` to the per-axis mesh sizes.
    """
    mesh_axes = normalize_mesh(mesh)
    avals = _resolve_avals(avals, example_args)
    if len(in_specs) != len(avals):
        raise ValueError(f"{len(in_specs)} in_specs for {len(avals)} inputs")
    if input_names is None:
        input_names = default_input_names(fn_seq, len(avals))
    degrees = tuple(mesh_axes.values())
    return StrategySpec(
        fn_seq, fn_dist, mesh_axes, tuple(in_specs), avals,
        tuple(input_names),
        name=name or getattr(fn_dist, "__name__", "user_fn"),
        degree=degrees if len(degrees) > 1 else degrees[0])


def run_functions(fn_seq: Callable, fn_dist: Callable, mesh,
                  in_specs: Sequence, avals: Optional[Sequence] = None,
                  input_names: Optional[Sequence[str]] = None, *,
                  example_args: Optional[Sequence] = None,
                  strict: bool = True,
                  engine_opts: Optional[dict] = None) -> Certificate:
    """Raising flavour of :func:`verify_functions` -> live ``Certificate``.

    Captures both functions through the generic jaxpr frontend (strict by
    default), expands the SPMD side per rank, derives the input relation
    from ``in_specs``, and runs relation inference.  Raises
    ``RefinementError`` when the implementation does not refine the
    sequential function and ``UnsupportedPrimitive``/``CaptureError`` when
    a function cannot be lowered.
    """
    spec = function_spec(fn_seq, fn_dist, mesh, in_specs, avals, input_names,
                         example_args=example_args)
    if not isinstance(engine_opts, _engine_opts):
        engine_opts = _engine_opts(engine_opts)
    with engine_opts as eo:
        gs = capture_function(spec.seq_fn, list(spec.avals),
                              list(spec.input_names), strict=strict)
        cap = capture_spmd_function(spec.dist_fn, spec.mesh_axes,
                                    list(spec.in_specs), list(spec.avals),
                                    list(spec.input_names), strict=strict)
        gd, r_i = expand_spmd(cap)
        return check_refinement(gs, gd, r_i, max_nodes=eo.max_nodes,
                                explain=eo.explain)


def verify_functions(fn_seq: Callable, fn_dist: Callable, mesh,
                     in_specs: Sequence, avals: Optional[Sequence] = None,
                     input_names: Optional[Sequence[str]] = None, *,
                     example_args: Optional[Sequence] = None,
                     name: Optional[str] = None, strict: bool = True,
                     engine_opts: Optional[dict] = None) -> Report:
    """Verify that ``fn_dist`` on ``mesh`` refines ``fn_seq`` -> ``Report``.

    The generic counterpart of :func:`~repro.api.verify`: instead of a
    registered case name it takes the two functions directly.  Outcomes map
    to the standard verdicts — ``certificate`` (with the clean R_o
    relation), ``refinement_error`` (with the localized operator payload),
    or ``error`` (capture/engine failure, including
    ``UnsupportedPrimitive`` for code outside the term vocabulary).
    Caller mistakes (mismatched avals/in_specs, bad mesh, bad engine_opts)
    raise instead of becoming verdicts.
    """
    spec = function_spec(fn_seq, fn_dist, mesh, in_specs, avals, input_names,
                         example_args=example_args, name=name)
    engine_opts = _engine_opts(engine_opts)   # caller mistakes raise here
    t0 = time.perf_counter()
    try:
        cert = run_functions(spec.seq_fn, spec.dist_fn, spec.mesh_axes,
                             spec.in_specs, spec.avals, spec.input_names,
                             strict=strict, engine_opts=engine_opts)
    except RefinementError as e:
        return Report(
            case=spec.name, degree=spec.degree, bug=None,
            verdict="refinement_error", expected="certificate", ok=False,
            localization=e.payload(),
            explanation=getattr(e, "explanation", None),
            wall_s=round(time.perf_counter() - t0, 6))
    except Exception as e:  # noqa: BLE001 — capture/engine failure -> verdict
        return Report(
            case=spec.name, degree=spec.degree, bug=None,
            verdict="error", expected="certificate", ok=False,
            error=f"{type(e).__name__}: {e}",
            wall_s=round(time.perf_counter() - t0, 6))
    cert_json = cert.to_json()
    return Report(
        case=spec.name, degree=spec.degree, bug=None,
        verdict="certificate", expected="certificate", ok=True,
        r_o=cert_json["r_o"], stats=cert_json["stats"], certificate=cert,
        explanation=cert.explanation,
        wall_s=round(time.perf_counter() - t0, 6))

"""Structured verification results.

``Report`` is the JSON-ready outcome of one ``verify()`` call — verdict,
the R_o certificate (stringified clean terms), the localization payload on
failure, and the engine's per-phase timers — replacing the CLI's
prints-and-exceptions surface.  The live ``Certificate`` object is attached
for in-process library use but never serialized (Terms are hash-consed and
deliberately not picklable), so reports cross process boundaries cheaply.

Verdicts:
    certificate        refinement holds; ``r_o`` carries the clean relation
    refinement_error   G_d does not (provably) refine G_s; ``localization``
                       names the operator (paper §6.2 debugging workflow)
    error              capture/engine failure (e.g. unsupported primitive)
    timeout            the suite runner gave up on the task
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields
from typing import Any, Dict, Optional

from .spec import Degree, normalize_degree, task_id

VERDICTS = ("certificate", "refinement_error", "error", "timeout")


@dataclass
class Report:
    """Outcome of verifying one (case, degree, bug) task."""
    case: str
    degree: Degree                       # int, or one entry per mesh axis
    bug: Optional[str]
    verdict: str                         # one of VERDICTS
    expected: str                        # registry expectation (spec.expected)
    ok: bool                             # verdict matches the expectation
    r_o: Optional[Dict[str, str]] = None        # G_s output -> clean Term str
    localization: Optional[Dict[str, Any]] = None
    stats: Optional[Dict[str, Any]] = None      # Certificate.stats (timers &c)
    error: Optional[str] = None
    wall_s: float = 0.0
    runtime: Optional[Dict[str, Any]] = None    # execution-layer facts
                                                # (cache hit, retries,
                                                # degraded_reason) — never
                                                # part of stable_summary
    explanation: Optional[Dict[str, Any]] = None  # proof provenance
                                                # (``--explain`` only): lemma
                                                # chain or failure frontier;
                                                # omitted from to_json when
                                                # absent and never part of
                                                # stable_summary
    certificate: Any = field(default=None, repr=False, compare=False)

    def __post_init__(self):
        # tuple degrees arrive as lists after a JSON round trip
        self.degree = normalize_degree(self.degree)
        if self.verdict not in VERDICTS:
            raise ValueError(f"verdict must be one of {VERDICTS}, "
                             f"got {self.verdict!r}")

    # -- serialization ------------------------------------------------------
    def to_json(self) -> dict:
        """JSON-safe dict (drops the live certificate object)."""
        out = {f.name: getattr(self, f.name) for f in fields(self)
               if f.name != "certificate"}
        if out.get("explanation") is None:
            # keep explain-off payloads byte-identical to pre-provenance ones
            out.pop("explanation")
        return out

    @classmethod
    def from_json(cls, d: dict) -> "Report":
        allowed = {f.name for f in fields(cls)} - {"certificate"}
        return cls(**{k: v for k, v in d.items() if k in allowed})

    # -- stable views -------------------------------------------------------
    def task_id(self) -> str:
        return task_id(self.case, self.degree, self.bug)

    def stable_summary(self) -> dict:
        """Deterministic fields only (no timings) — golden-diff material."""
        out = {"verdict": self.verdict, "expected": self.expected,
               "ok": self.ok}
        if self.r_o is not None:
            out["r_o"] = dict(sorted(self.r_o.items()))
        if self.localization is not None:
            out["localization"] = {
                k: self.localization[k]
                for k in ("op_index", "op_name", "out_name")
                if k in self.localization}
        return out

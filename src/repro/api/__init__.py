"""repro.api — the first-class verification API.

Typed task model + pluggable strategy registry + parallel suite runner on
top of the GraphGuard engine (``repro.core``):

    from repro.api import verify, Suite, register_strategy

    report = verify("tp_layer", degree=2)          # structured Report
    result = Suite(degrees=(2,)).run(workers=4)    # matrix, process pool

Importing this package populates the registry with the paper-§6 case
suite from ``repro.dist.strategies``; third-party code registers new
cases with ``@register_strategy`` without touching core.
"""
from .spec import (BugSpec, Degree, StrategySpec, EXPECTATIONS, axis_degrees,
                   degree_token, normalize_degree, parse_degree, task_id)
from .registry import (DuplicateStrategyError, RegisteredStrategy, bug_host,
                       build_spec, check_model_task, check_serve_task,
                       check_train_task, get_strategy, list_bugs,
                       list_model_tasks, list_serve_tasks, list_strategies,
                       list_train_tasks, register_strategy)
from .report import Report, VERDICTS
from .runner import run_spec, verify
from .functions import function_spec, run_functions, verify_functions
from .suite import Suite, SuiteResult, SuiteTask

from ..dist import strategies as _strategies  # noqa: F401 — populate registry

__all__ = [
    "BugSpec", "Degree", "StrategySpec", "EXPECTATIONS", "axis_degrees",
    "degree_token", "normalize_degree", "parse_degree", "task_id",
    "DuplicateStrategyError", "RegisteredStrategy", "bug_host", "build_spec",
    "check_model_task", "check_serve_task", "check_train_task",
    "get_strategy", "list_bugs", "list_model_tasks", "list_serve_tasks",
    "list_strategies", "list_train_tasks", "register_strategy",
    "Report", "VERDICTS", "run_spec", "verify", "function_spec",
    "run_functions", "verify_functions", "Suite", "SuiteResult",
    "SuiteTask",
]

"""``Suite`` — fan a (cases × degrees × bugs) matrix across a process pool.

    from repro.api import Suite
    result = Suite(degrees=(2,)).run(workers=4)      # clean matrix
    result = Suite(include_bugs=True).run()          # + all hosted bugs
    print(result.to_markdown()); result.write("suite.json")

Semantics:

* Tasks are the cross product of ``cases`` × ``degrees``, each case's
  hosted bugs riding along when ``include_bugs`` (bugs only run under the
  degrees their host case supports).
* ``run(workers=0)`` (or 1) executes in-process sequentially;
  ``workers >= 2`` uses a process pool (fork start method where
  available, spawn elsewhere) whose workers pre-warm the jax backend in
  an initializer and persist on the Suite instance across ``run`` calls
  — call ``shutdown()`` or use the Suite as a context manager to release
  them.  Workers receive only ``(case, degree, bug)`` name triples and
  rebuild specs from the registry, so nothing unpicklable crosses the
  boundary.
* Results are ordered by the task matrix — never by completion order —
  and the engine's deterministic tie-breaks make certificates (the
  ``r_o`` strings) byte-identical for any worker count and any
  ``GRAPHGUARD_OPT`` setting (covered by ``tests/test_api.py``).
* ``timeout_s`` is the per-task budget, enforced only on pool runs
  (``workers >= 2`` — an in-process sequential run cannot interrupt
  itself).  The happy path dispatches round-robin chunks (one IPC round
  trip per worker) under a ``timeout_s × chunk-size`` budget; a chunk
  that exceeds it or crashes is re-run task-by-task on a fresh pool so
  the offender is reported as ``verdict="timeout"``/``"error"`` under
  the exact per-task budget, and its wedged worker is killed with the
  pool.

CLI (also the CI golden gate — see scripts/ci.sh `suite`):

    python -m repro.api [--cases ...] [--degrees 2 4] [--bugs]
        [--workers N] [--timeout S] [--json PATH] [--markdown PATH]
        [--check GOLDEN | --write-golden GOLDEN]
"""
from __future__ import annotations

import argparse
import json
import multiprocessing
import sys
import time
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeoutError
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .registry import get_strategy, list_bugs, list_strategies
from .report import Report
from .runner import verify
from .spec import Degree, normalize_degree, parse_degree
from .spec import task_id as spec_task_id


@dataclass(frozen=True)
class SuiteTask:
    case: str
    degree: Degree                       # int, or one entry per mesh axis
    bug: Optional[str] = None

    def task_id(self) -> str:
        return spec_task_id(self.case, self.degree, self.bug)


def _run_task(task: Tuple[str, int, Optional[str]],
              engine_opts: Optional[dict]) -> dict:
    """Pool worker: rebuild the spec by name and return a JSON-ready dict."""
    case, degree, bug = task
    return verify(case, degree=degree, bug=bug,
                  engine_opts=engine_opts).to_json()


def _run_batch(tasks: List[Tuple[str, int, Optional[str]]],
               engine_opts: Optional[dict]) -> List[dict]:
    """Pool worker: run a chunk of tasks in one IPC round trip."""
    return [_run_task(t, engine_opts) for t in tasks]


def terminate_pool(pool: ProcessPoolExecutor) -> None:
    """Release a pool without blocking on wedged workers.

    ``shutdown(wait=True)`` would join a worker stuck in a hung task, so
    drop the executor handle and terminate the processes — idle workers
    die instantly, wedged ones get SIGTERM instead of leaking until their
    task (never) finishes.  Shared by the Suite, modelcheck, and
    gradcheck schedulers.
    """
    procs = list(getattr(pool, "_processes", {}).values())
    pool.shutdown(wait=False, cancel_futures=True)
    for p in procs:
        if p.is_alive():
            p.terminate()


def _warm_worker() -> None:
    """Pool initializer: pay the per-process jax backend cost up front.

    jax drops its XLA client cache in forked children (and spawn starts
    cold), so the first jax op in a worker costs hundreds of ms.  Doing it
    in the initializer moves that cost off the first task's critical path
    and lets a reused pool serve later ``Suite.run`` calls at steady-state
    speed.
    """
    import jax.numpy as jnp
    (jnp.zeros((1,)) + 1).block_until_ready()


class SuiteResult:
    """Ordered reports + aggregation to JSON / Markdown."""

    def __init__(self, reports: List[Report], wall_s: float, workers: int):
        self.reports = reports
        self.wall_s = wall_s
        self.workers = workers

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.reports)

    def __iter__(self):
        return iter(self.reports)

    def __len__(self):
        return len(self.reports)

    def summary(self) -> dict:
        verdicts: Dict[str, int] = {}
        for r in self.reports:
            verdicts[r.verdict] = verdicts.get(r.verdict, 0) + 1
        return {
            "total": len(self.reports),
            "ok": sum(r.ok for r in self.reports),
            "not_ok": [r.task_id() for r in self.reports if not r.ok],
            "verdicts": dict(sorted(verdicts.items())),
            "wall_s": round(self.wall_s, 3),
            "workers": self.workers,
        }

    def stable_summary(self) -> dict:
        """Timing-free view keyed by task id — the golden-diff artifact."""
        return {r.task_id(): r.stable_summary() for r in self.reports}

    def to_json(self) -> dict:
        return {
            "schema": 1,
            "summary": self.summary(),
            "reports": [r.to_json() for r in self.reports],
        }

    def to_markdown(self) -> str:
        lines = [
            "| task | verdict | expected | ok | wall ms |",
            "|------|---------|----------|----|--------:|",
        ]
        for r in self.reports:
            lines.append(
                f"| {r.task_id()} | {r.verdict} | {r.expected} "
                f"| {'yes' if r.ok else '**NO**'} | {r.wall_s * 1e3:.1f} |")
        s = self.summary()
        lines.append("")
        lines.append(f"{s['ok']}/{s['total']} tasks matched expectation in "
                     f"{s['wall_s']:.2f}s ({s['workers']} workers).")
        return "\n".join(lines)

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)


class Suite:
    """A verification task matrix with a parallel runner."""

    def __init__(self, cases: Optional[Sequence[str]] = None,
                 degrees: Optional[Sequence[int]] = None,
                 include_bugs: bool = False,
                 bugs: Optional[Sequence[str]] = None,
                 engine_opts: Optional[dict] = None):
        self.cases = tuple(cases) if cases is not None else list_strategies()
        for c in self.cases:
            get_strategy(c)              # fail fast on unknown names
        self.degrees = tuple(normalize_degree(d) for d in degrees) \
            if degrees is not None else None
        if self.degrees is not None:
            for c in self.cases:         # fail fast: a tuple degree on a
                for d in self.degrees:   # single-axis case would abort the
                    get_strategy(c).validate_degree(d)  # run mid-matrix
        self.include_bugs = include_bugs or bugs is not None
        self.bugs = tuple(bugs) if bugs is not None else None
        if self.bugs:
            hosted = list_bugs()
            for b in self.bugs:          # fail fast: a typo would otherwise
                if b not in hosted:      # silently yield zero bug tasks
                    raise KeyError(f"unknown bug `{b}` — registered: "
                                   f"{sorted(hosted)}")
                if hosted[b][0] not in self.cases:
                    raise ValueError(
                        f"bug `{b}` is hosted by case `{hosted[b][0]}`, "
                        f"which is not in this suite's cases — it would "
                        f"never run")
        self.engine_opts = engine_opts
        self._pool: Optional[ProcessPoolExecutor] = None
        self._pool_workers = 0

    def tasks(self) -> List[SuiteTask]:
        out: List[SuiteTask] = []
        for case in self.cases:
            entry = get_strategy(case)
            degrees = self.degrees if self.degrees is not None \
                else entry.degrees
            for deg in degrees:
                out.append(SuiteTask(case, deg))
                if not self.include_bugs:
                    continue
                for b in entry.bugs:
                    if self.bugs is not None and b.name not in self.bugs:
                        continue
                    out.append(SuiteTask(case, deg, b.name))
        return out

    # -- execution ----------------------------------------------------------
    def run(self, workers: Optional[int] = None,
            timeout_s: float = 120.0) -> SuiteResult:
        tasks = self.tasks()
        if workers is None:
            workers = min(4, len(tasks)) or 1
        t0 = time.perf_counter()
        if workers <= 1:
            dicts = [_run_task((t.case, t.degree, t.bug), self.engine_opts)
                     for t in tasks]
        else:
            dicts = self._run_pool(tasks, workers, timeout_s)
        reports = [Report.from_json(d) for d in dicts]
        return SuiteResult(reports, time.perf_counter() - t0, workers)

    # -- pool lifecycle -----------------------------------------------------
    def _get_pool(self, workers: int) -> ProcessPoolExecutor:
        """Create (or reuse) the worker pool.

        The pool persists on the Suite instance across ``run`` calls: the
        per-worker jax backend re-initialization (see ``_warm_worker``) is
        paid once, so repeated matrix sweeps run at steady-state speed.
        Call :meth:`shutdown` (or use the Suite as a context manager) to
        release the processes.
        """
        if self._pool is not None and self._pool_workers != workers:
            self.shutdown()
        if self._pool is None:
            methods = multiprocessing.get_all_start_methods()
            ctx = multiprocessing.get_context(
                "fork" if "fork" in methods else "spawn")
            self._pool = ProcessPoolExecutor(
                max_workers=workers, mp_context=ctx,
                initializer=_warm_worker)
            self._pool_workers = workers
        return self._pool

    def shutdown(self) -> None:
        """Release the pool without blocking on wedged workers (see
        :func:`terminate_pool`)."""
        if self._pool is not None:
            terminate_pool(self._pool)
            self._pool = None
            self._pool_workers = 0

    def __enter__(self) -> "Suite":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def _run_pool(self, tasks: List[SuiteTask], workers: int,
                  timeout_s: float) -> List[dict]:
        """Chunked fan-out with an individual-retry failure path.

        Tasks are dealt round-robin into one chunk per worker so the happy
        path costs one IPC round trip per worker instead of per task (the
        tasks are small; dispatch overhead would otherwise dominate).  A
        chunk that times out or crashes cannot attribute blame, so its
        tasks are re-run one-by-one on a fresh pool with the true per-task
        timeout — slow, but only on the failure path.
        """
        workers = min(workers, len(tasks)) or 1
        pool = self._get_pool(workers)
        dicts: List[dict] = [None] * len(tasks)  # type: ignore[list-item]
        chunk_idx = [list(range(len(tasks)))[i::workers]
                     for i in range(workers)]
        chunk_idx = [c for c in chunk_idx if c]
        futs = [pool.submit(
            _run_batch,
            [(tasks[i].case, tasks[i].degree, tasks[i].bug) for i in idxs],
            self.engine_opts) for idxs in chunk_idx]
        retry: List[int] = []
        poisoned = False
        for idxs, fut in zip(chunk_idx, futs):
            try:
                for i, d in zip(idxs, fut.result(
                        timeout=timeout_s * len(idxs))):
                    dicts[i] = d
            except Exception:  # noqa: BLE001 — timeout or broken worker
                fut.cancel()
                poisoned = True
                retry.extend(idxs)
        if poisoned:
            self.shutdown()              # don't reuse a pool with stuck tasks
        for i in retry:
            dicts[i] = self._run_single(tasks[i], timeout_s)
        if retry:
            self.shutdown()
        return dicts

    @staticmethod
    def _expected(task: SuiteTask) -> str:
        entry = get_strategy(task.case)
        if task.bug is None:
            return entry.expected
        return entry.bug_spec(task.bug).expected

    def _run_single(self, task: SuiteTask, timeout_s: float) -> dict:
        """Failure-path execution: one task, one worker, hard timeout."""
        pool = self._get_pool(1)
        fut = pool.submit(_run_task, (task.case, task.degree, task.bug),
                          self.engine_opts)
        try:
            return fut.result(timeout=timeout_s)
        except FutureTimeoutError:
            fut.cancel()
            self.shutdown()              # kill the wedged worker
            return Report(
                case=task.case, degree=task.degree, bug=task.bug,
                verdict="timeout", expected=self._expected(task), ok=False,
                error=f"exceeded per-task timeout of {timeout_s}s",
                wall_s=timeout_s).to_json()
        except Exception as e:  # noqa: BLE001 — broken worker
            self.shutdown()
            return Report(
                case=task.case, degree=task.degree, bug=task.bug,
                verdict="error", expected=self._expected(task), ok=False,
                error=f"worker failed: {type(e).__name__}: {e}",
                wall_s=0.0).to_json()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

# The checked-in CI golden: the clean degree-2 matrix's stable summary.
# ``--check`` diffs against it (make suite / scripts/ci.sh suite);
# ``--update-golden`` / ``make golden`` regenerates it deterministically.
DEFAULT_GOLDEN = "tests/golden/suite_degree2.json"
GOLDEN_DEGREES = (2,)


def update_golden(path: str = DEFAULT_GOLDEN, workers: int = 4,
                  timeout_s: float = 120.0) -> int:
    """Deterministically regenerate the checked-in golden.

    Certificates are byte-identical for any worker count (covered by
    ``tests/test_api.py``), so the output depends only on the registered
    strategies.  A matrix that misses its own expectations is refused —
    a golden must never encode a failing suite.
    """
    with Suite(degrees=GOLDEN_DEGREES) as suite:
        result = suite.run(workers=workers, timeout_s=timeout_s)
    if not result.ok:
        print(f"[suite] REFUSING to write golden: tasks missed their "
              f"expectation: {result.summary()['not_ok']}", file=sys.stderr)
        return 1
    with open(path, "w") as f:
        json.dump(result.stable_summary(), f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[suite] regenerated golden {path} "
          f"({len(result)} tasks)", file=sys.stderr)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.api",
        description="Run the verification suite matrix in parallel.")
    ap.add_argument("--cases", nargs="*", default=None,
                    help="cases to run (default: every registered strategy)")
    ap.add_argument("--degrees", nargs="*", type=parse_degree, default=None,
                    help="parallelism degrees — ints like `2 4`, or "
                         "per-mesh-axis values like `4x2` for 2D cases "
                         "(default: per-case registry metadata)")
    ap.add_argument("--bugs", action="store_true",
                    help="also run every hosted bug variant")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="per-task timeout in seconds")
    ap.add_argument("--json", default=None, help="write full report JSON")
    ap.add_argument("--markdown", default=None, help="write Markdown table")
    ap.add_argument("--check", default=None, metavar="GOLDEN",
                    help="diff the stable summary against a golden JSON "
                         "and fail on mismatch")
    ap.add_argument("--write-golden", default=None, metavar="GOLDEN",
                    help="write the stable summary as the new golden")
    ap.add_argument("--update-golden", action="store_true",
                    help="regenerate the checked-in CI golden "
                         f"({DEFAULT_GOLDEN}) from the canonical clean "
                         "degree-2 matrix and exit (replaces hand-editing "
                         "when strategies change; refuses to bake in a "
                         "failing matrix)")
    args = ap.parse_args(argv)

    if args.update_golden:
        clash = [flag for flag, v in (
            ("--cases", args.cases), ("--degrees", args.degrees),
            ("--bugs", args.bugs or None), ("--json", args.json),
            ("--markdown", args.markdown), ("--check", args.check),
            ("--write-golden", args.write_golden)) if v is not None]
        if clash:
            ap.error(f"--update-golden regenerates the canonical "
                     f"{DEFAULT_GOLDEN} matrix and cannot be combined with "
                     f"{', '.join(clash)} (use --write-golden PATH for a "
                     f"custom matrix)")
        return update_golden(workers=args.workers, timeout_s=args.timeout)

    suite = Suite(cases=args.cases, degrees=args.degrees,
                  include_bugs=args.bugs)
    result = suite.run(workers=args.workers, timeout_s=args.timeout)
    print(result.to_markdown())
    if args.json:
        result.write(args.json)
        print(f"[suite] wrote {args.json}", file=sys.stderr)
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(result.to_markdown() + "\n")
    if args.write_golden:
        with open(args.write_golden, "w") as f:
            json.dump(result.stable_summary(), f, indent=2, sort_keys=True)
        print(f"[suite] wrote golden {args.write_golden}", file=sys.stderr)
    rc = 0 if result.ok else 1
    if args.check:
        with open(args.check) as f:
            golden = json.load(f)
        got = result.stable_summary()
        if got != golden:
            missing = sorted(set(golden) - set(got))
            extra = sorted(set(got) - set(golden))
            changed = sorted(k for k in set(got) & set(golden)
                             if got[k] != golden[k])
            print(f"[suite] GOLDEN MISMATCH vs {args.check}: "
                  f"missing={missing} extra={extra} changed={changed}",
                  file=sys.stderr)
            for k in changed:
                print(f"  {k}:\n    golden: {golden[k]}\n    got:    {got[k]}",
                      file=sys.stderr)
            rc = 1
        else:
            print(f"[suite] matches golden {args.check}", file=sys.stderr)
    return rc

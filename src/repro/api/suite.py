"""``Suite`` — fan a (cases × degrees × bugs) matrix across a process pool.

    from repro.api import Suite
    result = Suite(degrees=(2,)).run(workers=4)      # clean matrix
    result = Suite(include_bugs=True).run()          # + all hosted bugs
    print(result.to_markdown()); result.write("suite.json")

Semantics:

* Tasks are the cross product of ``cases`` × ``degrees``, each case's
  hosted bugs riding along when ``include_bugs`` (bugs only run under the
  degrees their host case supports).
* ``run(workers=0)`` (or 1) executes in-process sequentially;
  ``workers >= 2`` fans out on the shared fault-tolerant runtime
  (:mod:`repro.runtime`): a supervised pool (fork start method where
  available, spawn elsewhere) whose warmed workers persist on the Suite
  instance across ``run`` calls — call ``shutdown()`` or use the Suite as
  a context manager to release them.  Workers receive only
  ``(case, degree, bug)`` name triples and rebuild specs from the
  registry, so nothing unpicklable crosses the boundary.
* Results are ordered by the task matrix — never by completion order —
  and the engine's deterministic tie-breaks make certificates (the
  ``r_o`` strings) byte-identical for any worker count and any
  ``GRAPHGUARD_OPT`` setting (covered by ``tests/test_api.py``).
* ``timeout_s`` is the *per-task* budget.  On pool runs the runtime
  enforces it from the moment the task starts on a worker (heartbeat
  tracked), reports the offender as ``verdict="timeout"`` with its
  measured elapsed time, kills the wedged worker with its pool, and
  resumes the rest on a replacement pool.  A crashed worker
  (``BrokenProcessPool``) quarantines the tasks it was running onto
  bounded retries with the exit cause recorded in the error string; a
  pool that cannot be rebuilt degrades to in-process execution with a
  structured ``degraded_reason`` in every affected Report.  In-process
  sequential runs cannot interrupt themselves, so budgets are not
  enforced there.
* ``cache=`` attaches the persistent certificate cache
  (:class:`repro.runtime.CertificateCache`): deterministic verdicts are
  committed as they complete, repeat tasks are served as cache hits with
  byte-identical certificates, and an interrupted run resumes from its
  last committed task.

CLI (also the CI golden gate — see scripts/ci.sh `suite`):

    python -m repro.api [--cases ...] [--degrees 2 4] [--bugs]
        [--workers N] [--timeout S] [--cache [DIR] | --no-cache]
        [--json PATH] [--markdown PATH]
        [--check GOLDEN | --write-golden GOLDEN]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

# Back-compat re-exports: these lived here before the fault-tolerant
# runtime was factored out into repro.runtime.
from ..runtime import (RuntimeTask, SupervisedPool,  # noqa: F401
                       execute_inline, pool_stats, resolve_cache,
                       strategy_cache_key, terminate_pool)
from ..runtime.pool import _warm_worker  # noqa: F401 — legacy import path
from .registry import build_spec, get_strategy, list_bugs, list_strategies
from .report import Report
from .runner import verify
from .spec import Degree, normalize_degree, parse_degree
from .spec import task_id as spec_task_id


@dataclass(frozen=True)
class SuiteTask:
    """One cell of the suite matrix: (case, degree, optional bug)."""
    case: str
    degree: Degree                       # int, or one entry per mesh axis
    bug: Optional[str] = None

    def task_id(self) -> str:
        return spec_task_id(self.case, self.degree, self.bug)


def _run_task(task: Tuple[str, int, Optional[str]],
              engine_opts: Optional[dict]) -> dict:
    """Pool worker: rebuild the spec by name and return a JSON-ready dict."""
    case, degree, bug = task
    return verify(case, degree=degree, bug=bug,
                  engine_opts=engine_opts).to_json()


class SuiteResult:
    """Ordered reports + aggregation to JSON / Markdown."""

    def __init__(self, reports: List[Report], wall_s: float, workers: int,
                 cache: Optional[dict] = None,
                 runtime: Optional[dict] = None):
        self.reports = reports
        self.wall_s = wall_s
        self.workers = workers
        self.cache = cache               # persistent-cache stats, if used
        self.runtime = runtime           # pool_stats() aggregate, if pooled

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.reports)

    def __iter__(self):
        return iter(self.reports)

    def __len__(self):
        return len(self.reports)

    def summary(self) -> dict:
        verdicts: Dict[str, int] = {}
        for r in self.reports:
            verdicts[r.verdict] = verdicts.get(r.verdict, 0) + 1
        out = {
            "total": len(self.reports),
            "ok": sum(r.ok for r in self.reports),
            "not_ok": [r.task_id() for r in self.reports if not r.ok],
            "verdicts": dict(sorted(verdicts.items())),
            "wall_s": round(self.wall_s, 3),
            "workers": self.workers,
        }
        if self.cache is not None:
            out["cache"] = self.cache
        if self.runtime is not None:
            # queue-wait vs on-worker wall aggregate (repro.runtime
            # pool_stats) — timing-class, so never in stable_summary()
            out["runtime"] = self.runtime
        return out

    def stable_summary(self) -> dict:
        """Timing-free view keyed by task id — the golden-diff artifact."""
        return {r.task_id(): r.stable_summary() for r in self.reports}

    def to_json(self) -> dict:
        return {
            "schema": 1,
            "summary": self.summary(),
            "reports": [r.to_json() for r in self.reports],
        }

    def to_markdown(self) -> str:
        lines = [
            "| task | verdict | expected | ok | wall ms |",
            "|------|---------|----------|----|--------:|",
        ]
        for r in self.reports:
            lines.append(
                f"| {r.task_id()} | {r.verdict} | {r.expected} "
                f"| {'yes' if r.ok else '**NO**'} | {r.wall_s * 1e3:.1f} |")
        s = self.summary()
        lines.append("")
        lines.append(f"{s['ok']}/{s['total']} tasks matched expectation in "
                     f"{s['wall_s']:.2f}s ({s['workers']} workers).")
        if self.cache is not None:
            lines.append(f"Certificate cache: {self.cache['hits']} hit(s), "
                         f"{self.cache['misses']} miss(es) "
                         f"({self.cache['dir']}).")
        return "\n".join(lines)

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)


class Suite:
    """A verification task matrix with a fault-tolerant parallel runner."""

    def __init__(self, cases: Optional[Sequence[str]] = None,
                 degrees: Optional[Sequence[int]] = None,
                 include_bugs: bool = False,
                 bugs: Optional[Sequence[str]] = None,
                 engine_opts: Optional[dict] = None):
        self.cases = tuple(cases) if cases is not None else list_strategies()
        for c in self.cases:
            get_strategy(c)              # fail fast on unknown names
        self.degrees = tuple(normalize_degree(d) for d in degrees) \
            if degrees is not None else None
        if self.degrees is not None:
            for c in self.cases:         # fail fast: a tuple degree on a
                for d in self.degrees:   # single-axis case would abort the
                    get_strategy(c).validate_degree(d)  # run mid-matrix
        self.include_bugs = include_bugs or bugs is not None
        self.bugs = tuple(bugs) if bugs is not None else None
        if self.bugs:
            hosted = list_bugs()
            for b in self.bugs:          # fail fast: a typo would otherwise
                if b not in hosted:      # silently yield zero bug tasks
                    raise KeyError(f"unknown bug `{b}` — registered: "
                                   f"{sorted(hosted)}")
                if hosted[b][0] not in self.cases:
                    raise ValueError(
                        f"bug `{b}` is hosted by case `{hosted[b][0]}`, "
                        f"which is not in this suite's cases — it would "
                        f"never run")
        self.engine_opts = engine_opts
        self._pool: Optional[SupervisedPool] = None
        self._pool_workers = 0

    def tasks(self) -> List[SuiteTask]:
        out: List[SuiteTask] = []
        for case in self.cases:
            entry = get_strategy(case)
            degrees = self.degrees if self.degrees is not None \
                else entry.degrees
            for deg in degrees:
                out.append(SuiteTask(case, deg))
                if not self.include_bugs:
                    continue
                for b in entry.bugs:
                    if self.bugs is not None and b.name not in self.bugs:
                        continue
                    out.append(SuiteTask(case, deg, b.name))
        return out

    # -- execution ----------------------------------------------------------
    def run(self, workers: Optional[int] = None,
            timeout_s: float = 120.0, cache=None,
            mp_method: Optional[str] = None) -> SuiteResult:
        """Run the matrix; ``cache`` takes anything
        :func:`repro.runtime.resolve_cache` accepts (a directory path, an
        open :class:`CertificateCache`, True for the default location,
        None to consult ``$GRAPHGUARD_CACHE_DIR``).  ``mp_method``
        overrides the worker start method (None = platform default;
        "spawn" sidesteps fork-after-jax hazards in threaded hosts at the
        cost of per-worker interpreter start-up)."""
        tasks = self.tasks()
        if workers is None:
            workers = min(4, len(tasks)) or 1
        cache = resolve_cache(cache)
        t0 = time.perf_counter()
        rts = [self._runtime_task(t, timeout_s, cache) for t in tasks]
        if workers <= 1:
            outcomes = execute_inline(rts, cache=cache)
        else:
            outcomes = self._get_pool(min(workers, len(rts)) or 1,
                                      mp_method).execute(rts, cache=cache)
        reports = [Report.from_json(self._outcome_dict(t, outcomes[t.task_id()]))
                   for t in tasks]
        hits = sum(1 for o in outcomes.values() if o.cache == "hit")
        misses = sum(1 for o in outcomes.values() if o.cache == "miss")
        cache_stats = None if cache is None else \
            {"dir": cache.dir, "hits": hits, "misses": misses,
             "entries": len(cache),
             "recovered_corrupt": cache.recovered_corrupt}
        return SuiteResult(reports, time.perf_counter() - t0, workers,
                           cache=cache_stats, runtime=pool_stats(outcomes))

    def _runtime_task(self, task: SuiteTask, timeout_s: float,
                      cache) -> RuntimeTask:
        cache_key = None
        if cache is not None:
            # content-addressed: mesh + shapes + dtypes + input specs, so
            # an edited strategy re-proves while untouched ones hit
            cache_key = strategy_cache_key(
                build_spec(task.case, degree=task.degree, bug=task.bug),
                self.engine_opts)
        return RuntimeTask(
            key=task.task_id(), fn=_run_task,
            args=((task.case, task.degree, task.bug), self.engine_opts),
            budget_s=timeout_s, cache_key=cache_key)

    def _outcome_dict(self, task: SuiteTask, outcome) -> dict:
        """Convert a runtime outcome into a Report-shaped dict with the
        fault attributed to exactly this task."""
        if outcome.ok:
            d = dict(outcome.value)
            info = outcome.runtime_info()
            if info:
                d["runtime"] = info
            return d
        verdict = "timeout" if outcome.status == "timeout" else "error"
        return Report(
            case=task.case, degree=task.degree, bug=task.bug,
            verdict=verdict, expected=self._expected(task), ok=False,
            error=outcome.error, wall_s=round(outcome.wall_s, 6),
            runtime=outcome.runtime_info() or None).to_json()

    # -- pool lifecycle -----------------------------------------------------
    def _get_pool(self, workers: int,
                  mp_method: Optional[str] = None) -> SupervisedPool:
        """Create (or reuse) the supervised worker pool.

        The pool persists on the Suite instance across ``run`` calls: the
        per-worker jax backend re-initialization (see
        ``repro.runtime.pool._warm_worker``) is paid once, so repeated
        matrix sweeps run at steady-state speed.  Call :meth:`shutdown`
        (or use the Suite as a context manager) to release the processes.
        """
        if self._pool is not None and \
                (self._pool_workers != workers
                 or (mp_method is not None
                     and self._pool.mp_method != mp_method)):
            self.shutdown()
        if self._pool is None:
            self._pool = SupervisedPool(workers, mp_method=mp_method)
            self._pool_workers = workers
        return self._pool

    def shutdown(self) -> None:
        """Release the pool without blocking on wedged workers (see
        :func:`repro.runtime.terminate_pool`)."""
        if self._pool is not None:
            self._pool.shutdown()
            self._pool = None
            self._pool_workers = 0

    def __enter__(self) -> "Suite":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    @staticmethod
    def _expected(task: SuiteTask) -> str:
        entry = get_strategy(task.case)
        if task.bug is None:
            return entry.expected
        return entry.bug_spec(task.bug).expected


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

# The checked-in CI golden: the clean degree-2 matrix's stable summary.
# ``--check`` diffs against it (make suite / scripts/ci.sh suite);
# ``--update-golden`` / ``make golden`` regenerates it deterministically.
DEFAULT_GOLDEN = "tests/golden/suite_degree2.json"
GOLDEN_DEGREES = (2,)


def update_golden(path: str = DEFAULT_GOLDEN, workers: int = 4,
                  timeout_s: float = 120.0) -> int:
    """Deterministically regenerate the checked-in golden.

    Certificates are byte-identical for any worker count (covered by
    ``tests/test_api.py``), so the output depends only on the registered
    strategies.  A matrix that misses its own expectations is refused —
    a golden must never encode a failing suite.
    """
    with Suite(degrees=GOLDEN_DEGREES) as suite:
        result = suite.run(workers=workers, timeout_s=timeout_s)
    if not result.ok:
        print(f"[suite] REFUSING to write golden: tasks missed their "
              f"expectation: {result.summary()['not_ok']}", file=sys.stderr)
        return 1
    with open(path, "w") as f:
        json.dump(result.stable_summary(), f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[suite] regenerated golden {path} "
          f"({len(result)} tasks)", file=sys.stderr)
    return 0


def add_cache_flags(ap: argparse.ArgumentParser) -> None:
    """The shared --cache/--no-cache pair (also used by launch/verify)."""
    from ..runtime import DEFAULT_CACHE_DIR
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--cache", nargs="?", const=True, default=None,
                   metavar="DIR",
                   help="persistent certificate cache: --cache DIR uses "
                        f"DIR, bare --cache uses {DEFAULT_CACHE_DIR}/ "
                        "(default: on only when $GRAPHGUARD_CACHE_DIR "
                        "is set)")
    g.add_argument("--no-cache", action="store_true",
                   help="disable the certificate cache even if "
                        "$GRAPHGUARD_CACHE_DIR is set")


def cache_from_args(args):
    """Map the flag pair onto :func:`repro.runtime.resolve_cache` input."""
    if args.no_cache:
        return False
    return args.cache                    # None -> env default; True/DIR


def main(argv=None) -> int:
    """CLI for ``python -m repro.api``: run the suite matrix in parallel."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.api",
        description="Run the verification suite matrix in parallel.")
    ap.add_argument("--cases", nargs="*", default=None,
                    help="cases to run (default: every registered strategy)")
    ap.add_argument("--degrees", nargs="*", type=parse_degree, default=None,
                    help="parallelism degrees — ints like `2 4`, or "
                         "per-mesh-axis values like `4x2` for 2D cases "
                         "(default: per-case registry metadata)")
    ap.add_argument("--bugs", action="store_true",
                    help="also run every hosted bug variant")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="per-task timeout in seconds")
    add_cache_flags(ap)
    ap.add_argument("--json", default=None, help="write full report JSON")
    ap.add_argument("--markdown", default=None, help="write Markdown table")
    ap.add_argument("--check", default=None, metavar="GOLDEN",
                    help="diff the stable summary against a golden JSON "
                         "and fail on mismatch")
    ap.add_argument("--write-golden", default=None, metavar="GOLDEN",
                    help="write the stable summary as the new golden")
    ap.add_argument("--update-golden", action="store_true",
                    help="regenerate the checked-in CI golden "
                         f"({DEFAULT_GOLDEN}) from the canonical clean "
                         "degree-2 matrix and exit (replaces hand-editing "
                         "when strategies change; refuses to bake in a "
                         "failing matrix)")
    args = ap.parse_args(argv)

    if args.update_golden:
        clash = [flag for flag, v in (
            ("--cases", args.cases), ("--degrees", args.degrees),
            ("--bugs", args.bugs or None), ("--json", args.json),
            ("--markdown", args.markdown), ("--check", args.check),
            ("--cache", args.cache),
            ("--write-golden", args.write_golden)) if v is not None]
        if clash:
            ap.error(f"--update-golden regenerates the canonical "
                     f"{DEFAULT_GOLDEN} matrix and cannot be combined with "
                     f"{', '.join(clash)} (use --write-golden PATH for a "
                     f"custom matrix)")
        return update_golden(workers=args.workers, timeout_s=args.timeout)

    suite = Suite(cases=args.cases, degrees=args.degrees,
                  include_bugs=args.bugs)
    result = suite.run(workers=args.workers, timeout_s=args.timeout,
                       cache=cache_from_args(args))
    print(result.to_markdown())
    if args.json:
        result.write(args.json)
        print(f"[suite] wrote {args.json}", file=sys.stderr)
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(result.to_markdown() + "\n")
    if args.write_golden:
        with open(args.write_golden, "w") as f:
            json.dump(result.stable_summary(), f, indent=2, sort_keys=True)
        print(f"[suite] wrote golden {args.write_golden}", file=sys.stderr)
    rc = 0 if result.ok else 1
    if args.check:
        with open(args.check) as f:
            golden = json.load(f)
        got = result.stable_summary()
        if got != golden:
            missing = sorted(set(golden) - set(got))
            extra = sorted(set(got) - set(golden))
            changed = sorted(k for k in set(got) & set(golden)
                             if got[k] != golden[k])
            print(f"[suite] GOLDEN MISMATCH vs {args.check}: "
                  f"missing={missing} extra={extra} changed={changed}",
                  file=sys.stderr)
            for k in changed:
                print(f"  {k}:\n    golden: {golden[k]}\n    got:    {got[k]}",
                      file=sys.stderr)
            rc = 1
        else:
            print(f"[suite] matches golden {args.check}", file=sys.stderr)
    return rc

"""Strategy registry: ``@register_strategy`` and spec construction.

The registry is the single source of truth for the verification case
matrix.  ``repro.dist.strategies`` populates it at import time; third-party
code can add cases the same way without touching core:

    from repro.api import register_strategy, BugSpec

    @register_strategy("my_case", bugs=[BugSpec("my_bug", "refinement_error")])
    def my_case(degree=2, bug=None):
        ...
        return StrategySpec(seq_fn, dist_fn, axes, specs, avals, names)

A registered builder returns a raw ``StrategySpec`` (or, for legacy code,
the old 6-tuple — it is normalized); the decorator wrapper stamps the
case name, degree, bug, and expectation metadata onto the spec and guards
against running a bug under the wrong host case (which would silently
verify the clean graph).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

from .spec import BugSpec, Degree, StrategySpec, normalize_degree


@dataclass(frozen=True)
class RegisteredStrategy:
    """Registry entry: builder + task metadata for one strategy case."""
    name: str
    builder: Callable                    # (degree=, bug=, **kw) -> StrategySpec
    bugs: Tuple[BugSpec, ...]
    degrees: Tuple[Degree, ...]          # degrees the suite sweeps by default
                                         # (ints, or per-mesh-axis tuples)
    expected: str                        # clean-run expectation
    description: str = ""

    def bug_names(self) -> Tuple[str, ...]:
        return tuple(b.name for b in self.bugs)

    def validate_degree(self, degree: Degree) -> Degree:
        """Reject per-axis tuple degrees a case cannot take.

        The registered default ``degrees`` carry the case's shape: a case
        whose defaults are all ints is single-axis (its builder does int
        arithmetic on ``degree`` and would die with an opaque TypeError on
        a tuple); a multi-axis case declares tuple defaults whose arity a
        tuple override must match.  Scalars are always fine — multi-axis
        builders broadcast them over the mesh (``axis_degrees``).
        """
        degree = normalize_degree(degree)
        if isinstance(degree, tuple):
            arities = {len(d) for d in self.degrees if isinstance(d, tuple)}
            if not arities:
                raise ValueError(
                    f"case `{self.name}` is single-axis — it takes an int "
                    f"degree, not the per-axis tuple {degree}")
            if len(degree) not in arities:
                raise ValueError(
                    f"case `{self.name}` takes {sorted(arities)}-axis "
                    f"degrees, got {degree}")
        return degree

    def bug_spec(self, bug: str) -> BugSpec:
        for b in self.bugs:
            if b.name == bug:
                return b
        raise KeyError(bug)


_REGISTRY: Dict[str, RegisteredStrategy] = {}


class DuplicateStrategyError(ValueError):
    """A strategy (or one of its bug names) is already registered."""


def register_strategy(name: str, *, bugs=(),
                      degrees: Tuple[Degree, ...] = (2, 4),
                      expected: str = "certificate", description: str = ""):
    """Class-of-2025 entry point: register a strategy builder under ``name``.

    ``bugs`` is a sequence of ``BugSpec`` (or plain bug-name strings, which
    default to ``expected="refinement_error"``).  ``expected`` states what
    the *clean* run should produce ("certificate", or "incomplete" for the
    documented completeness gaps).  ``degrees`` entries are ints or, for a
    multi-axis mesh, per-axis tuples like ``(4, 2)``.  The decorated
    function must accept ``degree=`` and ``bug=`` keywords and return a
    ``StrategySpec`` (the legacy 6-tuple is accepted and normalized).
    """
    bug_specs = tuple(b if isinstance(b, BugSpec) else BugSpec(str(b))
                      for b in bugs)
    if expected not in ("certificate", "incomplete"):
        raise ValueError(f"clean expectation must be certificate or "
                         f"incomplete, got {expected!r}")

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise DuplicateStrategyError(
                f"strategy `{name}` is already registered "
                f"(by {_REGISTRY[name].builder.__module__})")
        for entry in _REGISTRY.values():
            taken = set(entry.bug_names()) & {b.name for b in bug_specs}
            if taken:
                # a shadowed bug name would re-host the bug and defeat the
                # wrong-host guard, silently verifying the clean graph
                raise DuplicateStrategyError(
                    f"bug name(s) {sorted(taken)} already registered under "
                    f"case `{entry.name}`")

        def build(degree: Degree = 2, bug: Optional[str] = None, **kw):
            degree = _REGISTRY[name].validate_degree(degree)
            if bug is not None and bug not in {b.name for b in bug_specs}:
                hosts = [entry.name for entry in _REGISTRY.values()
                         if bug in entry.bug_names()]
                raise ValueError(
                    f"bug `{bug}` belongs to case {hosts or '?'} — running "
                    f"it under `{name}` would silently verify the clean "
                    f"graph")
            raw = fn(degree=degree, bug=bug, **kw)
            if not isinstance(raw, StrategySpec):
                seq_fn, dist_fn, axes, specs, avals, names = raw
                raw = StrategySpec(seq_fn, dist_fn, axes, tuple(specs),
                                   tuple(avals), tuple(names))
            exp = expected if bug is None else \
                next(b.expected for b in bug_specs if b.name == bug)
            return raw.with_identity(
                name=name, degree=degree, bug=bug, expected=exp,
                description=description or (fn.__doc__ or "").strip())

        build.__name__ = fn.__name__
        build.__doc__ = fn.__doc__
        build.__wrapped__ = fn
        _REGISTRY[name] = RegisteredStrategy(
            name=name, builder=build, bugs=bug_specs,
            degrees=tuple(normalize_degree(d) for d in degrees),
            expected=expected,
            description=description or (fn.__doc__ or "").strip().split("\n")[0])
        return build

    return deco


# ---------------------------------------------------------------------------
# lookups
# ---------------------------------------------------------------------------

def _ensure_populated() -> None:
    """Strategies self-register on import; make lookups lazy-import them."""
    if not _REGISTRY:
        from ..dist import strategies  # noqa: F401  (import side effect)


def get_strategy(name: str) -> RegisteredStrategy:
    """Look up a registered strategy; KeyError names the known set."""
    _ensure_populated()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown strategy `{name}` — registered: "
                       f"{sorted(_REGISTRY)}") from None


def list_strategies() -> Tuple[str, ...]:
    """Registered case names, in registration order."""
    _ensure_populated()
    return tuple(_REGISTRY)


def list_bugs() -> Dict[str, Tuple[str, BugSpec]]:
    """bug name -> (host case name, BugSpec)."""
    _ensure_populated()
    out: Dict[str, Tuple[str, BugSpec]] = {}
    for entry in _REGISTRY.values():
        for b in entry.bugs:
            out[b.name] = (entry.name, b)
    return out


def bug_host(bug: str) -> str:
    """The case name hosting ``bug``; KeyError names the known bugs."""
    try:
        return list_bugs()[bug][0]
    except KeyError:
        raise KeyError(f"unknown bug `{bug}` — registered: "
                       f"{sorted(list_bugs())}") from None


def build_spec(name: str, *, degree: Degree = 2, bug: Optional[str] = None,
               **kw) -> StrategySpec:
    """Materialize one verification task from the registry.

    Raises ``KeyError`` for an unknown case and ``ValueError`` when ``bug``
    is hosted by a different case (the wrong-host guard).
    """
    return get_strategy(name).builder(degree=degree, bug=bug, **kw)


# ---------------------------------------------------------------------------
# model-level tasks (repro.modelcheck)
# ---------------------------------------------------------------------------
# Whole-model verification tasks live beside the strategy registry under
# ``model@plan`` ids (e.g. ``gpt@dp2xtp2``).  They are resolved lazily so
# importing ``repro.api`` does not pull the model zoo in.

def list_model_tasks() -> Tuple[str, ...]:
    """``model@plan`` ids: every decomposable model x default mesh plan."""
    from ..modelcheck import supported_models
    from ..sharding.specs import DEFAULT_PLANS
    return tuple(f"{m}@{p}" for m in supported_models()
                 for p in DEFAULT_PLANS)


def check_model_task(task: str, **kw):
    """Run one ``model@plan`` whole-model task -> ``ModelReport``.

    Keyword arguments pass through to
    :func:`repro.modelcheck.check_model` (``bug=``, ``bug_layer=``,
    ``workers=``, ``engine_opts=``, ...).
    """
    model, sep, plan = str(task).partition("@")
    if not sep or not model or not plan:
        raise KeyError(f"bad model task `{task}` — expected `model@plan` "
                       f"like `gpt@dp2xtp2`")
    from ..modelcheck import check_model
    return check_model(model, plan, **kw)


# ---------------------------------------------------------------------------
# train-step tasks (repro.gradcheck)
# ---------------------------------------------------------------------------
# Training-step verification tasks live beside the case and ``model@plan``
# registries under ``train@strategy`` ids (e.g. ``train@dp_accum``) —
# resolved lazily so importing ``repro.api`` does not pull gradcheck in.

def list_train_tasks() -> Tuple[str, ...]:
    """``train@strategy`` ids: every registered train-step strategy."""
    from ..gradcheck import list_train_strategies
    return tuple(f"train@{s}" for s in list_train_strategies())


def check_train_task(task: str, **kw):
    """Run one ``train@strategy`` train-step task -> ``TrainReport``.

    Keyword arguments pass through to
    :func:`repro.gradcheck.check_train` (``degree=``, ``bug=``,
    ``workers=``, ``engine_opts=``, ...).
    """
    prefix, sep, strategy = str(task).partition("@")
    if not sep or prefix != "train" or not strategy:
        raise KeyError(f"bad train task `{task}` — expected "
                       f"`train@strategy` like `train@dp_accum`")
    from ..gradcheck import check_train
    return check_train(strategy, **kw)


# ---------------------------------------------------------------------------
# serving-path tasks (repro.servecheck)
# ---------------------------------------------------------------------------
# Serving-path verification tasks live beside the case, ``model@plan`` and
# ``train@strategy`` registries under ``serve@strategy`` ids (e.g.
# ``serve@tp_decode``) — resolved lazily so importing ``repro.api`` does
# not pull servecheck in.

def list_serve_tasks() -> Tuple[str, ...]:
    """``serve@strategy`` ids: every registered serving strategy."""
    from ..servecheck import list_serve_strategies
    return tuple(f"serve@{s}" for s in list_serve_strategies())


def check_serve_task(task: str, **kw):
    """Run one ``serve@strategy`` serving-path task -> ``ServeReport``.

    Keyword arguments pass through to
    :func:`repro.servecheck.check_serve` (``degree=``, ``bug=``,
    ``workers=``, ``engine_opts=``, ...).
    """
    prefix, sep, strategy = str(task).partition("@")
    if not sep or prefix != "serve" or not strategy:
        raise KeyError(f"bad serve task `{task}` — expected "
                       f"`serve@strategy` like `serve@tp_decode`")
    from ..servecheck import check_serve
    return check_serve(strategy, **kw)

"""``python -m repro.api`` — the suite-runner CLI.

(Entry point lives here rather than in ``suite.py`` so the package
``__init__``'s eager ``.suite`` import and runpy never double-execute the
module.)
"""
import sys

from .suite import main

sys.exit(main())

"""``verify()`` — the library entry point for one verification task.

    from repro.api import verify
    report = verify("tp_layer", degree=4)            # -> Report
    report = verify("sp_rope", bug="rope_offset")    # verdict=refinement_error

Accepts a registered case name or an already-built ``StrategySpec``.
``engine_opts`` tunes the engine per call without touching process-global
state afterwards:

    max_nodes       e-graph node budget (default 400_000)
    optimizations   None (leave the process setting), bool (all flags), or
                    a {flag: bool} dict of ``repro.core.profile.OptConfig``
                    overrides — restored after the call either way
    explain         record proof provenance (lemma chains / failure
                    frontiers, see ``repro.core.explain``); None defers to
                    the ``GRAPHGUARD_EXPLAIN`` environment default

``run_spec()`` is the raising flavour (returns the live ``Certificate`` or
raises ``RefinementError``/``CaptureError``) used by the back-compat CLI
shim; ``verify()`` wraps it into a structured :class:`~repro.api.Report`.
"""
from __future__ import annotations

import time
from typing import Optional, Union

from ..core import (Certificate, RefinementError, capture, capture_spmd,
                    check_refinement, expand_spmd)
from ..core.profile import CONFIG, set_optimizations
from ..obs import trace as obs_trace
from .registry import build_spec
from .report import Report
from .spec import StrategySpec

DEFAULT_MAX_NODES = 400_000


def _resolve(spec_or_name: Union[str, StrategySpec], degree: Optional[int],
             bug: Optional[str]) -> StrategySpec:
    if isinstance(spec_or_name, StrategySpec):
        if degree is not None or bug is not None:
            raise ValueError(
                "degree=/bug= only apply when verifying by name; this "
                "StrategySpec is already built for "
                f"degree={spec_or_name.degree}, bug={spec_or_name.bug!r} — "
                "use dataclasses.replace / build_spec to change it")
        return spec_or_name
    return build_spec(spec_or_name, degree=2 if degree is None else degree,
                      bug=bug)


class _engine_opts:
    """Apply {max_nodes, optimizations} for the duration of one call."""

    def __init__(self, opts: Optional[dict]):
        opts = dict(opts or {})
        self.max_nodes = opts.pop("max_nodes", DEFAULT_MAX_NODES)
        self.optimizations = opts.pop("optimizations", None)
        self.explain = opts.pop("explain", None)
        if opts:
            raise ValueError(f"unknown engine_opts: {sorted(opts)}")
        if isinstance(self.optimizations, dict):
            unknown = set(self.optimizations) - set(CONFIG.as_dict())
            if unknown:
                raise ValueError(
                    f"unknown optimization flags: {sorted(unknown)} "
                    f"(valid: {sorted(CONFIG.as_dict())})")
        self._saved = None

    def __enter__(self):
        if self.optimizations is not None:
            self._saved = CONFIG.as_dict()
            if isinstance(self.optimizations, dict):
                set_optimizations(True, **{**self._saved,
                                           **self.optimizations})
            else:
                set_optimizations(bool(self.optimizations))
        return self

    def __exit__(self, *exc):
        if self._saved is not None:
            set_optimizations(True, **self._saved)
        return False


def run_spec(spec: StrategySpec, *, engine_opts: Optional[dict] = None
             ) -> Certificate:
    """Capture G_s/G_d, derive R_i, and run relation inference (raising)."""
    if not isinstance(engine_opts, _engine_opts):
        engine_opts = _engine_opts(engine_opts)
    with engine_opts as eo:
        with obs_trace.span("capture", cat="capture", graph="gs",
                            case=spec.name):
            gs = capture(spec.seq_fn, list(spec.avals),
                         list(spec.input_names))
        with obs_trace.span("capture", cat="capture", graph="gd",
                            case=spec.name):
            cap = capture_spmd(spec.dist_fn, spec.mesh_axes,
                               list(spec.in_specs), list(spec.avals),
                               list(spec.input_names))
            gd, r_i = expand_spmd(cap)
        with obs_trace.span("infer", cat="engine", case=spec.name):
            return check_refinement(gs, gd, r_i, max_nodes=eo.max_nodes,
                                    explain=eo.explain)


def verify(spec_or_name: Union[str, StrategySpec], *,
           degree: Optional[int] = None, bug: Optional[str] = None,
           engine_opts: Optional[dict] = None) -> Report:
    """Verify one task and return a structured :class:`Report`.

    ``degree`` (default 2) and ``bug`` select the task when verifying by
    name; passing them alongside an already-built ``StrategySpec`` raises
    rather than silently ignoring them.  Unknown case/bug names and the
    bug-under-wrong-case guard also raise (``KeyError``/``ValueError``):
    those are caller mistakes, not verification outcomes.  Engine-side
    failures become verdicts.
    """
    spec = _resolve(spec_or_name, degree, bug)
    engine_opts = _engine_opts(engine_opts)   # caller mistakes raise here
    t0 = time.perf_counter()
    try:
        cert = run_spec(spec, engine_opts=engine_opts)
    except RefinementError as e:
        verdict, payload = "refinement_error", e.payload()
        return Report(
            case=spec.name, degree=spec.degree, bug=spec.bug,
            verdict=verdict, expected=spec.expected,
            ok=spec.expected_verdict == verdict, localization=payload,
            explanation=getattr(e, "explanation", None),
            wall_s=round(time.perf_counter() - t0, 6))
    except Exception as e:  # noqa: BLE001 — CaptureError/engine -> verdict
        return Report(
            case=spec.name, degree=spec.degree, bug=spec.bug,
            verdict="error", expected=spec.expected, ok=False,
            error=f"{type(e).__name__}: {e}",
            wall_s=round(time.perf_counter() - t0, 6))
    cert_json = cert.to_json()
    return Report(
        case=spec.name, degree=spec.degree, bug=spec.bug,
        verdict="certificate", expected=spec.expected,
        ok=spec.expected_verdict == "certificate",
        r_o=cert_json["r_o"], stats=cert_json["stats"], certificate=cert,
        explanation=cert.explanation,
        wall_s=round(time.perf_counter() - t0, 6))

"""Crash-safe persistent certificate cache (ROADMAP item 4).

``modelcheck`` already dedups obligations by ``canonical_key`` *within* a
run; this module makes that cache persist *across* runs, so re-verifying a
61-layer model after a one-block edit re-proves one obligation — not
three — and an interrupted run resumes from its last committed entry.

Storage model — append-only journal with atomic-rename commits:

* One directory per cache (``CertificateCache(path)``), holding
  ``meta.json`` (schema + engine fingerprint, written via temp-file +
  ``os.replace`` so it is never observed half-written) and
  ``journal.jsonl``.
* Each ``put`` appends one line — ``<sha256-prefix> <json payload>`` —
  then flushes and fsyncs.  An entry is *committed* once its line is
  fully on disk; a crash mid-append leaves at most one torn tail line.
* Recovery is corruption-tolerant by construction: a line that fails the
  checksum or does not parse is counted and *skipped* — the obligation is
  simply re-proved and re-committed.  Corruption is never fatal.
* ``compact()`` rewrites the journal (last write per key wins, corrupt
  lines dropped) through a temp file + atomic ``os.replace``.
* A cache written by a different engine (any source change under the
  fingerprinted subpackages) is invalidated wholesale on open: the stale
  journal is rotated aside, never reinterpreted.

Keys are *content-addressed*: ``modelcheck`` keys by
``obligations.canonical_key`` (structure + shapes + dtypes + specs +
mesh), the suite and ``gradcheck`` by :func:`strategy_cache_key` over the
same vocabulary, and every key embeds the engine-side knobs that can
change an outcome (``max_nodes``).  Only deterministic verdicts
(``certificate`` / ``refinement_error``) are ever stored — ``error`` and
``timeout`` reflect the environment, not the obligation.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from functools import lru_cache
from typing import Any, Dict, Optional, Union

from ..obs import trace as obs_trace
from ..obs.metrics import REGISTRY
from . import chaos

CACHE_SCHEMA = 1

# default location; overridable per call and via the environment
ENV_CACHE_DIR = "GRAPHGUARD_CACHE_DIR"
DEFAULT_CACHE_DIR = ".graphguard_cache"

# verdicts that are a function of the obligation (cacheable) rather than
# of the machine the run happened to land on (never cached)
DETERMINISTIC_VERDICTS = ("certificate", "refinement_error")


# ---------------------------------------------------------------------------
# content-addressed keys
# ---------------------------------------------------------------------------

def spec_token(spec) -> str:
    """Stable string form of a PartitionSpec (or None)."""
    if spec is None:
        return "-"
    entries = []
    for e in tuple(spec):
        if e is None:
            entries.append("_")
        elif isinstance(e, tuple):
            entries.append("(" + "+".join(map(str, e)) + ")")
        else:
            entries.append(str(e))
    return "P[" + ",".join(entries) + "]"


def aval_token(aval) -> str:
    return f"{tuple(aval.shape)}:{aval.dtype}"


@lru_cache(maxsize=1)
def engine_fingerprint() -> str:
    """Hash of every source file whose semantics a cached certificate
    depends on: the engine, the strategy/model/obligation builders, and
    the task model.  Any edit invalidates the cache wholesale — the
    conservative choice; *content* keys handle the common fast path of
    unchanged code + edited model."""
    pkg = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subdirs = ("core", "dist", "models", "sharding", "modelcheck",
               "gradcheck", "servecheck", "optim")
    files = [os.path.join(pkg, "api", "spec.py"),
             os.path.join(pkg, "api", "runner.py")]
    for sub in subdirs:
        root = os.path.join(pkg, sub)
        if not os.path.isdir(root):
            continue
        for dirpath, _, names in os.walk(root):
            files.extend(os.path.join(dirpath, n)
                         for n in names if n.endswith(".py"))
    h = hashlib.sha256()
    for path in sorted(files):
        h.update(path[len(pkg):].encode())
        try:
            with open(path, "rb") as f:
                h.update(f.read())
        except OSError:
            h.update(b"<unreadable>")
    return h.hexdigest()[:16]


def _engine_token(engine_opts: Optional[dict]) -> str:
    # max_nodes bounds the e-graph and can truncate a proof; optimization
    # flags are certified byte-identical (tests/test_api.py) and excluded.
    # explain changes the report payload (lemma chains attached), so
    # explain-on runs must not be served explain-off cache entries — the
    # env-ambient default counts too, not just the explicit option
    from ..api.runner import DEFAULT_MAX_NODES
    from ..core.profile import explain_enabled
    tok = f"mn{(engine_opts or {}).get('max_nodes', DEFAULT_MAX_NODES)}"
    if explain_enabled((engine_opts or {}).get("explain")):
        tok += ":xp"
    return tok


def obligation_cache_key(canonical: str,
                         engine_opts: Optional[dict] = None) -> str:
    """Cache key for a modelcheck obligation (already content-addressed
    by ``modelcheck.obligations.canonical_key``)."""
    return f"ob:{canonical}:{_engine_token(engine_opts)}"


def strategy_cache_key(spec, engine_opts: Optional[dict] = None) -> str:
    """Content-addressed key for a :class:`repro.api.StrategySpec` —
    the suite / gradcheck analogue of ``canonical_key``: mesh + shapes +
    dtypes + input specs + task identity, hashed short."""
    mesh = dict(spec.mesh_axes) if not isinstance(spec.mesh_axes, dict) \
        else spec.mesh_axes
    parts = [
        "name=" + spec.name,
        "bug=" + (spec.bug or "-"),
        "mesh=" + ",".join(f"{a}{s}" for a, s in mesh.items()),
        "in=" + ";".join(f"{n}:{aval_token(a)}:{spec_token(s)}"
                         for n, a, s in zip(spec.input_names, spec.avals,
                                            spec.in_specs)),
    ]
    digest = hashlib.sha256("|".join(parts).encode()).hexdigest()[:12]
    return f"spec:{spec.name}-{digest}:{_engine_token(engine_opts)}"


def serve_cache_key(strategy: str, canonical: str,
                    engine_opts: Optional[dict] = None) -> str:
    """Cache key for a servecheck obligation: the strategy name plus the
    obligation's content digest (``modelcheck.obligations.canonical_key``
    already hashes mesh + shapes + specs + structure facts, including the
    position class and any injected bug)."""
    digest = canonical.rsplit("-", 1)[-1]
    return f"serve:{strategy}-{digest}:{_engine_token(engine_opts)}"


def cacheable_report(value: Any) -> bool:
    """Default commit policy: only deterministic verdicts persist."""
    return (isinstance(value, dict)
            and value.get("verdict") in DETERMINISTIC_VERDICTS)


# ---------------------------------------------------------------------------
# the cache
# ---------------------------------------------------------------------------

_DIGEST_LEN = 16


def _line_for(key: str, value: dict) -> bytes:
    payload = json.dumps({"k": key, "v": value}, sort_keys=True,
                         separators=(",", ":"))
    digest = hashlib.sha256(payload.encode()).hexdigest()[:_DIGEST_LEN]
    return f"{digest} {payload}\n".encode()


def _parse_line(raw: bytes) -> Optional[Dict[str, Any]]:
    """Decode one journal line; None for torn/garbage/corrupt lines."""
    try:
        text = raw.decode()
    except UnicodeDecodeError:
        return None
    digest, sep, payload = text.rstrip("\n").partition(" ")
    if not sep or len(digest) != _DIGEST_LEN:
        return None
    if hashlib.sha256(payload.encode()).hexdigest()[:_DIGEST_LEN] != digest:
        return None
    try:
        entry = json.loads(payload)
    except json.JSONDecodeError:
        return None
    if not isinstance(entry, dict) or "k" not in entry or "v" not in entry:
        return None
    return entry


class CertificateCache:
    """Persistent content-addressed report cache over an append-only
    journal.  Safe against crashes of the *writer* (torn tail line) and
    against arbitrary corruption of the *file* (bad lines are skipped and
    re-proved); not designed for concurrent writers."""

    def __init__(self, path: Union[str, os.PathLike]):
        self.dir = os.fspath(path)
        os.makedirs(self.dir, exist_ok=True)
        self.journal_path = os.path.join(self.dir, "journal.jsonl")
        self._mem: Dict[str, dict] = {}
        self.hits = 0
        self.misses = 0
        self.recovered_corrupt = 0       # bad lines skipped during load
        self._check_meta()
        self._load()

    # -- fingerprint gate ---------------------------------------------------
    def _check_meta(self) -> None:
        meta_path = os.path.join(self.dir, "meta.json")
        want = {"schema": CACHE_SCHEMA, "engine": engine_fingerprint()}
        try:
            with open(meta_path) as f:
                have = json.load(f)
        except (OSError, json.JSONDecodeError):
            have = None
        if have != want:
            # stale or foreign cache: rotate the journal aside rather than
            # reinterpret entries proved by a different engine
            if os.path.exists(self.journal_path) \
                    and os.path.getsize(self.journal_path):
                os.replace(self.journal_path, self.journal_path + ".stale")
            fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(want, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, meta_path)   # atomic-rename commit

    # -- journal ------------------------------------------------------------
    def _load(self) -> None:
        if not os.path.exists(self.journal_path):
            return
        with open(self.journal_path, "rb") as f:
            for raw in f:
                entry = _parse_line(raw)
                if entry is None:
                    self.recovered_corrupt += 1
                    continue
                self._mem[entry["k"]] = entry["v"]

    def get(self, key: str) -> Optional[dict]:
        v = self._mem.get(key)
        result = "miss" if v is None else "hit"
        obs_trace.event("cache.probe", cat="cache", key=key.split(":", 1)[0],
                        digest=key[-12:], result=result)
        REGISTRY.counter("cache.hits" if v is not None
                         else "cache.misses").inc()
        if v is None:
            self.misses += 1
            return None
        self.hits += 1
        return json.loads(json.dumps(v))     # defensive copy

    def put(self, key: str, value: dict) -> None:
        """Commit one entry: append + flush + fsync.  The entry is durable
        (and will be resumed from) once this returns."""
        obs_trace.event("cache.commit", cat="cache",
                        key=key.split(":", 1)[0], digest=key[-12:])
        REGISTRY.counter("cache.commits").inc()
        line = _line_for(key, value)
        with open(self.journal_path, "ab") as f:
            offset = f.tell()
            f.write(line)
            f.flush()
            os.fsync(f.fileno())
        self._mem[key] = value
        if chaos.corrupt_cache_entry(key):
            self._corrupt_at(offset, len(line))

    def _corrupt_at(self, offset: int, length: int) -> None:
        """Chaos hook: flip one byte inside the just-committed payload
        (simulating a torn write / bit rot the next load must survive)."""
        with open(self.journal_path, "r+b") as f:
            f.seek(offset + min(_DIGEST_LEN + 2, length - 2))
            b = f.read(1)
            f.seek(-1, os.SEEK_CUR)
            f.write(bytes([b[0] ^ 0xFF]))
            f.flush()
            os.fsync(f.fileno())

    def compact(self) -> None:
        """Rewrite the journal (one line per live key, corruption dropped)
        via temp file + atomic ``os.replace``."""
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        with os.fdopen(fd, "wb") as f:
            for key in sorted(self._mem):
                f.write(_line_for(key, self._mem[key]))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.journal_path)

    # -- views --------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._mem)

    def __contains__(self, key: str) -> bool:
        return key in self._mem

    def stats(self) -> dict:
        return {
            "dir": self.dir,
            "entries": len(self._mem),
            "hits": self.hits,
            "misses": self.misses,
            "recovered_corrupt": self.recovered_corrupt,
        }


def resolve_cache(cache: Union[None, bool, str, os.PathLike,
                               CertificateCache]
                  ) -> Optional[CertificateCache]:
    """Normalize the ``cache=`` argument the schedulers accept.

    ``None`` consults ``$GRAPHGUARD_CACHE_DIR`` (set → cache on at that
    path; unset → no cache), ``False`` disables explicitly, ``True``
    uses the default location, a path opens that directory, and an
    existing :class:`CertificateCache` passes through.
    """
    if cache is False:
        return None
    if cache is None:
        env = os.environ.get(ENV_CACHE_DIR)
        return CertificateCache(env) if env else None
    if cache is True:
        return CertificateCache(DEFAULT_CACHE_DIR)
    if isinstance(cache, CertificateCache):
        return cache
    return CertificateCache(cache)

"""Fault injection for the verification runtime (``GRAPHGUARD_CHAOS``).

The chaos harness is how we *prove* the runtime's fault tolerance instead
of asserting it: an env-gated hook makes pool workers segfault, exit, or
sleep forever, and flips bytes in the persistent certificate cache as
entries are committed — all driven from tests and ``make chaos-smoke``.

Configuration (all via environment, so child processes inherit it):

    GRAPHGUARD_CHAOS=crash:0.3,hang:0.1,corrupt_cache:1
        comma-separated ``mode:probability`` pairs.  Modes:
          crash          worker raises SIGSEGV against itself (segfault)
          exit           worker hard-exits (``os._exit``) mid-task
          hang           worker sleeps "forever" (heartbeats keep beating,
                         so this exercises deadline — not liveness —
                         detection)
          corrupt_cache  the just-committed cache journal entry has one
                         payload byte flipped (a torn/garbage entry the
                         next run must skip and re-prove)
    GRAPHGUARD_CHAOS_TARGET=substr
        only afflict tasks/cache keys containing ``substr`` (empty/unset:
        every key is eligible)
    GRAPHGUARD_CHAOS_SEED=int
        seed for the deterministic per-(mode, key, attempt) draw
        (default 0)

Draws are *deterministic*: ``sha256(seed:mode:key:attempt)`` mapped to
[0, 1) and compared against the configured probability.  A probability of
1 therefore means "this key fails on every attempt" (how tests pin a
persistent fault to one task), while 0.3 means ~30% of attempts fail —
and a retry of the same key draws fresh randomness via its attempt
number.

Worker-side faults (`crash`/`exit`/`hang`) only ever fire inside a pool
worker (the shim calls :func:`enter_worker` first); the in-process
degradation path must stay safe — a segfault there would take down the
caller, which is exactly what the runtime exists to prevent.
"""
from __future__ import annotations

import hashlib
import os
import signal
import time
from dataclasses import dataclass, field
from typing import Dict, Optional

from ..obs import trace as obs_trace
from ..obs.metrics import REGISTRY

ENV_SPEC = "GRAPHGUARD_CHAOS"
ENV_TARGET = "GRAPHGUARD_CHAOS_TARGET"
ENV_SEED = "GRAPHGUARD_CHAOS_SEED"

MODES = ("crash", "exit", "hang", "corrupt_cache")

# how long an injected hang sleeps — far beyond any per-task budget, so
# the supervisor's deadline (not this constant) decides when it surfaces
HANG_S = 3600.0

# set by the pool worker shim; guards the process-killing fault modes
_IN_WORKER = False


@dataclass(frozen=True)
class ChaosConfig:
    """Parsed ``GRAPHGUARD_CHAOS`` spec."""
    probabilities: Dict[str, float] = field(default_factory=dict)
    target: str = ""
    seed: int = 0

    def p(self, mode: str) -> float:
        return self.probabilities.get(mode, 0.0)


def parse_spec(spec: str, target: str = "", seed: int = 0) -> ChaosConfig:
    """Parse ``crash:0.3,hang:0.1`` into a :class:`ChaosConfig` (raising
    on unknown modes / unparsable probabilities — a typo'd chaos spec
    silently injecting nothing would defeat the harness)."""
    probs: Dict[str, float] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        mode, sep, p = part.partition(":")
        if not sep:
            raise ValueError(f"chaos spec entry `{part}` is not mode:prob")
        if mode not in MODES:
            raise ValueError(f"unknown chaos mode `{mode}` "
                             f"(valid: {', '.join(MODES)})")
        prob = float(p)
        if not 0.0 <= prob <= 1.0:
            raise ValueError(f"chaos probability for `{mode}` must be in "
                             f"[0, 1], got {prob}")
        probs[mode] = prob
    return ChaosConfig(probabilities=probs, target=target, seed=seed)


def load_config() -> Optional[ChaosConfig]:
    """The active chaos config, or None when ``GRAPHGUARD_CHAOS`` is unset.

    Read fresh on every call (not cached): tests and the smoke driver flip
    the env var between runs within one process, and pool workers inherit
    whatever was set when they were spawned.
    """
    spec = os.environ.get(ENV_SPEC)
    if not spec:
        return None
    return parse_spec(spec, target=os.environ.get(ENV_TARGET, ""),
                      seed=int(os.environ.get(ENV_SEED, "0")))


def _draw(cfg: ChaosConfig, mode: str, key: str, attempt: int) -> float:
    h = hashlib.sha256(
        f"{cfg.seed}:{mode}:{key}:{attempt}".encode()).digest()
    return int.from_bytes(h[:8], "big") / 2**64


def should(mode: str, key: str, attempt: int = 0,
           cfg: Optional[ChaosConfig] = None) -> bool:
    """Deterministic: does chaos afflict (mode, key, attempt)?"""
    cfg = cfg if cfg is not None else load_config()
    if cfg is None:
        return False
    p = cfg.p(mode)
    if p <= 0.0:
        return False
    if cfg.target and cfg.target not in key:
        return False
    return p >= 1.0 or _draw(cfg, mode, key, attempt) < p


def enter_worker() -> None:
    """Mark this process as a pool worker (called by the worker shim);
    only then may :func:`maybe_fault` kill or wedge the process."""
    global _IN_WORKER
    _IN_WORKER = True


def maybe_fault(key: str, attempt: int = 0) -> None:
    """Inject a worker-side fault for (key, attempt) if chaos says so.

    ``crash`` delivers SIGSEGV to the worker itself (the classic silent
    killer from the distributed-DL bug studies), ``exit`` hard-exits
    without cleanup, ``hang`` sleeps far past any budget.  No-op outside
    a pool worker or when ``GRAPHGUARD_CHAOS`` is unset.
    """
    if not _IN_WORKER:
        return
    cfg = load_config()
    if cfg is None:
        return
    if should("crash", key, attempt, cfg):
        _note_injection("crash", key, attempt)
        signal.signal(signal.SIGSEGV, signal.SIG_DFL)
        os.kill(os.getpid(), signal.SIGSEGV)
        time.sleep(HANG_S)               # pragma: no cover — never reached
    if should("exit", key, attempt, cfg):
        _note_injection("exit", key, attempt)
        os._exit(3)
    if should("hang", key, attempt, cfg):
        _note_injection("hang", key, attempt)
        time.sleep(HANG_S)


def _note_injection(mode: str, key: str, attempt: int) -> None:
    """Record the injection on the local tracer/registry.  Worker-side
    kill modes usually take the tracer down with the process — the
    supervisor's fault events are what make those visible in the merged
    trace — but ``hang`` (and any future soft mode) is captured here."""
    obs_trace.event(f"chaos.{mode}", cat="fault", key=key, attempt=attempt)
    REGISTRY.counter("chaos.injected").inc()


def corrupt_cache_entry(key: str) -> bool:
    """Should the cache flip a byte in the entry just committed for
    ``key``?  (Cache corruption is a *storage* fault, so unlike the
    worker faults it may fire in any process.)"""
    hit = should("corrupt_cache", key)
    if hit:
        obs_trace.event("chaos.corrupt_cache", cat="fault",
                        key=key.split(":", 1)[0], digest=key[-12:])
        REGISTRY.counter("chaos.injected").inc()
    return hit

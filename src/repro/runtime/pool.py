"""Supervised worker pool — the shared fault-tolerant execution layer.

One scheduler to replace the three copy-pasted pool loops that grew in
``api/suite.py``, ``modelcheck/schedule.py`` and ``gradcheck/schedule.py``.
Callers describe work as :class:`RuntimeTask`\\ s (a picklable module-level
``fn`` + args, a stable key, a per-task wall-clock budget, optionally a
content-addressed cache key and an in-process fallback closure) and get
back one :class:`TaskOutcome` per key.  The pool guarantees:

* **Per-task hard deadlines** — each task's budget starts ticking when the
  task *starts on a worker* (tracked by heartbeats), not when it is
  submitted, so one slow obligation can never starve the budget of the
  tasks queued behind it.  A task past its deadline is reported as
  ``timeout`` with its measured elapsed time and heartbeat liveness
  ("worker alive — task over budget" vs "no heartbeat — worker hung");
  the wedged worker is killed with its pool and the survivors resume on a
  replacement pool.
* **Crash containment with exact blame** — a worker death
  (``BrokenProcessPool``: segfault, hard exit, OOM-kill) re-runs every
  unfinished task, but tasks that were *running* at crash time are
  quarantined onto a fresh single-worker pool one at a time with bounded
  retry + exponential backoff, so a poisonous task is blamed precisely
  (with the worker's exit cause in the error string) and an innocent
  bystander killed alongside it is never charged a retry.
* **Graceful degradation** — if a pool cannot be (re)created at all, the
  remaining tasks run in-process and every affected outcome carries a
  structured ``degraded_reason``.
* **Crash-safe persistence** — when a :class:`~.cache.CertificateCache`
  is attached, deterministic outcomes are committed as they arrive, so an
  interrupted run resumes from its last committed task.

Heartbeats ride a ``multiprocessing.Manager`` dict: the worker shim
records the task start and then beats from a daemon thread, which lets
the supervisor distinguish a *dead* worker (beats stopped) from a *hung*
one (beats continue, task over budget).  If the manager cannot start,
supervision degrades to submit-time budgets rather than failing.

Fault injection for all of the above lives in :mod:`repro.runtime.chaos`
and is exercised by ``make chaos-smoke`` and ``tests/test_runtime.py``.
"""
from __future__ import annotations

import multiprocessing
import signal
import threading
import time
from concurrent.futures import FIRST_COMPLETED, BrokenExecutor
from concurrent.futures import ProcessPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..obs import trace as obs_trace
from ..obs.metrics import REGISTRY
from . import chaos
from .cache import CertificateCache, cacheable_report

DEFAULT_MAX_RETRIES = 2
DEFAULT_BACKOFF_S = 0.1
DEFAULT_HEARTBEAT_S = 0.25
_POLL_S = 0.05


class PoolUnavailable(RuntimeError):
    """The process pool cannot be (re)created — degrade to in-process."""


@dataclass(frozen=True)
class RuntimeTask:
    """One schedulable unit of verification work."""
    key: str                             # stable id (attribution + chaos)
    fn: Callable                         # module-level picklable callable
    args: Tuple = ()                     # picklable arguments
    budget_s: float = 120.0              # per-task wall-clock budget
    cache_key: Optional[str] = None      # content-addressed cache identity
    local_fn: Optional[Callable] = None  # zero-arg in-process fallback
                                         # (may close over unpicklables)

    def run_local(self) -> Any:
        return self.local_fn() if self.local_fn is not None \
            else self.fn(*self.args)


@dataclass
class TaskOutcome:
    """What happened to one task, however it was executed."""
    key: str
    status: str                          # ok | timeout | error
    value: Any = None                    # fn's return (status == ok)
    error: Optional[str] = None          # cause (timeout/error statuses)
    wall_s: float = 0.0                  # on-worker elapsed (budget clock)
    queue_s: float = 0.0                 # waited behind pool siblings
    attempts: int = 1
    executor: str = "pool"               # pool | inline
    degraded_reason: Optional[str] = None
    cache: Optional[str] = None          # hit | miss | None (no cache)

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    def timing_info(self) -> dict:
        """Queue wait vs on-worker wall, reported separately — a task
        queued behind a slow sibling (large ``queue_s``) is a scheduling
        fact, a slow task (large ``run_s``) an engine fact.  Timing-class
        data: never part of reports' stable summaries."""
        return {"queue_s": round(self.queue_s, 6),
                "run_s": round(self.wall_s, 6)}

    def runtime_info(self) -> dict:
        """The non-trivial facts, for embedding in a Report (empty dict
        when the task ran the boring happy path)."""
        info: Dict[str, Any] = {}
        if self.cache is not None:
            info["cache"] = self.cache
        if self.attempts > 1:
            info["attempts"] = self.attempts
        if self.degraded_reason is not None:
            info["degraded_reason"] = self.degraded_reason
        # `executor` stays off the report: inline-by-request (workers<=1)
        # is not a runtime event, and inline-by-degradation already
        # carries degraded_reason — recording it would make reports
        # differ across worker counts for no informational gain
        return info


def _warm_worker() -> None:
    """Pool initializer: pay the per-process jax backend cost up front.

    jax drops its XLA client cache in forked children (and spawn starts
    cold), so the first jax op in a worker costs hundreds of ms.  Doing it
    in the initializer moves that cost off the first task's critical path
    and lets a reused pool serve later runs at steady-state speed.
    """
    import jax.numpy as jnp
    (jnp.zeros((1,)) + 1).block_until_ready()


def terminate_pool(pool: ProcessPoolExecutor) -> None:
    """Release a pool without blocking on wedged workers.

    ``shutdown(wait=True)`` would join a worker stuck in a hung task, so
    drop the executor handle and terminate the processes — idle workers
    die instantly, wedged ones get SIGTERM instead of leaking until their
    task (never) finishes.
    """
    procs = list(getattr(pool, "_processes", {}).values())
    pool.shutdown(wait=False, cancel_futures=True)
    for p in procs:
        if p.is_alive():
            p.terminate()


def _describe_exit(code: Optional[int]) -> str:
    if code is None:
        return "still exiting"
    if code < 0:
        try:
            return f"killed by {signal.Signals(-code).name}"
        except ValueError:
            return f"killed by signal {-code}"
    return f"exit code {code}"


def _worker_shim(fn: Callable, args: tuple, key: str, attempt: int,
                 hb, heartbeat_s: float, sink=None) -> Any:
    """Runs in the pool worker: mark worker context for chaos, record the
    start beat, keep beating from a daemon thread, then run the task.

    When the parent is tracing it passes a Manager list as ``sink``: the
    shim installs a fresh worker :class:`~repro.obs.trace.Tracer`, wraps
    the task in a ``task`` span (key / attempt / worker pid), and ships
    the event batch back for the supervisor to absorb — each worker keeps
    its own pid so the merged trace has one track per worker process.  A
    worker killed mid-task simply never ships; fault visibility comes
    from the supervisor-side events.
    """
    chaos.enter_worker()
    tracer = obs_trace.Tracer("worker") if sink is not None else None
    obs_trace.install(tracer)   # clears any fork-inherited parent tracer
    start = time.time()
    if hb is not None:
        try:
            hb[key] = (start, start)
        except Exception:  # noqa: BLE001 — manager gone: beat-less mode
            hb = None
    stop = threading.Event()
    if hb is not None:
        def _beat(hb=hb):
            while not stop.wait(heartbeat_s):
                try:
                    hb[key] = (start, time.time())
                except Exception:  # noqa: BLE001 — manager gone mid-task
                    return
        threading.Thread(target=_beat, daemon=True).start()
    try:
        chaos.maybe_fault(key, attempt)  # may segfault/exit/hang here
        if tracer is None:
            return fn(*args)
        with tracer.span("task", cat="pool", key=key, attempt=attempt,
                         worker_pid=tracer.pid):
            return fn(*args)
    finally:
        stop.set()
        if tracer is not None:
            obs_trace.install(None)
            try:
                sink.append(tracer.events)
            except Exception:  # noqa: BLE001 — manager gone: drop the batch
                pass


def execute_inline(tasks: Sequence[RuntimeTask],
                   cache: Optional[CertificateCache] = None,
                   cacheable: Callable[[Any], bool] = cacheable_report,
                   degraded_reason: Optional[str] = None
                   ) -> Dict[str, TaskOutcome]:
    """Sequential in-process execution (``workers <= 1`` and the
    degradation path).  Budgets are not enforceable — an in-process run
    cannot interrupt itself — but results still commit to the cache one
    by one, so an interrupted run resumes from its last committed task.
    Worker-side chaos never fires here (a segfault would take down the
    caller — the exact failure the runtime exists to contain)."""
    outcomes: Dict[str, TaskOutcome] = {}
    for task in tasks:
        outcomes[task.key] = _run_one_inline(task, cache, cacheable,
                                             degraded_reason)
    return outcomes


def _run_one_inline(task: RuntimeTask, cache, cacheable,
                    degraded_reason: Optional[str]) -> TaskOutcome:
    hit = _cache_lookup(task, cache)
    if hit is not None:
        return hit
    t0 = time.perf_counter()
    REGISTRY.counter("pool.tasks").inc()
    try:
        with obs_trace.span("task", cat="pool", key=task.key, inline=True):
            value = task.run_local()
    except Exception as e:  # noqa: BLE001 — one bad task must not sink the run
        return TaskOutcome(
            task.key, "error", executor="inline",
            error=f"task raised in-process: {type(e).__name__}: {e}",
            wall_s=time.perf_counter() - t0,
            degraded_reason=degraded_reason)
    wall_s = time.perf_counter() - t0
    REGISTRY.histogram("pool.run_s").observe(wall_s)
    out = TaskOutcome(task.key, "ok", value=value, executor="inline",
                      wall_s=wall_s, degraded_reason=degraded_reason,
                      cache=_commit(task, value, cache, cacheable))
    return out


def _cache_lookup(task: RuntimeTask, cache) -> Optional[TaskOutcome]:
    if cache is None or task.cache_key is None:
        return None
    value = cache.get(task.cache_key)
    if value is None:
        return None
    return TaskOutcome(task.key, "ok", value=value, attempts=0,
                       executor="cache", cache="hit")


def _commit(task: RuntimeTask, value: Any, cache, cacheable
            ) -> Optional[str]:
    if cache is None or task.cache_key is None:
        return None
    if cacheable(value):
        cache.put(task.cache_key, value)
    return "miss"


class SupervisedPool:
    """Fault-tolerant process-pool executor for :class:`RuntimeTask`\\ s.

    Persistent: the warmed workers (and the heartbeat manager) survive
    across :meth:`execute` calls until :meth:`shutdown`, so repeated
    sweeps run at steady-state speed.  Usable as a context manager.
    """

    def __init__(self, workers: int, mp_method: Optional[str] = None,
                 max_retries: int = DEFAULT_MAX_RETRIES,
                 backoff_s: float = DEFAULT_BACKOFF_S,
                 heartbeat_s: float = DEFAULT_HEARTBEAT_S,
                 warm: bool = True):
        if workers < 1:
            raise ValueError("SupervisedPool needs workers >= 1; use "
                             "execute_inline for in-process runs")
        if mp_method is None:
            methods = multiprocessing.get_all_start_methods()
            mp_method = "fork" if "fork" in methods else "spawn"
        self.workers = workers
        self.mp_method = mp_method
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.heartbeat_s = heartbeat_s
        self._initializer = _warm_worker if warm else None
        self._ctx = multiprocessing.get_context(mp_method)
        self._executor: Optional[ProcessPoolExecutor] = None
        self._manager = None
        self._hb = None                  # manager dict: key -> (start, beat)
        self._sink = None                # manager list: worker trace batches

    # -- lifecycle ----------------------------------------------------------
    def __enter__(self) -> "SupervisedPool":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def shutdown(self) -> None:
        self._discard_executor()
        if self._manager is not None:
            try:
                self._manager.shutdown()
            except Exception:  # noqa: BLE001 — already dead is fine
                pass
            self._manager = None
            self._hb = None

    def _ensure_heartbeats(self):
        if self._manager is None and self._hb is None:
            try:
                self._manager = self._ctx.Manager()
                self._hb = self._manager.dict()
            except Exception:  # noqa: BLE001 — degrade to submit-time budgets
                self._manager, self._hb = None, None
        return self._hb

    def _make_executor(self, size: int) -> ProcessPoolExecutor:
        try:
            return ProcessPoolExecutor(
                max_workers=size, mp_context=self._ctx,
                initializer=self._initializer)
        except Exception as e:  # noqa: BLE001 — no pool to be had
            raise PoolUnavailable(
                f"cannot create process pool: {type(e).__name__}: {e}"
            ) from e

    def _ensure_executor(self) -> ProcessPoolExecutor:
        if self._executor is None:
            self._executor = self._make_executor(self.workers)
        return self._executor

    def _discard_executor(self) -> None:
        if self._executor is not None:
            terminate_pool(self._executor)
            self._executor = None

    def _exit_cause(self) -> str:
        """Best-effort exit causes of the (broken) pool's dead workers."""
        if self._executor is None:
            return "worker process died"
        time.sleep(0.05)                 # let exit codes settle
        causes = [
            _describe_exit(p.exitcode)
            for p in getattr(self._executor, "_processes", {}).values()
            if p.exitcode not in (None, 0)]
        return "worker " + (", ".join(sorted(set(causes)))
                            if causes else "process died")

    # -- heartbeat bookkeeping ----------------------------------------------
    def _beat_of(self, key: str) -> Optional[Tuple[float, float]]:
        if self._hb is None:
            return None
        try:
            return self._hb.get(key)
        except Exception:  # noqa: BLE001 — manager died mid-run
            self._hb = None
            return None

    def _clear_beat(self, key: str) -> None:
        if self._hb is not None:
            try:
                self._hb.pop(key, None)
            except Exception:  # noqa: BLE001
                self._hb = None

    # -- execution ----------------------------------------------------------
    def execute(self, tasks: Sequence[RuntimeTask],
                cache: Optional[CertificateCache] = None,
                cacheable: Callable[[Any], bool] = cacheable_report
                ) -> Dict[str, TaskOutcome]:
        """Run every task; always returns one outcome per key."""
        keys = [t.key for t in tasks]
        if len(set(keys)) != len(keys):
            raise ValueError("duplicate task keys")
        outcomes: Dict[str, TaskOutcome] = {}
        todo: List[RuntimeTask] = []
        for t in tasks:
            hit = _cache_lookup(t, cache)
            if hit is not None:
                outcomes[t.key] = hit
            else:
                todo.append(t)
        if not todo:
            return outcomes
        tracer = obs_trace.current()
        self._open_sink(tracer)
        try:
            self._supervise(todo, outcomes, cache, cacheable)
        except PoolUnavailable as e:
            obs_trace.event("pool.degraded", cat="fault", reason=str(e))
            REGISTRY.counter("pool.degraded").inc()
            remaining = [t for t in todo if t.key not in outcomes]
            outcomes.update(execute_inline(
                remaining, cache, cacheable,
                degraded_reason=f"degraded to in-process: {e}"))
        finally:
            self._drain_sink(tracer)
        return outcomes

    # -- worker trace merging ------------------------------------------------
    def _open_sink(self, tracer) -> None:
        """A fresh Manager list per execute() for worker event batches."""
        self._sink = None
        if tracer is None:
            return
        self._ensure_heartbeats()
        if self._manager is not None:
            try:
                self._sink = self._manager.list()
            except Exception:  # noqa: BLE001 — trace merging is best-effort
                self._sink = None

    def _drain_sink(self, tracer) -> None:
        """Absorb every worker batch shipped during this execute()."""
        sink, self._sink = self._sink, None
        if tracer is None or sink is None:
            return
        try:
            batches = list(sink)
        except Exception:  # noqa: BLE001 — manager died: events are gone
            return
        for batch in batches:
            tracer.absorb(list(batch))

    def _obs_task_done(self, key: str, submit_t: Dict[str, float],
                       running_t: Optional[Dict[str, float]], status: str,
                       wall_s: float, attempt: int = 1) -> float:
        """Emit the supervisor-side queue/run spans + pool metrics for one
        finished task; returns its queue wait in seconds."""
        end = time.time()
        submit = submit_t.get(key)
        start = self._start_of(key, submit_t, running_t)
        queue_s = max(start - submit, 0.0) \
            if start is not None and submit is not None else 0.0
        tracer = obs_trace.current()
        if tracer is not None and submit is not None:
            if queue_s > 0:
                tracer.complete("queue", submit, submit + queue_s,
                                cat="pool", key=key)
            tracer.complete("run", start if start is not None else submit,
                            end, cat="pool", key=key, status=status,
                            attempt=attempt)
        REGISTRY.counter("pool.tasks").inc()
        REGISTRY.histogram("pool.queue_s").observe(queue_s)
        REGISTRY.histogram("pool.run_s").observe(wall_s)
        return round(queue_s, 6)

    def _supervise(self, tasks: List[RuntimeTask], outcomes, cache,
                   cacheable) -> None:
        self._ensure_heartbeats()
        pending: Dict[str, RuntimeTask] = {t.key: t for t in tasks}
        while pending:
            suspects, cause = self._run_shared(pending, outcomes, cache,
                                               cacheable)
            for key in suspects:
                self._run_isolated(pending.pop(key), outcomes, cache,
                                   cacheable, first_cause=cause)

    def _run_shared(self, pending: Dict[str, RuntimeTask], outcomes,
                    cache, cacheable) -> Tuple[List[str], str]:
        """Happy path: fan pending tasks out on the shared pool.

        Completed/errored/timed-out tasks are popped from ``pending`` as
        their outcomes land.  Returns ``(suspect keys, crash cause)`` on a
        pool break — the tasks that were *running* when the pool died and
        therefore need quarantined re-execution; queued tasks stay in
        ``pending`` for the caller to fan out again.
        """
        pool = self._ensure_executor()
        submit_t: Dict[str, float] = {}
        running_t: Dict[str, float] = {}
        futs: Dict[Any, str] = {}
        for key, task in pending.items():
            self._clear_beat(key)
            submit_t[key] = time.time()
            futs[pool.submit(_worker_shim, task.fn, task.args, key, 1,
                             self._hb, self.heartbeat_s,
                             self._sink)] = key
        while futs:
            done, _ = wait(set(futs), timeout=_POLL_S,
                           return_when=FIRST_COMPLETED)
            now = time.time()
            for f, key in futs.items():
                if key not in running_t and f.running():
                    running_t[key] = now
            broken = False
            for f in done:
                key = futs.pop(f)
                task = pending.get(key)
                if task is None:
                    continue
                try:
                    value = f.result()
                except BrokenExecutor:
                    broken = True
                    continue
                except Exception as e:  # noqa: BLE001 — task-level failure
                    pending.pop(key)
                    wall_s = self._elapsed(key, submit_t, running_t)
                    outcomes[key] = TaskOutcome(
                        key, "error",
                        error=f"worker failed: {type(e).__name__}: {e}",
                        wall_s=wall_s,
                        queue_s=self._obs_task_done(key, submit_t,
                                                    running_t, "error",
                                                    wall_s))
                    continue
                pending.pop(key)
                wall_s = self._elapsed(key, submit_t, running_t)
                outcomes[key] = TaskOutcome(
                    key, "ok", value=value, wall_s=wall_s,
                    queue_s=self._obs_task_done(key, submit_t, running_t,
                                                "ok", wall_s),
                    cache=_commit(task, value, cache, cacheable))
            if broken:
                cause = self._exit_cause()
                self._discard_executor()
                suspects = [k for k in pending
                            if self._beat_of(k) is not None
                            or self._hb is None]
                obs_trace.event("pool.broken", cat="fault", cause=cause,
                                suspects=sorted(suspects))
                REGISTRY.counter("pool.broken").inc()
                return suspects, cause
            expired = [k for k in list(futs.values())
                       if k in pending
                       and self._over_budget(pending[k], submit_t,
                                             running_t)]
            if expired:
                for key in expired:
                    task = pending.pop(key)
                    outcomes[key] = self._timeout_outcome(task, submit_t,
                                                          running_t)
                # the wedged worker dies with its pool; survivors resume
                # on a fresh one
                self._discard_executor()
                for f in futs:
                    f.cancel()
                if pending:
                    return self._run_shared(pending, outcomes, cache,
                                            cacheable)
                return [], ""
        return [], ""

    def _run_isolated(self, task: RuntimeTask, outcomes, cache, cacheable,
                      first_cause: str) -> None:
        """Quarantine: re-run one crash suspect alone on a fresh
        single-worker pool with bounded retry + exponential backoff, so a
        repeat crash blames exactly this task."""
        cause = first_cause
        attempts = 0
        while attempts <= self.max_retries:
            attempts += 1
            if attempts > 1:
                obs_trace.event("task.retry", cat="fault", key=task.key,
                                attempt=attempts, cause=cause)
                REGISTRY.counter("pool.retries").inc()
                time.sleep(self.backoff_s * 2 ** (attempts - 2))
            pool = self._make_executor(1)
            self._clear_beat(task.key)
            submit_t = {task.key: time.time()}
            running_t: Dict[str, float] = {}
            fut = pool.submit(_worker_shim, task.fn, task.args, task.key,
                              attempts, self._hb, self.heartbeat_s,
                              self._sink)
            try:
                while True:
                    done, _ = wait({fut}, timeout=_POLL_S)
                    if done:
                        break
                    if task.key not in running_t and fut.running():
                        running_t[task.key] = time.time()
                    if self._over_budget(task, submit_t, running_t):
                        outcomes[task.key] = self._timeout_outcome(
                            task, submit_t, running_t, attempts=attempts)
                        return
                try:
                    value = fut.result()
                except BrokenExecutor:
                    cause = self._exit_cause_of(pool) or cause
                    obs_trace.event("worker.crash", cat="fault",
                                    key=task.key, attempt=attempts,
                                    cause=cause)
                    continue             # retry on a replacement worker
                except Exception as e:  # noqa: BLE001
                    wall_s = self._elapsed(task.key, submit_t, running_t)
                    outcomes[task.key] = TaskOutcome(
                        task.key, "error", attempts=attempts,
                        error=f"worker failed: {type(e).__name__}: {e}",
                        wall_s=wall_s,
                        queue_s=self._obs_task_done(task.key, submit_t,
                                                    running_t, "error",
                                                    wall_s, attempts))
                    return
                wall_s = self._elapsed(task.key, submit_t, running_t)
                outcomes[task.key] = TaskOutcome(
                    task.key, "ok", value=value, attempts=attempts,
                    wall_s=wall_s,
                    queue_s=self._obs_task_done(task.key, submit_t,
                                                running_t, "ok", wall_s,
                                                attempts),
                    cache=_commit(task, value, cache, cacheable))
                return
            finally:
                terminate_pool(pool)
        obs_trace.event("task.failed", cat="fault", key=task.key,
                        attempts=attempts, cause=cause)
        outcomes[task.key] = TaskOutcome(
            task.key, "error", attempts=attempts,
            error=f"worker crashed on all {attempts} attempts "
                  f"(last: {cause})",
            wall_s=self._elapsed(task.key, {task.key: time.time()}))

    @staticmethod
    def _exit_cause_of(pool: ProcessPoolExecutor) -> Optional[str]:
        time.sleep(0.05)
        causes = [_describe_exit(p.exitcode)
                  for p in getattr(pool, "_processes", {}).values()
                  if p.exitcode not in (None, 0)]
        return f"worker {', '.join(sorted(set(causes)))}" if causes \
            else None

    # -- budget helpers -----------------------------------------------------
    def _start_of(self, key: str, submit_t: Dict[str, float],
                  running_t: Optional[Dict[str, float]] = None
                  ) -> Optional[float]:
        beat = self._beat_of(key)
        if beat is not None:
            return beat[0]
        if self._hb is None:             # no heartbeats: submit-time budget
            return submit_t.get(key)
        if running_t is not None and key in running_t:
            # picked up by the executor but no start beat ever arrived —
            # a worker wedged during startup (e.g. a fork-inherited lock)
            # must still burn its budget, or execute() would wait forever
            return running_t[key]
        return None                      # queued — budget not ticking yet

    def _elapsed(self, key: str, submit_t: Dict[str, float],
                 running_t: Optional[Dict[str, float]] = None) -> float:
        start = self._start_of(key, submit_t, running_t)
        return max(time.time() - start, 0.0) if start is not None else 0.0

    def _over_budget(self, task: RuntimeTask, submit_t: Dict[str, float],
                     running_t: Optional[Dict[str, float]] = None) -> bool:
        start = self._start_of(task.key, submit_t, running_t)
        return start is not None and time.time() - start > task.budget_s

    def _timeout_outcome(self, task: RuntimeTask,
                         submit_t: Dict[str, float],
                         running_t: Optional[Dict[str, float]] = None,
                         attempts: int = 1) -> TaskOutcome:
        elapsed = self._elapsed(task.key, submit_t, running_t)
        beat = self._beat_of(task.key)
        if beat is not None:
            age = time.time() - beat[1]
            liveness = (f"worker alive (heartbeat {age:.1f}s ago) — task "
                        f"over budget" if age <= 4 * self.heartbeat_s
                        else f"no heartbeat for {age:.1f}s — worker "
                             f"presumed hung")
        elif self._hb is not None:
            liveness = ("no heartbeat since start — worker wedged "
                        "during startup")
        else:
            liveness = "no heartbeat channel — submit-time budget"
        obs_trace.event("task.timeout", cat="fault", key=task.key,
                        elapsed=round(elapsed, 3), liveness=liveness)
        REGISTRY.counter("pool.timeouts").inc()
        return TaskOutcome(
            task.key, "timeout", attempts=attempts,
            error=f"exceeded per-task budget of {task.budget_s:g}s "
                  f"(ran {elapsed:.1f}s; {liveness})",
            wall_s=elapsed,
            queue_s=self._obs_task_done(task.key, submit_t, running_t,
                                        "timeout", elapsed, attempts))


def pool_stats(outcomes: Dict[str, TaskOutcome]) -> dict:
    """Aggregate queue-wait vs on-worker wall over a run's outcomes.

    Timing-class data for the report families' ``pool`` field and
    ``SuiteResult.summary()["runtime"]`` — never part of stable
    summaries (queue waits vary with worker count and machine load).
    Cache hits (``attempts == 0``) are excluded: they never occupied a
    worker.
    """
    executed = [o for o in outcomes.values() if o.attempts > 0]
    return {
        "tasks": len(executed),
        "queue_s_sum": round(sum(o.queue_s for o in executed), 6),
        "run_s_sum": round(sum(o.wall_s for o in executed), 6),
        "queue_s_max": round(max((o.queue_s for o in executed),
                                 default=0.0), 6),
        "retries": sum(max(o.attempts - 1, 0) for o in executed),
        "timeouts": sum(1 for o in executed if o.status == "timeout"),
    }


def run_tasks(tasks: Sequence[RuntimeTask], workers: int,
              mp_method: Optional[str] = None,
              cache: Optional[CertificateCache] = None,
              cacheable: Callable[[Any], bool] = cacheable_report,
              **pool_kw) -> Dict[str, TaskOutcome]:
    """One-shot convenience: inline for ``workers <= 1``, else a
    :class:`SupervisedPool` torn down afterwards."""
    if workers <= 1:
        return execute_inline(tasks, cache, cacheable)
    with SupervisedPool(workers, mp_method=mp_method, **pool_kw) as pool:
        return pool.execute(tasks, cache=cache, cacheable=cacheable)

"""repro.runtime — fault-tolerant execution layer for the verifier.

The shared runtime under ``repro.api.Suite``, ``repro.modelcheck`` and
``repro.gradcheck``: a supervised worker pool (per-task budgets,
heartbeat-based hang/death telling, bounded retry with worker
replacement, in-process degradation), a crash-safe persistent
certificate cache, and the chaos harness that proves both.

    from repro.runtime import RuntimeTask, SupervisedPool, run_tasks
    outcomes = run_tasks(tasks, workers=4, cache=CertificateCache(dir))

Fault injection (tests / ``make chaos-smoke``):

    GRAPHGUARD_CHAOS=crash:1 GRAPHGUARD_CHAOS_TARGET=sp_moe ...
"""
from .cache import (CACHE_SCHEMA, DEFAULT_CACHE_DIR, CertificateCache,
                    aval_token, cacheable_report, engine_fingerprint,
                    obligation_cache_key, resolve_cache, serve_cache_key,
                    spec_token, strategy_cache_key)
from .pool import (PoolUnavailable, RuntimeTask, SupervisedPool,
                   TaskOutcome, execute_inline, pool_stats, run_tasks,
                   terminate_pool)
from . import chaos

__all__ = [
    "CACHE_SCHEMA", "DEFAULT_CACHE_DIR", "CertificateCache", "aval_token",
    "cacheable_report", "engine_fingerprint", "obligation_cache_key",
    "resolve_cache", "serve_cache_key", "spec_token", "strategy_cache_key",
    "PoolUnavailable", "RuntimeTask", "SupervisedPool", "TaskOutcome",
    "execute_inline", "pool_stats", "run_tasks", "terminate_pool",
    "chaos",
]

"""repro.modelcheck — whole-model refinement verification.

The paper's headline claim is scale: GraphGuard verifies *full model*
deployments, not single layers.  This subsystem gets there the same way
production graph verifiers do (PAPERS.md: "Verifying Computational Graphs
in Production-Grade Distributed Machine Learning Frameworks"): layer-wise
decomposition plus structural deduplication.

    from repro.modelcheck import check_model
    report = check_model("gpt", "dp2xtp2")        # -> ModelReport
    report.dedup_ratio                            # 14 blocks / 3 obligations

Pipeline:

  * ``decompose``    slices a (model config, mesh plan) pair into per-block
                     verification obligations — embedding, each
                     transformer/MoE block, head — with R_i derived from
                     the plan's ``PartitionSpec``s and block *k*'s output
                     spec chained as block *k+1*'s input spec.
  * ``obligations``  canonicalizes obligations by structure + shapes +
                     specs (never layer index), so N identical transformer
                     layers cost one verification.
  * ``schedule``     fans the unique obligations across a process pool
                     (the ``repro.api.Suite`` worker model) or runs them
                     in-process.
  * ``stitch``       checks the seams (each block's inferred R_o must be
                     the relation its output spec promises the next block)
                     and assembles per-obligation certificates into one
                     :class:`ModelReport`.

Bug injection: ``check_model(..., bug="wrong_spec", bug_layer=k)`` shards
layer *k*'s MLP down-projection over the wrong mesh axis; the obligation
for that layer stops deduplicating against its siblings and the
``ModelReport`` localizes the refinement error to block *k*.
"""
from .decompose import (FAMILY_SUPPORT, ModelCheckError, decompose,
                        list_model_ids, supported_models)
from .obligations import Obligation, ObligationSet, canonical_key
from .report import MODEL_REPORT_SCHEMA, BlockResult, ModelReport
from .schedule import check_model, run_obligations
from .stitch import expected_output_relation, stitch

__all__ = [
    "FAMILY_SUPPORT", "ModelCheckError", "decompose", "list_model_ids",
    "supported_models", "Obligation", "ObligationSet", "canonical_key",
    "MODEL_REPORT_SCHEMA", "BlockResult", "ModelReport", "check_model",
    "run_obligations", "expected_output_relation", "stitch",
]

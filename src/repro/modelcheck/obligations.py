"""Per-block verification obligations and their structural dedup key.

An :class:`Obligation` is one block-sized verification task: a sequential
fragment, its per-rank SPMD implementation, the mesh, and the input/output
``PartitionSpec``s the decomposer derived from the plan.  It is the
modelcheck analogue of :class:`repro.api.StrategySpec` — and converts into
one (``to_strategy_spec``) so the existing engine plumbing runs it
unchanged.

``canonical_key`` is the dedup identity: structure + shapes + dtypes +
specs + mesh — deliberately *not* the layer index — so the twelve
identical GPT blocks canonicalize to a single obligation and the engine
verifies it once.  A bug injected into one layer changes that layer's
structure fingerprint, splitting it out of the dedup class (which is
exactly how the ``ModelReport`` localizes it).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from ..api.spec import StrategySpec


# the token vocabulary is shared with the persistent certificate cache
# (repro.runtime.cache), which content-addresses on these same strings
from ..runtime.cache import aval_token as _aval_token  # noqa: E402
from ..runtime.cache import spec_token as _spec_token  # noqa: E402


@dataclass(frozen=True)
class Obligation:
    """One block's verification task (hashable by its canonical key)."""
    kind: str                            # embed | block | moe_block | head
    seq_fn: Callable = field(compare=False)
    dist_fn: Callable = field(compare=False)
    mesh_axes: tuple                     # ordered ((axis, size), ...)
    in_specs: tuple                      # PartitionSpec per input
    out_specs: tuple                     # PartitionSpec per output (seams)
    avals: tuple                         # ShapeDtypeStruct per global input
    input_names: tuple
    structure: tuple                     # extra fingerprint facts, sorted
                                         # (("role", "local"), ("bug", ...))
    description: str = field(default="", compare=False)

    @property
    def key(self) -> str:
        return canonical_key(self)

    def to_strategy_spec(self, *, name: str, bug: Optional[str] = None,
                         expected: str = "certificate") -> StrategySpec:
        """View as a StrategySpec so ``repro.api.runner`` machinery runs it."""
        return StrategySpec(
            self.seq_fn, self.dist_fn, dict(self.mesh_axes),
            tuple(self.in_specs), tuple(self.avals),
            tuple(self.input_names), name=name,
            degree=tuple(s for _, s in self.mesh_axes),
            bug=bug, expected=expected, description=self.description)


def canonical_key(ob: Obligation) -> str:
    """Structural identity of an obligation — everything that determines
    the verification outcome, nothing that doesn't (layer index, block
    position).  Shapes/dtypes/specs/mesh/structure facts are hashed into a
    short stable token prefixed with the kind for readability."""
    parts = [
        "kind=" + ob.kind,
        "mesh=" + ",".join(f"{a}{s}" for a, s in ob.mesh_axes),
        "in=" + ";".join(f"{n}:{_aval_token(a)}:{_spec_token(s)}"
                         for n, a, s in zip(ob.input_names, ob.avals,
                                            ob.in_specs)),
        "out=" + ";".join(_spec_token(s) for s in ob.out_specs),
        "struct=" + ";".join(f"{k}={v}" for k, v in sorted(ob.structure)),
    ]
    digest = hashlib.sha256("|".join(parts).encode()).hexdigest()[:12]
    return f"{ob.kind}-{digest}"


@dataclass
class ObligationSet:
    """The dedup cache: ordered blocks -> unique obligations.

    ``blocks[i]`` is (block name, obligation key); ``unique`` maps key ->
    the representative :class:`Obligation` (the first block that produced
    it).  ``add`` returns the key and whether it was a cache hit.
    """
    blocks: List[Tuple[str, str]] = field(default_factory=list)
    unique: Dict[str, Obligation] = field(default_factory=dict)

    def add(self, block_name: str, ob: Obligation) -> Tuple[str, bool]:
        key = ob.key
        hit = key in self.unique
        if not hit:
            self.unique[key] = ob
        self.blocks.append((block_name, key))
        return key, hit

    @property
    def total_blocks(self) -> int:
        return len(self.blocks)

    @property
    def n_unique(self) -> int:
        return len(self.unique)

    @property
    def dedup_ratio(self) -> float:
        return self.total_blocks / max(self.n_unique, 1)

    def block_indices(self, key: str) -> List[int]:
        return [i for i, (_, k) in enumerate(self.blocks) if k == key]

    def keys_in_order(self) -> List[str]:
        """Unique keys ordered by first appearance in the block sequence."""
        seen, out = set(), []
        for _, k in self.blocks:
            if k not in seen:
                seen.add(k)
                out.append(k)
        return out

"""Scheduler: fan unique obligations across the Suite worker pool model.

``check_model`` is the subsystem entry point.  Unique obligations (after
dedup) are verified either in-process or on a fork/spawn process pool with
the same warmed-worker discipline as :class:`repro.api.Suite` — workers
receive only picklable ``(model id, plan name, bug, bug_layer, key)``
tuples and rebuild the obligation from the deterministic decomposition,
so nothing unpicklable crosses the boundary and certificates stay
byte-identical for any worker count.
"""
from __future__ import annotations

import time
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeoutError
from typing import Dict, Optional, Tuple, Union

from ..api.report import Report
from ..api.runner import _engine_opts
from ..core import (RefinementError, capture, capture_spmd, check_refinement,
                    expand_spmd)
from ..core.terms import pretty
from ..models.config import ModelConfig
from ..models.registry import load_config
from ..sharding.specs import MeshPlan
from .decompose import Decomposition, decompose, list_model_ids
from .obligations import Obligation
from .report import ModelReport
from .stitch import expected_output_relation, stitch

DEFAULT_TIMEOUT_S = 600.0


def _expected_for(ob: Obligation) -> str:
    return ("refinement_error"
            if dict(ob.structure).get("bug", "-") != "-" else "certificate")


def _verify_obligation(ob: Obligation, name: str, expected: str,
                       engine_opts: Optional[dict] = None) -> dict:
    """Verify one obligation; returns a JSON-ready nested Report dict with
    the seam check (inferred R_o vs spec-promised relation) attached."""
    spec = ob.to_strategy_spec(
        name=name, expected=expected,
        bug=None if expected == "certificate" else "wrong_spec")
    t0 = time.perf_counter()
    try:
        with _engine_opts(engine_opts) as eo:
            gs = capture(spec.seq_fn, list(spec.avals),
                         list(spec.input_names))
            cap = capture_spmd(spec.dist_fn, spec.mesh_axes,
                               list(spec.in_specs), list(spec.avals),
                               list(spec.input_names))
            gd, r_i = expand_spmd(cap)
            cert = check_refinement(gs, gd, r_i, max_nodes=eo.max_nodes)
    except RefinementError as e:
        return Report(
            case=name, degree=spec.degree, bug=spec.bug,
            verdict="refinement_error", expected=expected,
            ok=expected == "refinement_error", localization=e.payload(),
            wall_s=round(time.perf_counter() - t0, 6)).to_json()
    except Exception as e:  # noqa: BLE001 — capture/engine failure -> verdict
        return Report(
            case=name, degree=spec.degree, bug=spec.bug,
            verdict="error", expected=expected, ok=False,
            error=f"{type(e).__name__}: {e}",
            wall_s=round(time.perf_counter() - t0, 6)).to_json()

    # seam check: each distributed output must assemble exactly as its
    # output PartitionSpec promises the next block's input relation
    n_ranks = 1
    for _, s in ob.mesh_axes:
        n_ranks *= s
    seams, seams_ok = [], True
    for j, (out_name, ospec) in enumerate(zip(gs.outputs, ob.out_specs)):
        gd_out = gd.outputs[j * n_ranks]
        base = gd_out.split("@")[0]
        expect = expected_output_relation(
            base, gd.shapes[gd_out], gd.dtypes[gd_out], ospec,
            dict(ob.mesh_axes))
        got = cert.r_o.get(out_name)
        ok = got is expect               # Terms are hash-consed: identity
        seams_ok &= ok
        seams.append({"output": out_name, "ok": ok,
                      "expected": pretty(expect, 999),
                      "got": None if got is None else pretty(got, 999)})
    cert_json = cert.to_json()
    d = Report(
        case=name, degree=spec.degree, bug=spec.bug,
        verdict="certificate", expected=expected,
        ok=expected == "certificate" and seams_ok,
        r_o=cert_json["r_o"], stats=cert_json["stats"],
        wall_s=round(time.perf_counter() - t0, 6)).to_json()
    d["seams"] = seams
    return d


def _task_name(dec: Decomposition, key: str) -> str:
    return f"{dec.model}:{dec.plan.name}:{key}"


def _pool_task(model: str, plan: str, bug: Optional[str],
               bug_layer: Optional[int], key: str,
               engine_opts: Optional[dict]) -> Tuple[str, dict]:
    """Pool worker: rebuild the (deterministic) decomposition and verify
    the obligation addressed by ``key``."""
    dec = decompose(model, plan, bug=bug, bug_layer=bug_layer)
    ob = dec.obset.unique[key]
    return key, _verify_obligation(ob, _task_name(dec, key),
                                   _expected_for(ob), engine_opts)


def _poolable(dec: Decomposition) -> bool:
    """Workers rebuild by model id — only stock configs round-trip."""
    return (dec.model in list_model_ids()
            and load_config(dec.model) == dec.cfg)


def run_obligations(dec: Decomposition, workers: Optional[int] = None,
                    engine_opts: Optional[dict] = None,
                    timeout_s: float = DEFAULT_TIMEOUT_S
                    ) -> Tuple[Dict[str, dict], int]:
    """Verify the decomposition's unique obligations; returns
    ``({key: report dict}, workers actually used)``."""
    keys = dec.obset.keys_in_order()
    if workers is None:
        # auto: dedup usually leaves a single model with 3-4 sub-second
        # obligations — in-process beats paying pool spin-up; fan out only
        # when there is genuinely parallel work
        workers = min(4, len(keys)) if len(keys) > 4 else 1
    if workers >= 2 and not _poolable(dec):
        workers = 1
    reports: Dict[str, dict] = {}
    if workers < 2:
        for key in keys:
            ob = dec.obset.unique[key]
            reports[key] = _verify_obligation(
                ob, _task_name(dec, key), _expected_for(ob), engine_opts)
        return reports, 1

    import multiprocessing

    from ..api.suite import _warm_worker, terminate_pool
    # spawn, not fork: by the time a whole-model check runs, the parent
    # process has usually executed jax/pallas work and forking its
    # multithreaded state can deadlock the child mid-trace.  Obligations
    # are second-granularity (unlike the Suite's millisecond strategy
    # tasks), so the per-worker interpreter spin-up amortizes.
    ctx = multiprocessing.get_context("spawn")
    pool = ProcessPoolExecutor(max_workers=min(workers, len(keys)),
                               mp_context=ctx, initializer=_warm_worker)
    try:
        futs = {key: pool.submit(_pool_task, dec.model, dec.plan.name,
                                 dec.bug, dec.bug_layer, key, engine_opts)
                for key in keys}
        deadline = time.monotonic() + timeout_s
        for key, fut in futs.items():
            ob = dec.obset.unique[key]
            try:
                _, reports[key] = fut.result(
                    timeout=max(deadline - time.monotonic(), 0.001))
            except FutureTimeoutError:
                fut.cancel()
                reports[key] = Report(
                    case=_task_name(dec, key),
                    degree=tuple(s for _, s in ob.mesh_axes), bug=None,
                    verdict="timeout", expected=_expected_for(ob), ok=False,
                    error=f"exceeded model-check budget of {timeout_s}s",
                    wall_s=timeout_s).to_json()
            except Exception:  # noqa: BLE001 — broken/crashed worker:
                # fork-after-jax is flaky under heavy parent state, and the
                # obligation count is small — fall back to verifying this
                # obligation in-process rather than degrading the verdict
                reports[key] = _verify_obligation(
                    ob, _task_name(dec, key), _expected_for(ob),
                    engine_opts)
    finally:
        terminate_pool(pool)
    return reports, min(workers, len(keys))


def check_model(model: Union[str, ModelConfig], plan: Union[str, MeshPlan],
                *, bug: Optional[str] = None,
                bug_layer: Optional[int] = None,
                workers: Optional[int] = None,
                engine_opts: Optional[dict] = None,
                timeout_s: float = DEFAULT_TIMEOUT_S) -> ModelReport:
    """Whole-model refinement check: decompose, dedup, verify, stitch.

    Returns a :class:`ModelReport`; never raises on verification failures
    (they become block verdicts) — only on caller mistakes (unknown model /
    plan / bug).
    """
    t0 = time.perf_counter()
    dec = decompose(model, plan, bug=bug, bug_layer=bug_layer)
    reports, used = run_obligations(dec, workers=workers,
                                    engine_opts=engine_opts,
                                    timeout_s=timeout_s)
    return stitch(dec, reports, time.perf_counter() - t0, used)

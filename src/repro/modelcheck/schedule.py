"""Scheduler: fan unique obligations across the shared runtime.

``check_model`` is the subsystem entry point.  Unique obligations (after
dedup) are verified in-process or on a supervised spawn pool
(:mod:`repro.runtime`) — workers receive only picklable
``(model id, plan name, bug, bug_layer, key)`` tuples and rebuild the
obligation from the deterministic decomposition, so nothing unpicklable
crosses the boundary and certificates stay byte-identical for any worker
count.  ``timeout_s`` is a *per-obligation* budget enforced from the
moment the obligation starts on a worker, so one slow obligation can
never eat the budget of those queued behind it — the offender alone is
reported as ``timeout`` with its measured elapsed time.  With a
persistent cache attached (``cache=``), committed obligations are served
across runs by ``obligations.canonical_key`` content addressing.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Dict, Optional, Tuple, Union

from ..api.report import Report
from ..api.runner import _engine_opts
from ..core import (RefinementError, capture, capture_spmd, check_refinement,
                    expand_spmd)
from ..core.terms import pretty
from ..models.config import ModelConfig
from ..models.registry import load_config
from ..obs import trace as obs_trace
from ..runtime import (RuntimeTask, obligation_cache_key, pool_stats,
                       resolve_cache, run_tasks)
from ..sharding.specs import MeshPlan
from .decompose import Decomposition, decompose, list_model_ids
from .obligations import Obligation
from .report import ModelReport
from .stitch import expected_output_relation, stitch

DEFAULT_TIMEOUT_S = 600.0


def _expected_for(ob: Obligation) -> str:
    return ("refinement_error"
            if dict(ob.structure).get("bug", "-") != "-" else "certificate")


def _verify_obligation(ob: Obligation, name: str, expected: str,
                       engine_opts: Optional[dict] = None) -> dict:
    """Verify one obligation; returns a JSON-ready nested Report dict with
    the seam check (inferred R_o vs spec-promised relation) attached."""
    spec = ob.to_strategy_spec(
        name=name, expected=expected,
        bug=None if expected == "certificate" else "wrong_spec")
    t0 = time.perf_counter()
    try:
        with _engine_opts(engine_opts) as eo:
            gs = capture(spec.seq_fn, list(spec.avals),
                         list(spec.input_names))
            cap = capture_spmd(spec.dist_fn, spec.mesh_axes,
                               list(spec.in_specs), list(spec.avals),
                               list(spec.input_names))
            gd, r_i = expand_spmd(cap)
            cert = check_refinement(gs, gd, r_i, max_nodes=eo.max_nodes,
                                    explain=eo.explain)
    except RefinementError as e:
        return Report(
            case=name, degree=spec.degree, bug=spec.bug,
            verdict="refinement_error", expected=expected,
            ok=expected == "refinement_error", localization=e.payload(),
            explanation=getattr(e, "explanation", None),
            wall_s=round(time.perf_counter() - t0, 6)).to_json()
    except Exception as e:  # noqa: BLE001 — capture/engine failure -> verdict
        return Report(
            case=name, degree=spec.degree, bug=spec.bug,
            verdict="error", expected=expected, ok=False,
            error=f"{type(e).__name__}: {e}",
            wall_s=round(time.perf_counter() - t0, 6)).to_json()

    # seam check: each distributed output must assemble exactly as its
    # output PartitionSpec promises the next block's input relation
    n_ranks = 1
    for _, s in ob.mesh_axes:
        n_ranks *= s
    seams, seams_ok = [], True
    for j, (out_name, ospec) in enumerate(zip(gs.outputs, ob.out_specs)):
        gd_out = gd.outputs[j * n_ranks]
        base = gd_out.split("@")[0]
        expect = expected_output_relation(
            base, gd.shapes[gd_out], gd.dtypes[gd_out], ospec,
            dict(ob.mesh_axes))
        got = cert.r_o.get(out_name)
        ok = got is expect               # Terms are hash-consed: identity
        seams_ok &= ok
        seams.append({"output": out_name, "ok": ok,
                      "expected": pretty(expect, 999),
                      "got": None if got is None else pretty(got, 999)})
    cert_json = cert.to_json()
    d = Report(
        case=name, degree=spec.degree, bug=spec.bug,
        verdict="certificate", expected=expected,
        ok=expected == "certificate" and seams_ok,
        r_o=cert_json["r_o"], stats=cert_json["stats"],
        explanation=cert.explanation,
        wall_s=round(time.perf_counter() - t0, 6)).to_json()
    d["seams"] = seams
    return d


def _task_name(dec: Decomposition, key: str) -> str:
    return f"{dec.model}:{dec.plan.name}:{key}"


def _pool_task(model: str, plan: str, bug: Optional[str],
               bug_layer: Optional[int], key: str,
               engine_opts: Optional[dict]) -> dict:
    """Pool worker: rebuild the (deterministic) decomposition and verify
    the obligation addressed by ``key``."""
    dec = decompose(model, plan, bug=bug, bug_layer=bug_layer)
    ob = dec.obset.unique[key]
    return _verify_obligation(ob, _task_name(dec, key),
                              _expected_for(ob), engine_opts)


def _poolable(dec: Decomposition) -> bool:
    """Workers rebuild by model id — only stock configs round-trip."""
    return (dec.model in list_model_ids()
            and load_config(dec.model) == dec.cfg)


def _outcome_report(dec: Decomposition, key: str, outcome) -> dict:
    """Convert a runtime outcome into this obligation's report dict."""
    if outcome.ok:
        d = dict(outcome.value)
        if outcome.cache == "hit":
            # cache entries are content-addressed — the committed report
            # may carry the task name of another model that shares the
            # obligation; re-label it for this decomposition
            d["case"] = _task_name(dec, key)
        info = outcome.runtime_info()
        if info:
            d["runtime"] = info
        return d
    ob = dec.obset.unique[key]
    verdict = "timeout" if outcome.status == "timeout" else "error"
    return Report(
        case=_task_name(dec, key),
        degree=tuple(s for _, s in ob.mesh_axes), bug=None,
        verdict=verdict, expected=_expected_for(ob), ok=False,
        error=outcome.error, wall_s=round(outcome.wall_s, 6),
        runtime=outcome.runtime_info() or None).to_json()


def run_obligations(dec: Decomposition, workers: Optional[int] = None,
                    engine_opts: Optional[dict] = None,
                    timeout_s: float = DEFAULT_TIMEOUT_S,
                    cache=None
                    ) -> Tuple[Dict[str, dict], int, Optional[dict], dict]:
    """Verify the decomposition's unique obligations.

    Returns ``({key: report dict}, workers actually used, cache stats or
    None, runtime pool stats)``.  ``timeout_s`` budgets each obligation
    individually — the runtime starts the clock when the obligation
    starts on a worker, so a slow obligation times out alone instead of
    marking everything queued behind it.  ``cache`` takes anything
    :func:`repro.runtime.resolve_cache` accepts.
    """
    keys = dec.obset.keys_in_order()
    if workers is None:
        # auto: dedup usually leaves a single model with 3-4 sub-second
        # obligations — in-process beats paying pool spin-up; fan out only
        # when there is genuinely parallel work
        workers = min(4, len(keys)) if len(keys) > 4 else 1
    if workers >= 2 and not _poolable(dec):
        workers = 1
    cache = resolve_cache(cache)
    tasks = []
    for key in keys:
        ob = dec.obset.unique[key]
        tasks.append(RuntimeTask(
            key=key, fn=_pool_task,
            args=(dec.model, dec.plan.name, dec.bug, dec.bug_layer, key,
                  engine_opts),
            budget_s=timeout_s,
            cache_key=None if cache is None
            else obligation_cache_key(key, engine_opts),
            local_fn=partial(_verify_obligation, ob, _task_name(dec, key),
                             _expected_for(ob), engine_opts)))
    used = min(workers, len(keys)) or 1
    # spawn, not fork: by the time a whole-model check runs, the parent
    # process has usually executed jax/pallas work and forking its
    # multithreaded state can deadlock the child mid-trace.  Obligations
    # are second-granularity (unlike the Suite's millisecond strategy
    # tasks), so the per-worker interpreter spin-up amortizes.
    outcomes = run_tasks(tasks, used, mp_method="spawn", cache=cache)
    reports = {key: _outcome_report(dec, key, outcomes[key])
               for key in keys}
    cache_stats = None if cache is None else {
        "dir": cache.dir,
        "hits": sum(1 for o in outcomes.values() if o.cache == "hit"),
        "misses": sum(1 for o in outcomes.values() if o.cache == "miss"),
        "entries": len(cache),
        "recovered_corrupt": cache.recovered_corrupt}
    return reports, used, cache_stats, pool_stats(outcomes)


def check_model(model: Union[str, ModelConfig], plan: Union[str, MeshPlan],
                *, bug: Optional[str] = None,
                bug_layer: Optional[int] = None,
                workers: Optional[int] = None,
                engine_opts: Optional[dict] = None,
                timeout_s: float = DEFAULT_TIMEOUT_S,
                cache=None) -> ModelReport:
    """Whole-model refinement check: decompose, dedup, verify, stitch.

    Returns a :class:`ModelReport`; never raises on verification failures
    (they become block verdicts) — only on caller mistakes (unknown model /
    plan / bug).  ``cache`` attaches the persistent certificate cache
    (see :func:`repro.runtime.resolve_cache`), so a re-check after a
    one-block edit re-proves only the changed obligation.
    """
    t0 = time.perf_counter()
    dec = decompose(model, plan, bug=bug, bug_layer=bug_layer)
    obs_trace.event("dedup", cat="engine", subsystem="modelcheck",
                    total=dec.total_blocks, unique=dec.n_unique)
    reports, used, cache_stats, pstats = run_obligations(
        dec, workers=workers, engine_opts=engine_opts,
        timeout_s=timeout_s, cache=cache)
    return stitch(dec, reports, time.perf_counter() - t0, used,
                  cache_stats=cache_stats, pool=pstats)

"""Model decomposer: (config, plan) -> ordered per-block obligations.

``decompose`` walks a model's block structure — embedding, one obligation
per transformer/MoE layer (cycling the config's attention ``pattern``),
head — and derives every obligation's ``in_specs`` from the plan's
``PartitionSpec``s, with block *k*'s activation output spec chained as
block *k+1*'s activation input spec (the seam contract ``stitch`` checks
against each block's inferred R_o).

Obligations land in an :class:`ObligationSet`, which canonicalizes by
structure rather than layer index: GPT's 12 identical layers cost one
verification; gemma3's 5:1 local:global pattern yields two distinct layer
obligations.  An injected bug (``bug="wrong_spec"``, ``bug_layer=k``)
changes layer *k*'s fingerprint, so it splits out of its dedup class and
is verified (and localized) separately.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple, Union

from ..models.config import ModelConfig
from ..models.registry import ARCH_IDS, load_config
from ..sharding.specs import MeshPlan, parse_plan
from .blocks import (BlockBuildError, embed_obligation, head_obligation,
                     layer_obligation)
from .obligations import ObligationSet

# family -> support level (None = not yet decomposable).  "backbone" means
# the language backbone is verified and the stubbed frontend (vision/audio)
# is out of scope for the refinement check.
FAMILY_SUPPORT = {
    "dense": "full",
    "moe": "full",
    "vlm": "backbone",
    "ssm": None,        # cross-rank prefix scans need a cumsum lemma family
    "hybrid": None,     # RG-LRU recurrence, same limitation
    "audio": None,      # encoder-decoder frontend
}

# why each unsupported family is unsupported — surfaced in the
# ModelCheckError so the CLI user learns the actual blocker, not just
# the verdict
FAMILY_BLOCKERS = {
    "ssm": "cross-rank prefix scans need a cumsum lemma family",
    "hybrid": "the RG-LRU recurrence needs the same cross-rank scan lemmas",
    "audio": "the encoder-decoder cross-attention frontend is not "
             "block-decomposable yet",
}

BUGS = ("wrong_spec",)


class ModelCheckError(ValueError):
    pass


def list_model_ids() -> Tuple[str, ...]:
    """Every config id resolvable by ``repro.models.registry.load_config``."""
    return ("gpt",) + tuple(ARCH_IDS)


def supported_models() -> Tuple[str, ...]:
    out = []
    for mid in list_model_ids():
        if FAMILY_SUPPORT.get(load_config(mid).family):
            out.append(mid)
    return tuple(out)


@dataclass
class Decomposition:
    """The block sequence of one (model, plan) pair, deduplicated."""
    model: str
    cfg: ModelConfig
    plan: MeshPlan
    obset: ObligationSet
    bug: Optional[str] = None
    bug_layer: Optional[int] = None

    @property
    def total_blocks(self) -> int:
        return self.obset.total_blocks

    @property
    def n_unique(self) -> int:
        return self.obset.n_unique

    @property
    def dedup_ratio(self) -> float:
        return self.obset.dedup_ratio

    def sequential_chain(self):
        """Capture the whole sequential model as a named-block sequence
        (``repro.core.capture.capture_chain``): each block's graph reads
        the previous block's ``{name}.out*`` tensors, giving the report its
        whole-model G_s operator count without one opaque model jaxpr."""
        from ..core import capture_chain
        stages = []
        first = None
        for name, key in self.obset.blocks:
            ob = self.obset.unique[key]
            if first is None:
                first = ob
            # carry is the activation (input 0); params are the rest
            stages.append((name, ob.seq_fn, list(ob.avals[1:]),
                           list(ob.input_names[1:])))
        init_avals = [first.avals[0]]
        init_names = [first.input_names[0]]
        return capture_chain(stages, init_avals, init_names)


def _resolve(model: Union[str, ModelConfig],
             plan: Union[str, MeshPlan]) -> Tuple[str, ModelConfig, MeshPlan]:
    if isinstance(model, ModelConfig):
        cfg, mid = model, model.name
    else:
        mid = str(model)
        if mid not in list_model_ids():
            raise ModelCheckError(
                f"unknown model `{mid}` — known: {list(list_model_ids())}")
        cfg = load_config(mid)
    support = FAMILY_SUPPORT.get(cfg.family)
    if not support:
        why = FAMILY_BLOCKERS.get(
            cfg.family, f"family `{cfg.family}` is not registered")
        raise ModelCheckError(
            f"model `{mid}` is in family `{cfg.family}`, which modelcheck "
            f"cannot decompose yet ({why}) — supported families: "
            f"{sorted(k for k, v in FAMILY_SUPPORT.items() if v)}; "
            f"checkable models: {list(supported_models())}")
    if isinstance(plan, str):
        plan = parse_plan(plan)
    return mid, cfg, plan


def decompose(model: Union[str, ModelConfig], plan: Union[str, MeshPlan],
              *, bug: Optional[str] = None,
              bug_layer: Optional[int] = None) -> Decomposition:
    """Slice ``model`` under ``plan`` into per-block obligations.

    ``bug="wrong_spec"`` shards one layer's MLP down-projection over the
    wrong mesh axis (default ``bug_layer``: the middle layer).
    """
    mid, cfg, plan = _resolve(model, plan)
    if bug is not None:
        if bug not in BUGS:
            raise ModelCheckError(f"unknown bug `{bug}` — known: {BUGS}")
        if bug_layer is None:
            bug_layer = cfg.n_layers // 2
        if not 0 <= bug_layer < cfg.n_layers:
            raise ModelCheckError(
                f"bug_layer {bug_layer} out of range for {cfg.n_layers} "
                f"layers")
    elif bug_layer is not None:
        raise ModelCheckError("bug_layer without bug")

    moe = cfg.family == "moe"
    obset = ObligationSet()
    try:
        obset.add("embed", embed_obligation(cfg, plan))
        for i in range(cfg.n_layers):
            role = cfg.pattern[i % len(cfg.pattern)]
            if role not in ("global", "local"):
                raise ModelCheckError(
                    f"model `{mid}`: layer role `{role}` is not "
                    f"decomposable yet")
            layer_bug = bug if (bug is not None and i == bug_layer) else None
            obset.add(f"layer{i}",
                      layer_obligation(cfg, plan, role=role, moe=moe,
                                       bug=layer_bug))
        obset.add("head", head_obligation(cfg, plan))
    except BlockBuildError as e:
        raise ModelCheckError(f"model `{mid}` under plan "
                              f"`{plan.name}`: {e}") from e
    return Decomposition(mid, cfg, plan, obset, bug=bug, bug_layer=bug_layer)

"""Stitching: seam contracts + per-obligation reports -> ModelReport.

The decomposer chains block *k*'s output ``PartitionSpec`` as block
*k+1*'s input spec, so the whole-model argument is sound iff every block's
*inferred* R_o is exactly the relation its output spec promises the next
block (the same nested-concat construction ``derive_input_relation``
performs on inputs, applied to the block's distributed outputs).  The seam
check runs at verification time (``schedule._verify_obligation``) where
the captured G_d is in hand; this module builds the expected relation and
assembles the final :class:`ModelReport`.
"""
from __future__ import annotations

import itertools
from typing import Dict, List

from ..core.capture import Graph, derive_input_relation
from ..core.explain import aggregate_explanations
from .decompose import Decomposition
from .report import BlockResult, ModelReport


def expected_output_relation(base_name: str, local_shape, dtype: str,
                             spec, mesh_axes: dict):
    """The clean Term a block's output spec promises: the nested concat of
    per-rank outputs over the sharded mesh axes, at replica coordinate 0 of
    the unsharded ones (the engine's deterministic extraction picks the
    lexicographically-first replica, which is the same choice)."""
    axis_names = tuple(mesh_axes)
    sizes = tuple(mesh_axes[a] for a in axis_names)
    coords = list(itertools.product(*[range(s) for s in sizes]))
    g = Graph([base_name], [], [], {base_name: tuple(local_shape)},
              {base_name: dtype})
    r = derive_input_relation(g, [spec], axis_names, sizes, coords)
    return r[base_name][0]


def stitch(dec: Decomposition, reports: Dict[str, dict], wall_s: float,
           workers: int, cache_stats: Dict = None,
           pool: Dict = None) -> ModelReport:
    """Assemble per-obligation reports into the whole-model verdict.

    Per-block verdicts come from the dedup cache (``reports`` is keyed by
    obligation key); a block is ``cached`` when an earlier block already
    paid for its obligation.  The model verdict is the worst block verdict
    (error > refinement_error > seam mismatch > certificate), and ``ok``
    encodes the run's expectation: a clean run must certify end-to-end,
    a bug run must localize to exactly the injected block.
    """
    blocks: List[BlockResult] = []
    failing: List[int] = []
    seen: set = set()
    gs_ops_total = 0                     # whole-model G_s op count: each
    for i, (name, key) in enumerate(dec.obset.blocks):
        rep = reports[key]               # block costs its obligation's ops,
        ob = dec.obset.unique[key]       # cache hit or not (no re-tracing)
        gs_ops_total += (rep.get("stats") or {}).get("gs_ops", 0)
        seams = rep.get("seams") or []
        seam_ok = all(s["ok"] for s in seams) if seams else \
            rep["verdict"] == "certificate"
        blocks.append(BlockResult(
            index=i, name=name, kind=ob.kind, obligation=key,
            verdict=rep["verdict"], cached=key in seen, seam_ok=seam_ok))
        seen.add(key)
        if rep["verdict"] != "certificate" or not seam_ok:
            failing.append(i)

    verdicts = {b.verdict for b in blocks}
    if verdicts & {"error", "timeout"}:
        verdict = "error"
    elif "refinement_error" in verdicts:
        verdict = "refinement_error"
    elif any(not b.seam_ok for b in blocks):
        verdict = "unexpected_relation"
    else:
        verdict = "certificate"

    if dec.bug is None:
        ok = verdict == "certificate"
    else:
        # the injected bug must be localized to exactly its block:
        # block 0 is the embedding, so layer k is block k+1
        ok = (verdict == "refinement_error"
              and failing == [1 + dec.bug_layer])

    return ModelReport(
        model=dec.model, plan=dec.plan.name, verdict=verdict, ok=ok,
        total_blocks=dec.total_blocks, unique_obligations=dec.n_unique,
        dedup_ratio=round(dec.dedup_ratio, 3), blocks=blocks,
        reports=dict(reports), failing_blocks=failing,
        bug=dec.bug, bug_layer=dec.bug_layer,
        gs_ops_total=gs_ops_total, wall_s=round(wall_s, 6), workers=workers,
        cache=cache_stats, pool=pool,
        explanation=aggregate_explanations(reports))

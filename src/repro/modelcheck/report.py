"""ModelReport: per-obligation certificates stitched into one verdict.

A :class:`ModelReport` nests one :class:`repro.api.Report` per *unique*
obligation (the dedup cache means N identical layers share a single nested
report — and therefore byte-identical certificates) plus the block-level
view that maps every model block back to its obligation, flags cache hits,
and localizes failures to block indices.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, fields
from typing import Dict, List, Optional

MODEL_REPORT_SCHEMA = 1

VERDICTS = ("certificate", "refinement_error", "unexpected_relation",
            "error")


@dataclass
class BlockResult:
    """One model block's outcome (resolved through the dedup cache)."""
    index: int
    name: str                    # "embed" | "layer3" | "head"
    kind: str                    # obligation kind
    obligation: str              # canonical obligation key
    verdict: str                 # nested report's verdict
    cached: bool                 # True if another block already verified it
    seam_ok: bool                # inferred R_o == spec-promised relation

    def to_json(self) -> dict:
        return {f.name: getattr(self, f.name) for f in fields(self)}


@dataclass
class ModelReport:
    """Whole-model refinement verdict for (model, plan)."""
    model: str
    plan: str
    verdict: str                         # one of VERDICTS
    ok: bool                             # matches the run's expectation
    total_blocks: int
    unique_obligations: int
    dedup_ratio: float
    blocks: List[BlockResult]
    reports: Dict[str, dict]             # obligation key -> nested Report
                                         # JSON (+ "seams" detail)
    failing_blocks: List[int] = field(default_factory=list)
    bug: Optional[str] = None
    bug_layer: Optional[int] = None
    gs_ops_total: int = 0                # whole-model sequential op count
    wall_s: float = 0.0
    workers: int = 0
    cache: Optional[dict] = None         # persistent-cache stats (hits,
                                         # misses, entries) — timing-class
                                         # data, never in stable_summary
    pool: Optional[dict] = None          # runtime pool_stats() aggregate
                                         # (queue-wait vs on-worker wall)
                                         # — timing-class data, never in
                                         # stable_summary
    explanation: Optional[dict] = None   # proof-provenance roll-up
                                         # (``--explain`` only): per-
                                         # obligation step counts + lemma
                                         # sets; full chains stay on the
                                         # nested reports.  Omitted from
                                         # to_json when absent, never in
                                         # stable_summary
    schema_version: int = MODEL_REPORT_SCHEMA

    def __post_init__(self):
        if self.verdict not in VERDICTS:
            raise ValueError(f"verdict must be one of {VERDICTS}, "
                             f"got {self.verdict!r}")

    # -- serialization ------------------------------------------------------
    def to_json(self) -> dict:
        out = {f.name: getattr(self, f.name) for f in fields(self)
               if f.name != "blocks"}
        if out.get("explanation") is None:
            out.pop("explanation")
        out["blocks"] = [b.to_json() for b in self.blocks]
        out["timing"] = self.timing()
        return out

    @classmethod
    def from_json(cls, d: dict) -> "ModelReport":
        allowed = {f.name for f in fields(cls)}
        kw = {k: v for k, v in d.items() if k in allowed}
        kw["blocks"] = [BlockResult(**b) for b in d.get("blocks", ())]
        return cls(**kw)

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    # -- views --------------------------------------------------------------
    def timing(self) -> dict:
        """Per-phase wall time aggregated over the unique obligations."""
        phases: Dict[str, float] = {}
        infer_s = 0.0
        for rep in self.reports.values():
            stats = rep.get("stats") or {}
            infer_s += float(stats.get("time_s", 0.0))
            for k, v in (stats.get("phase_s") or {}).items():
                phases[k] = phases.get(k, 0.0) + float(v)
        return {
            "wall_s": round(self.wall_s, 6),
            "infer_s_sum": round(infer_s, 6),
            "phase_s_sum": {k: round(v, 6)
                            for k, v in sorted(phases.items())},
        }

    def stable_summary(self) -> dict:
        """Deterministic fields only — golden-diff material."""
        return {
            "verdict": self.verdict,
            "ok": self.ok,
            "total_blocks": self.total_blocks,
            "unique_obligations": self.unique_obligations,
            "failing_blocks": list(self.failing_blocks),
            "blocks": [{"name": b.name, "verdict": b.verdict,
                        "cached": b.cached, "seam_ok": b.seam_ok}
                       for b in self.blocks],
        }

    def to_markdown(self) -> str:
        lines = [
            f"### {self.model} @ {self.plan}"
            + (f" (bug={self.bug}@layer{self.bug_layer})" if self.bug
               else ""),
            "",
            "| # | block | obligation | verdict | cached | seam |",
            "|--:|-------|------------|---------|--------|------|",
        ]
        for b in self.blocks:
            lines.append(
                f"| {b.index} | {b.name} | {b.obligation} | {b.verdict} "
                f"| {'hit' if b.cached else '-'} "
                f"| {'ok' if b.seam_ok else '**MISMATCH**'} |")
        lines.append("")
        lines.append(
            f"**{self.verdict}** — {self.unique_obligations} unique "
            f"obligation(s) for {self.total_blocks} blocks "
            f"(dedup {self.dedup_ratio:.1f}x) in {self.wall_s:.2f}s.")
        if self.failing_blocks:
            lines.append(f"Failing blocks: {self.failing_blocks}.")
        return "\n".join(lines)

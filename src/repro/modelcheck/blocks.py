"""Block program library: per-block (G_s, G_d) pairs for whole-model checks.

Each builder returns an :class:`Obligation` pairing a sequential block
fragment with its per-rank SPMD implementation under a
:class:`repro.sharding.specs.MeshPlan`:

  * ``embed``      feature-sharded embedding gather + tp all_gather
  * ``layer``      pre-norm transformer block: RMSNorm -> multi-head
                   (masked, linear) attention with Megatron col/row-sharded
                   projections + tp psum -> residual -> RMSNorm -> GeGLU
                   MLP (col/row + psum) -> residual
  * ``moe_layer``  same attention sublayer; the MLP is an expert-parallel
                   soft-routed expert sum (experts sharded over tp)
  * ``head``       final RMSNorm + vocab-parallel logits (+ softcap)

Dimensions come from ``ModelConfig.reduced()`` — the engine is symbolic,
so verification cost is driven by operator count and mesh size, not tensor
extents; reduced extents keep jax tracing fast while every structural fact
(heads, pattern role, windowing, softcap, expert count) survives and is
part of the obligation's dedup fingerprint.

Attention is *linear* attention (scores are mask-weighted q.k^T without a
softmax): data-dependent renormalization is outside any symbolic engine's
fragment, while the sharded computation structure — head-split score/value
bmms, the causal/sliding-window mask, col/row projections and the
cross-rank psum — is exactly the part distribution strategies get wrong.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..sharding.specs import MeshPlan
from .obligations import Obligation

# default activation extents per block check: dp shards the batch dim
# (attention mixes across seq, so seq stays whole per rank)
BATCH = 4
SEQ = 4


class BlockBuildError(ValueError):
    pass


def _aval(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _check_div(what: str, n: int, axis: str, deg: int):
    if n % deg:
        raise BlockBuildError(
            f"{what} ({n}) not divisible by {axis} degree {deg}")


def reduced_dims(cfg: ModelConfig, plan: MeshPlan) -> dict:
    """Engine-sized dims for the block programs, divisibility-checked
    against the plan."""
    r = cfg.reduced(n_layers=cfg.n_layers)
    d = {
        "d_model": r.d_model, "n_heads": r.n_heads, "head_dim": r.hd,
        "d_ff": r.d_ff or 4 * r.d_model, "vocab": r.vocab,
        "n_experts": r.n_experts, "moe_d_ff": r.moe_d_ff or r.d_model,
        "window": max(r.window, 2) if cfg.window else 0,
        "eps": cfg.norm_eps, "softcap": bool(cfg.logit_softcap),
        "batch": BATCH, "seq": SEQ,
    }
    dp, tp = plan.axis("dp"), plan.axis("tp")
    _check_div("batch", d["batch"], "dp", dp)
    for k in ("d_model", "d_ff", "vocab"):
        _check_div(k, d[k], "tp", tp)
    _check_div("n_heads", d["n_heads"], "tp", tp)
    if d["n_experts"]:
        _check_div("n_experts", d["n_experts"], "tp", tp)
    return d


def _mask(role: str, S: int, window: int) -> np.ndarray:
    q = np.arange(S)[:, None]
    k = np.arange(S)[None, :]
    m = (k <= q)
    if role == "local" and window:
        m &= (q - k) < window
    return m.astype(np.float32)


def _rms(x, g, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * (1.0 + g)


def _attn(x, wq, wk, wv, wo, mask, hd):
    B, S, _ = x.shape
    q = (x @ wq).reshape(B, S, -1, hd)
    k = (x @ wk).reshape(B, S, -1, hd)
    v = (x @ wv).reshape(B, S, -1, hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * mask[None, None]
    y = jnp.einsum("bhqk,bkhd->bqhd", s, v)
    return y.reshape(B, S, -1) @ wo


# ---------------------------------------------------------------------------
# embed
# ---------------------------------------------------------------------------

def embed_obligation(cfg: ModelConfig, plan: MeshPlan) -> Obligation:
    d = reduced_dims(cfg, plan)
    B, S, V, D = d["batch"], d["seq"], d["vocab"], d["d_model"]
    tp = "tp" if plan.axis("tp") > 1 else None

    def seq_fn(tokens, table):
        return jnp.take(table, tokens, axis=0)

    def dist_fn(tokens, table):
        x = jnp.take(table, tokens, axis=0)
        if tp:
            x = jax.lax.all_gather(x, tp, axis=2, tiled=True)
        return x

    return Obligation(
        kind="embed", seq_fn=seq_fn, dist_fn=dist_fn,
        mesh_axes=plan.axes,
        in_specs=(plan.spec_for(("batch", "seq")),
                  plan.spec_for(("vocab_rows", "embed_tp"))),
        out_specs=(plan.spec_for(("batch", "seq", "embed")),),
        avals=(_aval((B, S), jnp.int32), _aval((V, D))),
        input_names=("tokens", "table"),
        structure=(("B", B), ("S", S), ("V", V), ("D", D)),
        description="feature-sharded embedding gather (+ tp all_gather)")


# ---------------------------------------------------------------------------
# transformer / MoE layer
# ---------------------------------------------------------------------------

def layer_obligation(cfg: ModelConfig, plan: MeshPlan, role: str = "global",
                     moe: bool = False,
                     bug: Optional[str] = None) -> Obligation:
    d = reduced_dims(cfg, plan)
    B, S = d["batch"], d["seq"]
    D, H, hd = d["d_model"], d["n_heads"], d["head_dim"]
    F, eps, window = d["d_ff"], d["eps"], d["window"]
    E, FE = d["n_experts"], d["moe_d_ff"]
    tp_deg = plan.axis("tp")
    tp = "tp" if tp_deg > 1 else None
    mask = _mask(role, S, window)
    if moe and not E:
        raise BlockBuildError(f"{cfg.name}: moe block without experts")

    def attn_sub(x, g1, wq, wk, wv, wo, *, dist):
        a = _attn(_rms(x, g1, eps), wq, wk, wv, wo, mask, hd)
        if dist and tp:
            a = jax.lax.psum(a, tp)
        return x + a

    def mlp_sub(x, g2, wg, wu, wd, *, dist):
        h = _rms(x, g2, eps)
        m = (jax.nn.silu(h @ wg) * (h @ wu)) @ wd
        if dist and tp:
            m = jax.lax.psum(m, tp)
        return x + m

    def moe_sub(x, g2, w1, w2, *, dist):
        h = _rms(x, g2, eps)
        n_local = w1.shape[0]
        m = None
        for e in range(n_local):
            y = jnp.tanh(h @ w1[e]) @ w2[e]
            m = y if m is None else m + y
        if dist and tp:
            m = jax.lax.psum(m, tp)
        return x + m

    if moe:
        def seq_fn(x, g1, wq, wk, wv, wo, g2, w1, w2):
            x = attn_sub(x, g1, wq, wk, wv, wo, dist=False)
            return moe_sub(x, g2, w1, w2, dist=False)

        def dist_fn(x, g1, wq, wk, wv, wo, g2, w1, w2):
            x = attn_sub(x, g1, wq, wk, wv, wo, dist=True)
            return moe_sub(x, g2, w1, w2, dist=True)

        mlp_names = ("w1", "w2")
        mlp_avals = (_aval((E, D, FE)), _aval((E, FE, D)))
        mlp_logical = [("experts", "embed", "expert_ff"),
                       ("experts", "expert_ff", "embed")]
    else:
        def seq_fn(x, g1, wq, wk, wv, wo, g2, wg, wu, wd):
            x = attn_sub(x, g1, wq, wk, wv, wo, dist=False)
            return mlp_sub(x, g2, wg, wu, wd, dist=False)

        def dist_fn(x, g1, wq, wk, wv, wo, g2, wg, wu, wd):
            x = attn_sub(x, g1, wq, wk, wv, wo, dist=True)
            return mlp_sub(x, g2, wg, wu, wd, dist=True)

        mlp_names = ("wg", "wu", "wd")
        mlp_avals = (_aval((D, F)), _aval((D, F)), _aval((F, D)))
        mlp_logical = [("embed", "ff"), ("embed", "ff"), ("ff", "embed")]

    logical = [("batch", "seq", "embed"),                # x
               ("embed",),                               # g1
               ("embed", "heads"), ("embed", "kv_heads"),
               ("embed", "kv_heads"), ("heads", "embed"),
               ("embed",)] + mlp_logical                 # g2 + mlp weights
    in_specs = [plan.spec_for(ax) for ax in logical]
    if bug == "wrong_spec":
        # the injected whole-model bug: the MLP down-projection's partition
        # spec names the wrong mesh axis — its first (sharded) dim is split
        # over dp instead of tp, so every tp group computes with dp-sliced
        # weight rows while still psum-ing over tp
        if plan.axis("dp") != tp_deg or tp is None:
            raise BlockBuildError(
                "wrong_spec needs a 2D plan with equal dp/tp degrees "
                "(the mis-sharded weight must keep its per-rank shape)")
        from jax.sharding import PartitionSpec as P
        in_specs[-1] = P("dp", *([None] * (len(mlp_avals[-1].shape) - 1)))
    avals = (_aval((B, S, D)), _aval((D,)), _aval((D, H * hd)),
             _aval((D, H * hd)), _aval((D, H * hd)), _aval((H * hd, D)),
             _aval((D,))) + mlp_avals
    names = ("x", "g1", "wq", "wk", "wv", "wo", "g2") + mlp_names

    return Obligation(
        kind="moe_block" if moe else "block",
        seq_fn=seq_fn, dist_fn=dist_fn, mesh_axes=plan.axes,
        in_specs=tuple(in_specs),
        out_specs=(plan.spec_for(("batch", "seq", "embed")),),
        avals=avals, input_names=names,
        structure=(("role", role), ("window", window if role == "local"
                                    else 0),
                   ("eps", eps), ("bug", bug or "-")),
        description=("expert-parallel MoE block" if moe else
                     f"transformer block ({role} attention)"))


# ---------------------------------------------------------------------------
# head
# ---------------------------------------------------------------------------

def head_obligation(cfg: ModelConfig, plan: MeshPlan) -> Obligation:
    d = reduced_dims(cfg, plan)
    B, S, D, V = d["batch"], d["seq"], d["d_model"], d["vocab"]
    eps, softcap = d["eps"], d["softcap"]

    def fwd(x, g, wun):
        logits = _rms(x, g, eps) @ wun
        if softcap:
            logits = jnp.tanh(logits / 30.0) * 30.0
        return logits

    return Obligation(
        kind="head", seq_fn=fwd, dist_fn=fwd, mesh_axes=plan.axes,
        in_specs=(plan.spec_for(("batch", "seq", "embed")),
                  plan.spec_for(("embed",)),
                  plan.spec_for(("embed", "vocab"))),
        out_specs=(plan.spec_for(("batch", "seq", "vocab")),),
        avals=(_aval((B, S, D)), _aval((D,)), _aval((D, V))),
        input_names=("x", "g", "wun"),
        structure=(("eps", eps), ("softcap", softcap)),
        description="final RMSNorm + vocab-parallel logits"
                    + (" (softcap)" if softcap else ""))

"""Distribution-strategy case suite for GraphGuard verification.

``repro.dist.strategies`` holds the paper-§6 workload builders: each case
pairs a sequential model fragment (G_s) with its shard_map distributed
implementation (G_d) plus the mesh/spec metadata needed to derive R_i, and
``BUG_CASES`` injects the six real-world bug classes of the §6.2 case study.
"""
from . import strategies

__all__ = ["strategies"]

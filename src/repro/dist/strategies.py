"""Distribution-strategy case suite (paper §6 workloads + §6.2 bug study,
plus the FSDP/ZeRO, pipeline-parallel, and 2D-mesh families from the
bug-study literature in PAPERS.md).

Each builder is registered with ``@register_strategy`` and returns a typed
:class:`repro.api.StrategySpec` carrying:

  seq_fn       the sequential model fragment G_s (plain jax function)
  dist_fn      the per-rank SPMD implementation, traced under ``shard_map``
               by ``capture_spmd`` (collectives allowed)
  mesh_axes    {axis name: parallelism degree}
  in_specs     ``PartitionSpec`` per input — ``derive_input_relation`` turns
               these into R_i
  avals        ``ShapeDtypeStruct`` per (global) input
  input_names  logical input names

plus registry-stamped metadata (case name, degree, bug, expected verdict).
Specs still unpack as the legacy 6-tuple for older call sites.

``bug=<name>`` injects one of the ten real-world bug classes (paper §6.2
plus the FSDP/pipeline/2D-mesh studies) into the distributed side.  Each bug is declared on its host case as a
``BugSpec`` whose ``expected`` states how detection surfaces:
``refinement_error`` (localized raise) or ``unexpected_relation`` (paper
bug 5 — a clean but unexpected certificate the user inspects).  The two
documented completeness gaps are ``expected="incomplete"`` on the clean
case itself (sound false alarm — see EXPERIMENTS.md §Gaps).

Sizes are deliberately small: verification cost is driven by operator count
and parallelism degree, not tensor extents (the engine is symbolic).

``STRATEGY_CASES`` / ``BUG_CASES`` remain as read-only views for legacy
callers; the registry (``repro.api.list_strategies``/``list_bugs``) is the
source of truth.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..api.registry import register_strategy
from ..api.spec import BugSpec, StrategySpec, axis_degrees


def _aval(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


# ---------------------------------------------------------------------------
# tp_layer — Megatron-style tensor-parallel MLP block
# ---------------------------------------------------------------------------

@register_strategy("tp_layer", degrees=(2, 4, 8),
                   description="Megatron TP MLP (col/row-parallel W1/W2)")
def tp_transformer_layer(degree: int = 2, bug=None, seq: int = 4,
                         d_model: int = 8, d_ff: int = 8):
    """Column-parallel W1, row-parallel W2, psum to assemble the output.
    The canonical TP pattern (paper Fig. 2): the k-split matmul pairs with
    the psum expansion to an add over the rank group."""
    assert d_ff % degree == 0

    def seq_fn(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    def dist_fn(x, w1, w2):
        h = jnp.tanh(x @ w1)          # x replicated, w1 column shard
        yp = h @ w2                   # w2 row shard -> partial sums
        return jax.lax.psum(yp, "tp")

    return StrategySpec(
        seq_fn, dist_fn, {"tp": degree},
        (P(), P(None, "tp"), P("tp", None)),
        (_aval((seq, d_model)), _aval((d_model, d_ff)),
         _aval((d_ff, d_model))),
        ("x", "w1", "w2"))


# ---------------------------------------------------------------------------
# sp_rope — sequence-parallel rotary position embedding
# ---------------------------------------------------------------------------

@register_strategy(
    "sp_rope", degrees=(2, 4, 8),
    bugs=[BugSpec("rope_offset", "refinement_error",
                  "every rank slices cos/sin at local positions (offset 0) "
                  "— the vLLM/Neuron bug class")],
    description="sequence-parallel rotary embedding (offset slices)")
def sp_rope_layer(degree: int = 2, bug=None, seq: int = 8, d_model: int = 8):
    """Rotary embedding under a sequence shard: each rank must slice the
    cos/sin tables at its *global* position offset (rank * chunk).
    Bug `rope_offset`: every rank uses local positions (offset 0) — the
    real-world vLLM/Neuron bug class from the paper's case study."""
    assert seq % degree == 0 and d_model % 2 == 0
    half = d_model // 2
    pos = np.arange(seq, dtype=np.float32)[:, None]
    inv = 1.0 / (10000.0 ** (np.arange(half, dtype=np.float32) / half))
    cos = np.cos(pos * inv).astype(np.float32)        # (S, half)
    sin = np.sin(pos * inv).astype(np.float32)
    chunk = seq // degree

    def seq_fn(x):
        x1, x2 = x[:, :half], x[:, half:]
        y1 = x1 * cos - x2 * sin
        y2 = x2 * cos + x1 * sin
        return jnp.concatenate([y1, y2], axis=1)

    def dist_fn(x):
        if bug == "rope_offset":
            start = 0                 # BUG: local positions on every rank
        else:
            start = jax.lax.axis_index("sp") * chunk
        c = jax.lax.dynamic_slice(cos, (start, 0), (chunk, half))
        s = jax.lax.dynamic_slice(sin, (start, 0), (chunk, half))
        x1, x2 = x[:, :half], x[:, half:]
        y1 = x1 * c - x2 * s
        y2 = x2 * c + x1 * s
        return jnp.concatenate([y1, y2], axis=1)

    return StrategySpec(seq_fn, dist_fn, {"sp": degree}, (P("sp", None),),
                        (_aval((seq, d_model)),), ("x",))


# ---------------------------------------------------------------------------
# sp_pad — pad-to-block then slice-off under a sequence shard
# ---------------------------------------------------------------------------

@register_strategy(
    "sp_pad", degrees=(2, 4, 8),
    bugs=[BugSpec("pad_slice", "refinement_error",
                  "the slice keeps padding rows and drops real tokens — "
                  "the pad/slice mismatch class")],
    description="pad-to-block + slice-off per rank")
def sp_pad_slice(degree: int = 2, bug=None, seq: int = 8, d_model: int = 4,
                 pad: int = 2):
    """Each rank pads its shard to a kernel block size, computes, then
    slices the padding back off. Bug `pad_slice`: the slice keeps the wrong
    rows (drops real tokens, keeps padding) — the paper's pad/slice
    mismatch class."""
    assert seq % degree == 0
    chunk = seq // degree

    def seq_fn(x):
        return jnp.tanh(x)

    def dist_fn(x):
        p = jnp.pad(x, ((0, pad), (0, 0)))
        h = jnp.tanh(p)
        if bug == "pad_slice":
            return h[pad:pad + chunk]     # BUG: off-by-pad slice
        return h[:chunk]

    return StrategySpec(seq_fn, dist_fn, {"sp": degree}, (P("sp", None),),
                        (_aval((seq, d_model)),), ("x",))


# ---------------------------------------------------------------------------
# ep_moe — expert-parallel MoE with pre-routed tokens
# ---------------------------------------------------------------------------

@register_strategy(
    "ep_moe", degrees=(2, 4, 8),
    bugs=[BugSpec("sharded_expert", "refinement_error",
                  "expert-to-shard mapping rotated via ppermute — each rank "
                  "applies its neighbour's expert weights")],
    description="expert-parallel MoE, pre-routed tokens")
def ep_moe_layer(degree: int = 2, bug=None, tokens: int = 4, d_model: int = 4):
    """Expert e lives on rank e; tokens arrive pre-sorted by expert, so the
    token shard on rank e is exactly expert e's batch. Bug `sharded_expert`:
    the expert-to-shard mapping is rotated (each rank applies its
    neighbour's expert weights via ppermute) — the paper's mis-sharded
    expert weight class."""
    n_exp = degree

    def seq_fn(x, w):
        outs = []
        for e in range(n_exp):
            xe = x[e * tokens:(e + 1) * tokens]
            outs.append(xe @ w[e])
        return jnp.concatenate(outs, axis=0)

    def dist_fn(x, w):
        we = w[0]                     # local expert shard (1, D, D) -> (D, D)
        if bug == "sharded_expert":
            we = jax.lax.ppermute(
                we, "ep", [(i, (i + 1) % n_exp) for i in range(n_exp)])
        return x @ we

    return StrategySpec(
        seq_fn, dist_fn, {"ep": degree},
        (P("ep", None), P("ep", None, None)),
        (_aval((n_exp * tokens, d_model)), _aval((n_exp, d_model, d_model))),
        ("x", "w"))


# ---------------------------------------------------------------------------
# aux_loss — auxiliary-loss normalization (documented completeness gap)
# ---------------------------------------------------------------------------

@register_strategy(
    # the n-ary add normal form collapsed degree 8 from ~8 s to
    # milliseconds, so the full sweep is registered
    "aux_loss", degrees=(2, 4, 8),
    bugs=[BugSpec("aux_scale", "refinement_error",
                  "each rank averages by its local element count before the "
                  "psum, inflating the loss by the parallelism degree")],
    description="aux-loss normalization (reduce-of-reshape + scalar factor)")
def aux_loss_scale(degree: int = 2, bug=None, seq: int = 8, d_model: int = 4):
    """Load-balancing-style scalar loss. The sequential side sums a
    *flattened* view while the distributed side reduces both axes at once —
    the ``reduce_reshape`` segment lemma relates the reduction across the
    reshape boundary and the constrained ``scalar_factor`` lemma lets the
    global ``/ n`` normalization chase per-rank pieces, so the correct
    implementation now certifies (this was a documented completeness gap
    until those two lemmas landed).
    Bug `aux_scale`: each rank averages by its *local* element count before
    the psum, inflating the loss by the parallelism degree — the paper's
    aux-loss mis-scaling class."""
    assert seq % degree == 0
    n = seq * d_model
    local_n = (seq // degree) * d_model

    def seq_fn(p):
        return jnp.sum(p.reshape(-1)) / n

    def dist_fn(p):
        loc = jnp.sum(p)
        if bug == "aux_scale":
            return jax.lax.psum(loc / local_n, "ep")   # BUG: degree x too big
        return jax.lax.psum(loc, "ep") / n

    return StrategySpec(seq_fn, dist_fn, {"ep": degree}, (P("ep", None),),
                        (_aval((seq, d_model)),), ("p",))


# ---------------------------------------------------------------------------
# sp_moe — sequence-parallel gated FFN stack (the fig5 scaling case)
# ---------------------------------------------------------------------------

@register_strategy("sp_moe", degrees=(2, 4, 8),
                   description="4x chained gated FFN, sequence-parallel")
def sp_moe_layer(degree: int = 2, bug=None, seq: int = 16, d_model: int = 8,
                 d_ff: int = 8):
    """Four chained gated-FFN blocks under a sequence shard with replicated
    weights. Pure row parallelism — no collectives — but every operator's
    relation is a degree-wide concat, so e-graph size and lemma work scale
    with the degree (paper Fig. 5's scaling axis), and the chained blocks
    give the relation chains realistic depth."""
    assert seq % degree == 0

    def block(x, wg, w1, w2):
        h = jnp.tanh(x @ w1)
        g = jax.nn.sigmoid(x @ wg)
        return (h * g) @ w2

    def seq_fn(x, wg, w1, w2):
        u = x
        for _ in range(4):
            u = block(u, wg, w1, w2)
        return u

    dist_fn = seq_fn                  # same per-rank program, sharded inputs

    return StrategySpec(
        seq_fn, dist_fn, {"sp": degree},
        (P("sp", None), P(), P(), P()),
        (_aval((seq, d_model)), _aval((d_model, d_ff)),
         _aval((d_model, d_ff)), _aval((d_ff, d_model))),
        ("x", "wg", "w1", "w2"))


# ---------------------------------------------------------------------------
# grad_accum — microbatch gradient accumulation (gap closed by dus_concat)
# ---------------------------------------------------------------------------

@register_strategy(
    "grad_accum", degrees=(2, 4),
    bugs=[BugSpec("grad_accum", "refinement_error",
                  "final normalization divides by the per-rank element "
                  "count — accumulated gradients n_steps x too large")],
    description="microbatch grad accumulation (dus scatter buffer)")
def grad_accum_step(degree: int = 2, bug=None, batch: int = 8,
                    d_model: int = 4):
    """Data-parallel gradient step with per-rank microbatch accumulation
    into a scatter buffer (dynamic_update_slice), then a psum and a global
    normalization. The buffer-scatter accumulation certifies via the
    constrained ``dus_concat`` lemma (a complete dus chain over a zero-init
    buffer is the concat of its updates) — this was a documented
    completeness gap until that lemma landed.
    Bug `grad_accum`: the final normalization divides by the per-rank
    element count instead of the global batch — the HF-regression class
    where accumulated gradients come out n_steps x too large."""
    assert batch % (2 * degree) == 0
    local = batch // degree
    half = local // 2

    def seq_fn(x):
        return jnp.sum(x, axis=0) / batch

    def dist_fn(x):
        g1 = jnp.sum(x[:half], axis=0)
        g2 = jnp.sum(x[half:], axis=0)
        buf = jnp.zeros((2, x.shape[1]), x.dtype)
        buf = jax.lax.dynamic_update_slice(buf, g1[None], (0, 0))
        buf = jax.lax.dynamic_update_slice(buf, g2[None], (1, 0))
        acc = jnp.sum(buf, axis=0)
        tot = jax.lax.psum(acc, "dp")
        denom = local if bug == "grad_accum" else batch   # BUG: missing 1/deg
        return tot / denom

    return StrategySpec(seq_fn, dist_fn, {"dp": degree}, (P("dp", None),),
                        (_aval((batch, d_model)),), ("x",))


# ---------------------------------------------------------------------------
# ln_grad — layer-norm weight gradient under sequence parallelism
# ---------------------------------------------------------------------------

@register_strategy(
    "ln_grad", degrees=(2, 4, 8),
    bugs=[BugSpec("ln_no_allreduce", "unexpected_relation",
                  "the psum is skipped — no raise, but the certificate is a "
                  "cross-rank add instead of an identity map (paper bug 5)")],
    description="layer-norm weight grad over sharded seq")
def ln_weight_grad(degree: int = 2, bug=None, seq: int = 8, d_model: int = 4):
    """The weight-gradient reduction of a norm layer: sum over the (sharded)
    sequence axis needs a cross-rank all-reduce. Bug `ln_no_allreduce`
    (paper bug 5): the psum is skipped. No error is raised — the inferred
    R_o is clean but *unexpected* (a cross-rank add instead of an identity
    map), which is how the paper reports the user caught it."""
    assert seq % degree == 0

    def seq_fn(dy, xhat):
        return jnp.sum(dy * xhat, axis=0)

    def dist_fn(dy, xhat):
        loc = jnp.sum(dy * xhat, axis=0)
        if bug == "ln_no_allreduce":
            return loc                # BUG: per-rank partial, no all-reduce
        return jax.lax.psum(loc, "sp")

    return StrategySpec(
        seq_fn, dist_fn, {"sp": degree}, (P("sp", None), P("sp", None)),
        (_aval((seq, d_model)), _aval((seq, d_model))),
        ("dy", "xhat"))


# ---------------------------------------------------------------------------
# fsdp_mlp — ZeRO-3-style fully-sharded MLP (weight gather + grad scatter)
# ---------------------------------------------------------------------------

@register_strategy(
    # degree 8 certifies in ~3 s (was ~21 s before the n-ary add normal
    # form) — reachable via --degrees 8, kept off the default sweep so the
    # matrix stays sub-second
    "fsdp_mlp", degrees=(2, 4),
    bugs=[BugSpec("stale_shard", "refinement_error",
                  "the forward uses the local W1 shard tiled degree times "
                  "instead of the all_gather — the stale/ungathered "
                  "parameter class of ZeRO-3 implementations"),
          BugSpec("rs_wrong_axis", "unexpected_relation",
                  "the gradient reduce_scatter splits the wrong dimension — "
                  "no raise, but R_o assembles grad shards along dim 1 "
                  "instead of dim 0 (paper bug 5 detection mode)")],
    description="ZeRO-3 FSDP MLP: all_gather weights, reduce_scatter grads")
def fsdp_mlp_layer(degree: int = 2, bug=None, batch: int = 8,
                   d_model: int = 8, d_ff: int = 8):
    """ZeRO-3-style fully-sharded MLP step: every parameter lives sharded on
    dim 0 across the data-parallel group; the forward all_gathers W1/W2
    before compute, and the (pseudo-)weight gradient of W2 is
    reduce_scattered back so each rank keeps exactly its shard's gradient.
    Outputs: the batch-sharded activation and the rank-local grad shard.
    Bug `stale_shard`: the forward skips the W1 gather and tiles the local
    shard — the stale/ungathered parameter class. Bug `rs_wrong_axis`: the
    reduce_scatter splits dim 1 instead of dim 0 — clean certificate, but
    R_o concatenates grad shards along the wrong axis (paper bug 5)."""
    assert batch % degree == 0 and d_model % degree == 0 \
        and d_ff % degree == 0

    def seq_fn(x, w1, w2):
        h = jnp.tanh(x @ w1)
        y = h @ w2
        gw2 = h.T @ y                 # pseudo-gradient of w2
        return y, gw2

    def dist_fn(x, w1s, w2s):
        if bug == "stale_shard":
            w1 = jnp.concatenate([w1s] * degree, axis=0)   # BUG: no gather
        else:
            w1 = jax.lax.all_gather(w1s, "dp", axis=0, tiled=True)
        w2 = jax.lax.all_gather(w2s, "dp", axis=0, tiled=True)
        h = jnp.tanh(x @ w1)
        y = h @ w2
        gw2_partial = h.T @ y
        sd = 1 if bug == "rs_wrong_axis" else 0            # BUG: wrong dim
        gw2s = jax.lax.psum_scatter(gw2_partial, "dp", scatter_dimension=sd,
                                    tiled=True)
        return y, gw2s

    return StrategySpec(
        seq_fn, dist_fn, {"dp": degree},
        (P("dp", None), P("dp", None), P("dp", None)),
        (_aval((batch, d_model)), _aval((d_model, d_ff)),
         _aval((d_ff, d_model))),
        ("x", "w1", "w2"))


# ---------------------------------------------------------------------------
# pp_stage — pipeline-parallel stage chain with microbatch hand-offs
# ---------------------------------------------------------------------------

@register_strategy(
    "pp_stage", degrees=(2, 4),
    bugs=[BugSpec("drop_microbatch", "refinement_error",
                  "the hand-off loop feeds microbatch 0 into the last "
                  "microbatch's slot — one microbatch of work is silently "
                  "dropped from the schedule")],
    description="pipeline-parallel stage chain, microbatch ppermute relay")
def pp_stage_block(degree: int = 2, bug=None, batch: int = 4,
                   d_model: int = 4, n_micro: int = 2):
    """GPipe-style pipeline: stage s's weight lives on rank s (the stacked
    weight tensor is sharded on its leading stage axis), the input is
    replicated, and each microbatch's activation is relayed rank-to-rank
    with ``ppermute`` after every stage — so the last rank's accumulated
    microbatch outputs are exactly the sequential chain, and R_o is the
    single-rank projection ``y = out@pp{n-1}``. Bug `drop_microbatch`: the
    relay loop reads microbatch 0 again in the last slot, dropping the
    final microbatch — the paper bug studies' lost-microbatch schedule
    class."""
    assert batch % n_micro == 0
    mb = batch // n_micro
    n_stage = degree

    def seq_fn(x, w):
        h = x
        for s in range(n_stage):
            h = jnp.tanh(h @ w[s])
        return h

    def dist_fn(x, w):
        wloc = w[0]                   # this rank's stage weight (stage shard)
        outs = []
        for m in range(n_micro):
            src = 0 if (bug == "drop_microbatch" and m == n_micro - 1) \
                else m                # BUG: last slot re-reads microbatch 0
            h = jax.lax.dynamic_slice(x, (src * mb, 0), (mb, d_model))
            for s in range(n_stage):
                h = jnp.tanh(h @ wloc)
                if s < n_stage - 1:   # relay activation to the next stage
                    h = jax.lax.ppermute(
                        h, "pp", [(i, i + 1) for i in range(n_stage - 1)])
            outs.append(h)
        return jnp.concatenate(outs, axis=0)

    return StrategySpec(
        seq_fn, dist_fn, {"pp": degree},
        (P(), P("pp", None, None)),
        (_aval((batch, d_model)), _aval((n_stage, d_model, d_model))),
        ("x", "w"))


# ---------------------------------------------------------------------------
# tp_dp_2d — composed 2D mesh: Megatron TP x data parallelism
# ---------------------------------------------------------------------------

@register_strategy(
    # (4, 4) — a 16-rank mesh whose multi-axis psum is a 16-wide add
    # chain — certifies in milliseconds under the n-ary add normal form
    # (it used to blow up assoc/comm saturation and false-alarm)
    "tp_dp_2d", degrees=((2, 2), (2, 4), (4, 2), (4, 4)),
    bugs=[BugSpec("psum_wrong_axis", "refinement_error",
                  "the output all-reduce runs over the dp mesh axis instead "
                  "of tp — partial sums are combined across batch shards")],
    description="2D mesh (dp x tp) Megatron MLP, multi-axis psum")
def tp_dp_2d_mlp(degree=(2, 2), bug=None, seq: int = 4, d_model: int = 8,
                 d_ff: int = 8):
    """The Megatron MLP composed with data parallelism on a 2D mesh
    ``{"dp": d_dp, "tp": d_tp}``: the batch is sharded over dp, W1/W2 are
    col/row-sharded over tp and replicated over dp. Every input relation is
    multi-mapping (one concat per replica coordinate on the unused axis),
    the scalar loss is a *multi-axis* ``psum`` over ``("dp", "tp")``, and
    the row-parallel output needs the tp-group psum — exercising
    ``concat_inject`` (shard-replica equality) and ``reduce_add``
    (reduce/psum exchange). ``degree`` may be an int (both axes) or a
    per-axis ``(d_dp, d_tp)`` tuple. Bug `psum_wrong_axis`: the output
    all-reduce runs over dp instead of tp, combining partial sums across
    batch shards — the composed-mesh wrong-axis collective class."""
    d_dp, d_tp = axis_degrees(degree, 2)
    assert seq % d_dp == 0 and d_ff % d_tp == 0

    def seq_fn(x, w1, w2):
        y = jnp.tanh(x @ w1) @ w2
        return y, jnp.sum(y)

    def dist_fn(x, w1, w2):
        h = jnp.tanh(x @ w1)          # x: dp batch shard, w1: tp col shard
        yp = h @ w2                   # w2: tp row shard -> partial sums
        axis = "dp" if bug == "psum_wrong_axis" else "tp"   # BUG: wrong axis
        y = jax.lax.psum(yp, axis)
        tot = jax.lax.psum(jnp.sum(yp), ("dp", "tp"))       # multi-axis psum
        return y, tot

    return StrategySpec(
        seq_fn, dist_fn, {"dp": d_dp, "tp": d_tp},
        (P("dp", None), P(None, "tp"), P("tp", None)),
        (_aval((seq, d_model)), _aval((d_model, d_ff)),
         _aval((d_ff, d_model))),
        ("x", "w1", "w2"))


# ---------------------------------------------------------------------------
# legacy views (source of truth: the repro.api registry)
# ---------------------------------------------------------------------------

from ..api.registry import get_strategy as _get, list_bugs as _list_bugs, \
    list_strategies as _list_strategies  # noqa: E402 — after registration

STRATEGY_CASES = {name: _get(name).builder for name in _list_strategies()}

# bug name -> (host case builder, detection raises RefinementError?)
# False = paper bug 5 style: certificate is produced but its relation is not
# the one the user expects (inspected, not raised).
BUG_CASES = {bug: (_get(host).builder, bspec.raises)
             for bug, (host, bspec) in _list_bugs().items()}

"""Distribution-strategy case suite (paper §6 workloads + §6.2 bug study).

Each builder returns ``(seq_fn, dist_fn, mesh_axes, in_specs, avals, names)``:

  seq_fn     the sequential model fragment G_s (plain jax function)
  dist_fn    the per-rank SPMD implementation, traced under ``shard_map``
             by ``capture_spmd`` (collectives allowed)
  mesh_axes  {axis name: parallelism degree}
  in_specs   ``PartitionSpec`` per input — ``derive_input_relation`` turns
             these into R_i
  avals      ``ShapeDtypeStruct`` per (global) input
  names      logical input names

``bug=<name>`` injects one of the six real-world bug classes (paper §6.2)
into the distributed side; ``BUG_CASES`` maps each bug to its host case and
whether detection surfaces as a ``RefinementError`` (True) or as an
unexpected-but-clean certificate the user inspects (False — paper bug 5).

Sizes are deliberately small: verification cost is driven by operator count
and parallelism degree, not tensor extents (the engine is symbolic).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _aval(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


# ---------------------------------------------------------------------------
# tp_layer — Megatron-style tensor-parallel MLP block
# ---------------------------------------------------------------------------

def tp_transformer_layer(degree: int = 2, bug=None, seq: int = 4,
                         d_model: int = 8, d_ff: int = 8):
    """Column-parallel W1, row-parallel W2, psum to assemble the output.
    The canonical TP pattern (paper Fig. 2): the k-split matmul pairs with
    the psum expansion to an add over the rank group."""
    assert d_ff % degree == 0

    def seq_fn(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    def dist_fn(x, w1, w2):
        h = jnp.tanh(x @ w1)          # x replicated, w1 column shard
        yp = h @ w2                   # w2 row shard -> partial sums
        return jax.lax.psum(yp, "tp")

    axes = {"tp": degree}
    specs = [P(), P(None, "tp"), P("tp", None)]
    avals = [_aval((seq, d_model)), _aval((d_model, d_ff)),
             _aval((d_ff, d_model))]
    return seq_fn, dist_fn, axes, specs, avals, ["x", "w1", "w2"]


# ---------------------------------------------------------------------------
# sp_rope — sequence-parallel rotary position embedding
# ---------------------------------------------------------------------------

def sp_rope_layer(degree: int = 2, bug=None, seq: int = 8, d_model: int = 8):
    """Rotary embedding under a sequence shard: each rank must slice the
    cos/sin tables at its *global* position offset (rank * chunk).
    Bug `rope_offset`: every rank uses local positions (offset 0) — the
    real-world vLLM/Neuron bug class from the paper's case study."""
    assert seq % degree == 0 and d_model % 2 == 0
    half = d_model // 2
    pos = np.arange(seq, dtype=np.float32)[:, None]
    inv = 1.0 / (10000.0 ** (np.arange(half, dtype=np.float32) / half))
    cos = np.cos(pos * inv).astype(np.float32)        # (S, half)
    sin = np.sin(pos * inv).astype(np.float32)
    chunk = seq // degree

    def seq_fn(x):
        x1, x2 = x[:, :half], x[:, half:]
        y1 = x1 * cos - x2 * sin
        y2 = x2 * cos + x1 * sin
        return jnp.concatenate([y1, y2], axis=1)

    def dist_fn(x):
        if bug == "rope_offset":
            start = 0                 # BUG: local positions on every rank
        else:
            start = jax.lax.axis_index("sp") * chunk
        c = jax.lax.dynamic_slice(cos, (start, 0), (chunk, half))
        s = jax.lax.dynamic_slice(sin, (start, 0), (chunk, half))
        x1, x2 = x[:, :half], x[:, half:]
        y1 = x1 * c - x2 * s
        y2 = x2 * c + x1 * s
        return jnp.concatenate([y1, y2], axis=1)

    axes = {"sp": degree}
    specs = [P("sp", None)]
    return seq_fn, dist_fn, axes, specs, [_aval((seq, d_model))], ["x"]


# ---------------------------------------------------------------------------
# sp_pad — pad-to-block then slice-off under a sequence shard
# ---------------------------------------------------------------------------

def sp_pad_slice(degree: int = 2, bug=None, seq: int = 8, d_model: int = 4,
                 pad: int = 2):
    """Each rank pads its shard to a kernel block size, computes, then
    slices the padding back off. Bug `pad_slice`: the slice keeps the wrong
    rows (drops real tokens, keeps padding) — the paper's pad/slice
    mismatch class."""
    assert seq % degree == 0
    chunk = seq // degree

    def seq_fn(x):
        return jnp.tanh(x)

    def dist_fn(x):
        p = jnp.pad(x, ((0, pad), (0, 0)))
        h = jnp.tanh(p)
        if bug == "pad_slice":
            return h[pad:pad + chunk]     # BUG: off-by-pad slice
        return h[:chunk]

    axes = {"sp": degree}
    specs = [P("sp", None)]
    return seq_fn, dist_fn, axes, specs, [_aval((seq, d_model))], ["x"]


# ---------------------------------------------------------------------------
# ep_moe — expert-parallel MoE with pre-routed tokens
# ---------------------------------------------------------------------------

def ep_moe_layer(degree: int = 2, bug=None, tokens: int = 4, d_model: int = 4):
    """Expert e lives on rank e; tokens arrive pre-sorted by expert, so the
    token shard on rank e is exactly expert e's batch. Bug `sharded_expert`:
    the expert-to-shard mapping is rotated (each rank applies its
    neighbour's expert weights via ppermute) — the paper's mis-sharded
    expert weight class."""
    n_exp = degree

    def seq_fn(x, w):
        outs = []
        for e in range(n_exp):
            xe = x[e * tokens:(e + 1) * tokens]
            outs.append(xe @ w[e])
        return jnp.concatenate(outs, axis=0)

    def dist_fn(x, w):
        we = w[0]                     # local expert shard (1, D, D) -> (D, D)
        if bug == "sharded_expert":
            we = jax.lax.ppermute(
                we, "ep", [(i, (i + 1) % n_exp) for i in range(n_exp)])
        return x @ we

    axes = {"ep": degree}
    specs = [P("ep", None), P("ep", None, None)]
    avals = [_aval((n_exp * tokens, d_model)),
             _aval((n_exp, d_model, d_model))]
    return seq_fn, dist_fn, axes, specs, avals, ["x", "w"]


# ---------------------------------------------------------------------------
# aux_loss — auxiliary-loss normalization (documented completeness gap)
# ---------------------------------------------------------------------------

def aux_loss_scale(degree: int = 2, bug=None, seq: int = 8, d_model: int = 4):
    """Load-balancing-style scalar loss. The sequential side sums a
    *flattened* view while the distributed side reduces both axes at once —
    numerically identical, but relating a reduce-of-reshape to a multi-axis
    reduce is outside the lemma fragment, so even the correct implementation
    false-alarms (sound incompleteness, see EXPERIMENTS.md §Gaps).
    Bug `aux_scale`: each rank averages by its *local* element count before
    the psum, inflating the loss by the parallelism degree — the paper's
    aux-loss mis-scaling class."""
    assert seq % degree == 0
    n = seq * d_model
    local_n = (seq // degree) * d_model

    def seq_fn(p):
        return jnp.sum(p.reshape(-1)) / n

    def dist_fn(p):
        loc = jnp.sum(p)
        if bug == "aux_scale":
            return jax.lax.psum(loc / local_n, "ep")   # BUG: degree x too big
        return jax.lax.psum(loc, "ep") / n

    axes = {"ep": degree}
    specs = [P("ep", None)]
    return seq_fn, dist_fn, axes, specs, [_aval((seq, d_model))], ["p"]


# ---------------------------------------------------------------------------
# sp_moe — sequence-parallel gated FFN stack (the fig5 scaling case)
# ---------------------------------------------------------------------------

def sp_moe_layer(degree: int = 2, bug=None, seq: int = 16, d_model: int = 8,
                 d_ff: int = 8):
    """Four chained gated-FFN blocks under a sequence shard with replicated
    weights. Pure row parallelism — no collectives — but every operator's
    relation is a degree-wide concat, so e-graph size and lemma work scale
    with the degree (paper Fig. 5's scaling axis), and the chained blocks
    give the relation chains realistic depth."""
    assert seq % degree == 0

    def block(x, wg, w1, w2):
        h = jnp.tanh(x @ w1)
        g = jax.nn.sigmoid(x @ wg)
        return (h * g) @ w2

    def seq_fn(x, wg, w1, w2):
        u = x
        for _ in range(4):
            u = block(u, wg, w1, w2)
        return u

    dist_fn = seq_fn                  # same per-rank program, sharded inputs

    axes = {"sp": degree}
    specs = [P("sp", None), P(), P(), P()]
    avals = [_aval((seq, d_model)), _aval((d_model, d_ff)),
             _aval((d_model, d_ff)), _aval((d_ff, d_model))]
    return seq_fn, dist_fn, axes, specs, avals, ["x", "wg", "w1", "w2"]


# ---------------------------------------------------------------------------
# grad_accum — microbatch gradient accumulation (documented completeness gap)
# ---------------------------------------------------------------------------

def grad_accum_step(degree: int = 2, bug=None, batch: int = 8,
                    d_model: int = 4):
    """Data-parallel gradient step with per-rank microbatch accumulation
    into a scatter buffer (dynamic_update_slice), then a psum and a global
    normalization. The buffer-scatter accumulation is outside the clean
    fragment (no dus-to-concat lemma yet), so even the correct version
    false-alarms — documented gap, see EXPERIMENTS.md §Gaps.
    Bug `grad_accum`: the final normalization divides by the per-rank
    element count instead of the global batch — the HF-regression class
    where accumulated gradients come out n_steps x too large."""
    assert batch % (2 * degree) == 0
    local = batch // degree
    half = local // 2

    def seq_fn(x):
        return jnp.sum(x, axis=0) / batch

    def dist_fn(x):
        g1 = jnp.sum(x[:half], axis=0)
        g2 = jnp.sum(x[half:], axis=0)
        buf = jnp.zeros((2, x.shape[1]), x.dtype)
        buf = jax.lax.dynamic_update_slice(buf, g1[None], (0, 0))
        buf = jax.lax.dynamic_update_slice(buf, g2[None], (1, 0))
        acc = jnp.sum(buf, axis=0)
        tot = jax.lax.psum(acc, "dp")
        denom = local if bug == "grad_accum" else batch   # BUG: missing 1/deg
        return tot / denom

    axes = {"dp": degree}
    specs = [P("dp", None)]
    return seq_fn, dist_fn, axes, specs, [_aval((batch, d_model))], ["x"]


# ---------------------------------------------------------------------------
# ln_grad — layer-norm weight gradient under sequence parallelism
# ---------------------------------------------------------------------------

def ln_weight_grad(degree: int = 2, bug=None, seq: int = 8, d_model: int = 4):
    """The weight-gradient reduction of a norm layer: sum over the (sharded)
    sequence axis needs a cross-rank all-reduce. Bug `ln_no_allreduce`
    (paper bug 5): the psum is skipped. No error is raised — the inferred
    R_o is clean but *unexpected* (a cross-rank add instead of an identity
    map), which is how the paper reports the user caught it."""
    assert seq % degree == 0

    def seq_fn(dy, xhat):
        return jnp.sum(dy * xhat, axis=0)

    def dist_fn(dy, xhat):
        loc = jnp.sum(dy * xhat, axis=0)
        if bug == "ln_no_allreduce":
            return loc                # BUG: per-rank partial, no all-reduce
        return jax.lax.psum(loc, "sp")

    axes = {"sp": degree}
    specs = [P("sp", None), P("sp", None)]
    avals = [_aval((seq, d_model)), _aval((seq, d_model))]
    return seq_fn, dist_fn, axes, specs, avals, ["dy", "xhat"]


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------

STRATEGY_CASES = {
    "tp_layer": tp_transformer_layer,
    "sp_rope": sp_rope_layer,
    "sp_pad": sp_pad_slice,
    "ep_moe": ep_moe_layer,
    "aux_loss": aux_loss_scale,
    "sp_moe": sp_moe_layer,
    "grad_accum": grad_accum_step,
    "ln_grad": ln_weight_grad,
}

# bug name -> (host case builder, detection raises RefinementError?)
# False = paper bug 5 style: certificate is produced but its relation is not
# the one the user expects (inspected, not raised).
BUG_CASES = {
    "rope_offset": (sp_rope_layer, True),
    "aux_scale": (aux_loss_scale, True),
    "pad_slice": (sp_pad_slice, True),
    "sharded_expert": (ep_moe_layer, True),
    "grad_accum": (grad_accum_step, True),
    "ln_no_allreduce": (ln_weight_grad, False),
}

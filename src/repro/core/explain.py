"""Proof provenance: explainable certificates and failure frontiers.

With ``EGraph(explain=True)`` every union is journaled as an edge
``(root_a, root_b, reason)`` between its two pre-union roots (egg-style
explanations, Flatt et al.): each union joins exactly two components of the
edge graph, so two class ids are union-find-equal iff an edge path connects
them.  This module walks those paths to produce two artifacts:

* **Certificate chains** — for each G_s output, the step-by-step sequence of
  term rewrites ``seq_out = t_1 = t_2 = ... = R_o(dist_out)`` with the lemma
  (or congruence/definition) justifying each step.  Ids are quotiented by
  their *rendered term* (the creating e-node, recursively) so the chain is a
  path over distinct expressions, and BFS with canonically sorted adjacency
  makes it deterministic for a given set of recorded unions.
* **Failure frontiers** — when refinement gets stuck, the nearest proven
  equivalences around the stuck operator plus the lemmas that fired while
  processing it but did not close the goal, rendered as a narrative.

Every explanation carries a ``replay`` section (both graphs' defining
equations, the input relation, and const values) so ``check_explanation``
can re-validate the chain *outside* the e-graph: it evaluates both graphs on
seeded random inputs and checks each step's lhs/rhs numerically plus the
chain's connectivity — a tampered or fabricated step fails.  That makes the
explanation a machine-checkable proof object rather than a log.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import numpy as np

from .terms import Term, eval_term, pretty

SCHEMA = 1


# -- term (de)serialization ---------------------------------------------------

def term_to_obj(t: Term) -> dict:
    """JSON-safe structural form of a Term (attrs values are ints, floats,
    strings, or int tuples — tuples become lists in JSON and are restored
    by :func:`term_from_obj`)."""
    return {
        "op": t.op,
        "attrs": [[k, v] for k, v in t.attrs],
        "args": [term_to_obj(a) for a in t.args],
        "shape": list(t.shape),
        "dtype": t.dtype,
    }


def _tupled(v):
    if isinstance(v, (list, tuple)):
        return tuple(_tupled(x) for x in v)
    return v


def term_from_obj(o: dict) -> Term:
    """Rebuild a hash-consed Term from :func:`term_to_obj` output (accepts
    both in-memory and JSON-round-tripped forms)."""
    attrs = tuple((k, _tupled(v)) for k, v in o["attrs"])
    args = tuple(term_from_obj(a) for a in o["args"])
    return Term(o["op"], args, attrs, tuple(o["shape"]), o["dtype"])


def _reason_obj(reason: Optional[tuple]) -> dict:
    if reason is None:
        return {"kind": "merge"}
    kind = reason[0]
    if kind == "congruence":
        return {"kind": "congruence", "op": reason[1]}
    if len(reason) > 1:
        return {"kind": kind, "name": reason[1]}
    return {"kind": kind}


def _reason_key(reason: Optional[tuple]) -> tuple:
    return ("merge",) if reason is None else tuple(str(x) for x in reason)


def reason_label(robj: dict) -> str:
    """One-token human label for a step justification."""
    kind = robj.get("kind", "merge")
    detail = robj.get("name") or robj.get("op")
    return f"{kind} {detail}" if detail else kind


# -- proof-forest walking -----------------------------------------------------

def term_of(eg, cid: int, memo: dict) -> Term:
    """Render class ``cid`` as the Term built from its creating e-node,
    recursively (children ids are strictly smaller, so this is acyclic)."""
    t = memo.get(cid)
    if t is None:
        node, shape, dtype = eg.node_meta[cid]
        args = tuple(term_of(eg, c, memo) for c in node.children)
        t = Term(node.op, args, node.attrs, shape, dtype)
        memo[cid] = t
    return t


def edge_adjacency(eg) -> dict:
    """Quotient the journaled union edges by rendered term.

    Returns ``{Term: [(Term, reason_obj), ...]}`` with adjacency lists
    sorted by (neighbour sort_key, reason) and deduped, so BFS over it is
    deterministic for a given edge *set* regardless of recording order."""
    memo: dict = {}
    raw: dict = {}
    for a, b, reason in eg.explain_edges:
        u, v = term_of(eg, a, memo), term_of(eg, b, memo)
        if u is v:
            continue
        raw.setdefault(u, {})[(v.sort_key(), _reason_key(reason))] = (v, reason)
        raw.setdefault(v, {})[(u.sort_key(), _reason_key(reason))] = (u, reason)
    adj: dict = {}
    for u, nbrs in raw.items():
        adj[u] = [(v, _reason_obj(r))
                  for _k, (v, r) in sorted(nbrs.items(), key=lambda kv: kv[0])]
    return adj


def _bfs(adj: dict, start: Term):
    """Full BFS from ``start``: returns ({term: (prev, reason)}, {term: dist}).
    Deterministic given the sorted adjacency."""
    prev: dict = {start: None}
    dist: dict = {start: 0}
    q = deque([start])
    while q:
        u = q.popleft()
        for v, reason in adj.get(u, ()):
            if v not in prev:
                prev[v] = (u, reason)
                dist[v] = dist[u] + 1
                q.append(v)
    return prev, dist


def certificate_chain(eg, adj: dict, out_name: str, out_shape, out_dtype,
                      r_o_term: Term, leaf_ok) -> list:
    """The step list proving ``out_name ≡ r_o_term``.

    Walks the proof forest from the G_s output tensor to the first term
    (in BFS order) that is clean over allowed leaves — preferring the exact
    R_o term — then appends the final ``extract`` step when the endpoint is
    not literally R_o (extraction combines best sub-renderings across
    classes, so no single journaled vertex need equal it).  Every step,
    including ``extract``, is numerically validated by the replay checker.
    """
    from .terms import tensor as mk_tensor
    start = mk_tensor(out_name, out_shape, out_dtype)
    prev, dist = _bfs(adj, start)

    def clean_over(t: Term) -> bool:
        return t.is_clean() and all(
            l.op == "lit" or leaf_ok(l.name) for l in t.leaves())

    end = None
    if r_o_term in prev:
        end = r_o_term
    else:
        cands = [t for t in prev if t is not start and clean_over(t)]
        if cands:
            end = min(cands, key=lambda t: (dist[t], t.sort_key()))
    if end is None:
        # degenerate: no journaled vertex is clean — chain is the single
        # extraction step (still replay-checked numerically)
        path = [start]
    else:
        path = [end]
        while prev[path[-1]] is not None:
            u, reason = prev[path[-1]]
            path.append(u)
        path.reverse()

    steps = []
    for i in range(len(path) - 1):
        u, v = path[i], path[i + 1]
        _pu, reason = prev[v]
        steps.append(_step(u, v, reason))
    if path[-1] is not r_o_term:
        steps.append(_step(path[-1], r_o_term, {"kind": "extract"}))
    return steps


def _step(lhs: Term, rhs: Term, reason: dict) -> dict:
    return {"lhs": term_to_obj(lhs), "rhs": term_to_obj(rhs),
            "lhs_str": pretty(lhs, 999), "rhs_str": pretty(rhs, 999),
            "reason": reason}


# -- building explanations ----------------------------------------------------

def build_replay(gg) -> dict:
    """Everything the replay checker needs to re-validate a chain without
    the e-graph: both graphs' defs, the input relation, and const values."""
    gs, gd = gg.gs, gg.gd
    consts = {}
    for g in (gs, gd):
        for n, v in g.consts.items():
            a = np.asarray(v)
            consts[n] = {"shape": list(a.shape), "dtype": str(a.dtype),
                         "data": a.tolist()}
    return {
        "gd_inputs": [{"name": n, "shape": list(gd.shapes[n]),
                       "dtype": gd.dtypes[n]} for n in gd.inputs],
        "gd_defs": [[n, term_to_obj(t)] for n, t in gd.defs],
        "gs_defs": [[n, term_to_obj(t)] for n, t in gs.defs],
        "r_i": {n: [term_to_obj(e) for e in exprs]
                for n, exprs in sorted(gg.r_i.items())},
        "consts": consts,
    }


def build_certificate_explanation(gg, r_o: dict) -> dict:
    """Lemma chains for every R_o entry plus the replay payload."""
    eg = gg.eg
    adj = edge_adjacency(eg)
    out_names = set(gg.gd.outputs)
    leaf_ok = lambda n: n in out_names or n in gg.gd.consts
    outputs = {}
    lemmas_used: set = set()
    total = 0
    for o in sorted(r_o):
        shape = gg.gs.shapes.get(o, r_o[o].shape)
        dtype = gg.gs.dtypes.get(o, r_o[o].dtype)
        steps = certificate_chain(eg, adj, o, shape, dtype, r_o[o], leaf_ok)
        for s in steps:
            if s["reason"].get("kind") == "lemma":
                lemmas_used.add(s["reason"]["name"])
        outputs[o] = {"n_steps": len(steps), "steps": steps,
                      "target": pretty(r_o[o], 999)}
        total += len(steps)
    return {
        "kind": "certificate",
        "schema": SCHEMA,
        "outputs": outputs,
        "lemmas_used": sorted(lemmas_used),
        "total_steps": total,
        "replay": build_replay(gg),
    }


def build_failure_frontier(gg, op_index: int, op_name: str, out_name: str,
                           input_mappings: dict, diagnostic,
                           fired: dict) -> dict:
    """The frontier of failure around a stuck operator: nearest proven
    equivalences, lemmas that fired on this op without closing it, and the
    best non-clean candidate, as a step-by-step narrative."""
    proven = list(gg.relation.items())[-6:]
    fired = {k: fired[k] for k in sorted(fired) if fired[k] > 0}
    lines = [
        f"refinement stuck at G_s op #{op_index} `{op_name}` "
        f"(output `{out_name}`)",
    ]
    if proven:
        lines.append("frontier of proven equivalences nearest the stuck op:")
        for name, t in proven:
            lines.append(f"  {name} = {pretty(t, 999)}")
    if input_mappings:
        lines.append("input mappings at the frontier:")
        for k, v in input_mappings.items():
            lines.append(f"  {k} = {pretty(v, 999)}")
    if fired:
        lines.append("lemmas that fired on this op but did not close it: "
                     + ", ".join(f"{k} x{v}" for k, v in fired.items()))
    else:
        lines.append("no lemma fired while processing this op")
    if diagnostic is not None:
        expr, n_unclean = diagnostic
        lines.append(f"nearest candidate needs {n_unclean} non-clean op(s): "
                     f"{pretty(expr, 999)}")
    return {
        "kind": "failure_frontier",
        "schema": SCHEMA,
        "stuck_op": {"op_index": op_index, "op_name": op_name,
                     "out_name": out_name},
        "proven": {name: pretty(t, 999) for name, t in proven},
        "input_mappings": {k: pretty(v, 999)
                           for k, v in input_mappings.items()
                           if v is not None},
        "fired_no_close": fired,
        "diagnostic": None if diagnostic is None else
        {"expr": pretty(diagnostic[0], 999), "n_unclean": diagnostic[1]},
        "narrative": lines,
    }


# -- independent replay checking ----------------------------------------------

def _np_dtype(d: str):
    return {"f": np.float64, "i": np.int64, "b": np.bool_}.get(d, np.float64)


def _rand(rng, shape, dtype: str):
    shape = tuple(shape)
    if dtype == "i":
        # small non-negative ints: safe as gather indices into any table
        return rng.integers(0, 2, size=shape, dtype=np.int64)
    if dtype == "b":
        return rng.integers(0, 2, size=shape).astype(bool)
    return rng.standard_normal(shape)


def _values_close(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    if a.shape != b.shape:
        return False
    if a.dtype.kind in "ib" and b.dtype.kind in "ib":
        return bool(np.array_equal(a, b))
    return bool(np.allclose(np.asarray(a, dtype=np.float64),
                            np.asarray(b, dtype=np.float64),
                            rtol=1e-6, atol=1e-8))


def _alias_leaves(a: Term, b: Term, alias: dict):
    """Record that structurally-corresponding tensor leaves of two R_i
    expressions must carry equal values (replicated shards)."""
    if a.op == "tensor" and b.op == "tensor":
        ca, cb = _canon_name(a.name, alias), _canon_name(b.name, alias)
        if ca != cb:
            alias[cb] = ca
    elif a.op == b.op and len(a.args) == len(b.args):
        for x, y in zip(a.args, b.args):
            _alias_leaves(x, y, alias)


def _canon_name(n: str, alias: dict) -> str:
    while n in alias:
        n = alias[n]
    return n


def replay_env(replay: dict, seed: int = 0) -> dict:
    """Evaluate both graphs on seeded random G_d inputs; returns the full
    ``name -> ndarray`` environment every chain term can be read in.

    A G_s input with several R_i expressions (one per replica coordinate)
    constrains corresponding G_d leaves to be equal — replicated shards are
    generated once and shared, so the random environment actually satisfies
    R_i."""
    env: dict = {}
    rng = np.random.default_rng(seed)
    alias: dict = {}
    for n, objs in replay["r_i"].items():
        if len(objs) > 1:
            t0 = term_from_obj(objs[0])
            for o in objs[1:]:
                _alias_leaves(t0, term_from_obj(o), alias)
    for spec in replay["gd_inputs"]:
        c = _canon_name(spec["name"], alias)
        if c not in env:
            env[c] = _rand(rng, spec["shape"], spec["dtype"])
        env[spec["name"]] = env[c]
    for n, d in replay["consts"].items():
        env[n] = np.asarray(d["data"],
                            dtype=np.dtype(d["dtype"])).reshape(d["shape"])
    for n, t in replay["gd_defs"]:
        env[n] = eval_term(term_from_obj(t), env)
    for n, objs in replay["r_i"].items():
        if objs and n not in env:
            env[n] = eval_term(term_from_obj(objs[0]), env)
    for n, t in replay["gs_defs"]:
        env[n] = eval_term(term_from_obj(t), env)
    return env


def check_explanation(expl: dict, seed: int = 0) -> dict:
    """Re-validate a certificate explanation outside the e-graph.

    Checks, per output chain: (1) the chain starts at the output tensor,
    (2) consecutive steps connect (step i's rhs is step i+1's lhs), and
    (3) every step's lhs and rhs evaluate to the same value on seeded
    random inputs.  Returns ``{"ok", "checked_steps", "failures"}`` — any
    tampered, reordered, or fabricated step lands in ``failures``."""
    failures: list = []
    checked = 0
    if expl.get("kind") != "certificate":
        return {"ok": False, "checked_steps": 0,
                "failures": ["not a certificate explanation"]}
    try:
        env = replay_env(expl["replay"], seed=seed)
    except Exception as e:  # noqa: BLE001 - any replay failure is a finding
        return {"ok": False, "checked_steps": 0,
                "failures": [f"replay environment failed: {e!r}"]}
    for o, entry in sorted(expl["outputs"].items()):
        steps = entry["steps"]
        if not steps:
            failures.append(f"{o}: empty chain")
            continue
        first = term_from_obj(steps[0]["lhs"])
        if not (first.op == "tensor" and first.name == o):
            failures.append(f"{o}: chain does not start at the output tensor")
        for i, s in enumerate(steps):
            lhs, rhs = term_from_obj(s["lhs"]), term_from_obj(s["rhs"])
            if i + 1 < len(steps) \
                    and rhs is not term_from_obj(steps[i + 1]["lhs"]):
                failures.append(f"{o}: step {i} rhs != step {i + 1} lhs "
                                "(broken chain)")
            try:
                lv, rv = eval_term(lhs, env), eval_term(rhs, env)
            except Exception as e:  # noqa: BLE001
                failures.append(f"{o}: step {i} failed to evaluate: {e!r}")
                continue
            checked += 1
            if not _values_close(lv, rv):
                failures.append(
                    f"{o}: step {i} ({reason_label(s['reason'])}) does not "
                    f"hold numerically: {s['lhs_str']} != {s['rhs_str']}")
    return {"ok": not failures, "checked_steps": checked,
            "failures": failures}


# -- aggregation + rendering --------------------------------------------------

def aggregate_explanations(reports: dict) -> Optional[dict]:
    """Roll nested per-obligation explanations up into a family-report
    summary (counts + lemma sets; the full chains stay on the nested
    reports).  Returns None when no nested report carries one."""
    per: dict = {}
    total = 0
    for key in sorted(reports):
        rep = reports[key]
        expl = rep.get("explanation") if isinstance(rep, dict) else None
        if not expl:
            continue
        if expl.get("kind") == "certificate":
            per[key] = {
                "kind": "certificate",
                "steps": {o: e["n_steps"]
                          for o, e in sorted(expl["outputs"].items())},
                "lemmas_used": expl.get("lemmas_used", []),
            }
            total += expl.get("total_steps", 0)
        else:
            per[key] = {
                "kind": expl.get("kind"),
                "stuck_op": expl.get("stuck_op"),
                "fired_no_close": sorted(expl.get("fired_no_close") or {}),
            }
    if not per:
        return None
    return {"kind": "summary", "schema": SCHEMA,
            "per_obligation": per, "total_steps": total}


def explanation_steps(expl: Optional[dict]) -> int:
    """Total chain steps in any explanation shape (0 when absent)."""
    if not expl:
        return 0
    return int(expl.get("total_steps", 0))


def render_narrative(expl: dict) -> list:
    """Human-readable lines for an explanation (any kind)."""
    if expl.get("kind") == "failure_frontier":
        return list(expl.get("narrative", ()))
    if expl.get("kind") == "summary":
        lines = []
        for key, entry in sorted(expl.get("per_obligation", {}).items()):
            if entry.get("kind") == "certificate":
                steps = ", ".join(f"{o}: {n} step(s)"
                                  for o, n in sorted(entry["steps"].items()))
                lem = ", ".join(entry.get("lemmas_used") or ()) or "-"
                lines.append(f"{key}: proved ({steps}; lemmas: {lem})")
            else:
                stuck = entry.get("stuck_op") or {}
                fired = ", ".join(entry.get("fired_no_close") or ()) or "-"
                lines.append(
                    f"{key}: STUCK at op #{stuck.get('op_index')} "
                    f"`{stuck.get('op_name')}` (fired, did not close: "
                    f"{fired})")
        lines.append(f"total chain steps: {expl.get('total_steps', 0)}")
        return lines
    lines = []
    for o, entry in sorted(expl.get("outputs", {}).items()):
        lines.append(f"output `{o}`: {entry['n_steps']} step(s)")
        cur = None
        for s in entry["steps"]:
            if cur is None:
                lines.append(f"  {s['lhs_str']}")
            lines.append(f"    = [{reason_label(s['reason'])}] {s['rhs_str']}")
            cur = s["rhs_str"]
    if expl.get("lemmas_used"):
        lines.append("lemmas used: " + ", ".join(expl["lemmas_used"]))
    return lines

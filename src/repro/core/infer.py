"""GraphGuard relation inference (paper §4, Listings 1–3).

``check_refinement(gs, gd, r_i)`` processes each G_s operator in topological
order, maintaining a single e-graph in which every G_s tensor's class is
merged with its defining expression and (transitively) with equivalent
expressions over G_d tensors. Per operator it:

  1. installs the operator's defining equation (step 1 of Listing 2 — input
     substitution is implicit: inputs share classes with their mappings),
  2. saturates the lemma set (step 2),
  3. grows the related-subgraph frontier of G_d and installs the defining
     equations of newly-eligible G_d nodes (step 3, optimized per Listing 3),
  4. extracts a *clean* expression over G_d tensors for each output
     (step 4); failure raises ``RefinementError`` naming the operator —
     the paper's bug-localization output — and attaches the best non-clean
     candidate expression as a diagnostic (our extension: it shows *what
     computation would be required*, e.g. a leftover ``div`` for scaling
     bugs).

The result is a ``Certificate`` holding the complete clean output relation
R_o; ``Certificate.reconstruct`` replays it numerically (certificates are
executable — paper §3.1 'the user can use a complete R_o to translate
outputs from a deployed G_d').

Frontier growth (step 3) is indexed: each pending G_d def carries an
unmet-dependency count, and a map from leaf tensor name to waiting defs
lets a newly related tensor enqueue exactly the defs it unblocks —
O(new names) per call instead of rescanning every pending def.
``Certificate.stats`` carries per-phase wall time (saturate / rebuild /
frontier / extract) and engine counters from ``repro.core.profile``.
"""
from __future__ import annotations

import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from ..obs import trace as obs_trace
from ..obs.metrics import REGISTRY
from .capture import Graph
from .egraph import EGraph, EGraphLimit
from .explain import build_certificate_explanation, build_failure_frontier
from .lemmas import all_lemmas
from .profile import CONFIG, Profile, explain_enabled
from .terms import Term, eval_term, pretty


def is_dist_name(name: str) -> bool:
    """True for per-rank tensor names (carrying a ``@rank`` tag)."""
    return "@" in name


@dataclass
class Certificate:
    """A complete clean output relation R_o (soundness certificate)."""
    r_o: dict                      # G_s output name -> clean Term over G_d
    relation: dict                 # all G_s tensors -> clean Term (R)
    stats: dict
    # proof provenance (``explain=True`` only); deliberately NOT part of
    # ``to_json`` so certificate payloads stay byte-identical with it off
    explanation: Optional[dict] = field(default=None, repr=False,
                                        compare=False)

    def reconstruct(self, gd_env: dict) -> dict:
        """Rebuild G_s outputs from G_d tensor values (executable R_o)."""
        return {name: eval_term(expr, gd_env)
                for name, expr in self.r_o.items()}

    def to_json(self) -> dict:
        """JSON-safe view: full-depth stringified R_o + the stats dict.

        The ``repro.api`` Report layer builds on this; r_o strings use
        unbounded pretty-printing so certificates compare byte-identical
        across engine configurations and processes.
        """
        return {
            "r_o": {k: pretty(v, 999) for k, v in self.r_o.items()},
            "stats": self.stats,
        }


class RefinementError(Exception):
    """G_d does not (provably) refine G_s. Carries localization info."""

    def __init__(self, op_index: int, op_name: str, out_name: str,
                 input_mappings: dict, diagnostic: Optional[tuple],
                 message: str = ""):
        self.op_index = op_index
        self.op_name = op_name
        self.out_name = out_name
        self.input_mappings = input_mappings
        self.diagnostic = diagnostic
        self.explanation = None     # failure frontier (``explain=True`` only)
        lines = [
            f"refinement failed at G_s operator #{op_index} "
            f"`{op_name}` (output `{out_name}`)",
        ]
        if input_mappings:
            lines.append("input mappings found so far:")
            for k, v in input_mappings.items():
                lines.append(f"  {k} = {v}")
        if diagnostic is not None:
            expr, n_unclean = diagnostic
            lines.append(
                f"nearest candidate needs {n_unclean} non-clean op(s): {expr}")
            lines.append(
                "  -> reconstructing this output requires real computation; "
                "inspect the operators above for the missing/incorrect "
                "transformation (paper §6.2 debugging workflow)")
        if message:
            lines.append(message)
        super().__init__("\n".join(lines))

    def payload(self) -> dict:
        """JSON-safe localization payload (the paper's bug report, typed)."""
        out = {
            "op_index": self.op_index,
            "op_name": self.op_name,
            "out_name": self.out_name,
            "input_mappings": {k: pretty(v, 999)
                               for k, v in self.input_mappings.items()
                               if v is not None},
        }
        if self.diagnostic is not None:
            expr, n_unclean = self.diagnostic
            out["diagnostic"] = {"expr": pretty(expr, 999),
                                 "n_unclean": n_unclean}
        return out


@dataclass
class GraphGuard:
    """Iterative relation inference over (G_s, G_d, R_i)."""
    gs: Graph
    gd: Graph
    r_i: dict                       # G_s input name -> [Terms over G_d inputs]
    max_nodes: int = 400_000
    collect_lemma_stats: bool = True
    explain: Optional[bool] = None  # None -> GRAPHGUARD_EXPLAIN env default

    def __post_init__(self):
        self.explain = explain_enabled(self.explain)
        self.eg = EGraph(max_nodes=self.max_nodes, explain=self.explain)
        self.lemmas = all_lemmas()
        self.fire_counts: dict = {}
        self.profile = Profile()
        self.eg.profile = self.profile
        self.related: set = set()          # T_rel: related G_d tensor names
        self.gd_pending = list(self.gd.defs)  # G_d defs not yet installed
        self.relation: dict = {}           # G_s tensor -> clean Term
        # frontier index: per-def unmet-dependency counts + leaf -> waiters
        self._unmet: dict = {}
        self._waiters: dict = defaultdict(list)
        self._ready: deque = deque()
        self._installed: set = set()
        if CONFIG.indexed_frontier:
            self._init_frontier_index()

    # -- setup ---------------------------------------------------------------
    def _init_frontier_index(self):
        for entry in self.gd_pending:
            name, term = entry
            deps = {l.name for l in term.leaves()
                    if l.op == "tensor" and l.name not in self.gd.consts
                    and l.name not in self.related}
            if not deps:
                self._ready.append(entry)
            else:
                self._unmet[name] = len(deps)
                for d in deps:
                    self._waiters[d].append(entry)

    def _mark_name(self, name: str):
        """Add a G_d tensor to T_rel, unblocking defs that waited on it."""
        if name in self.related:
            return
        self.related.add(name)
        if not CONFIG.indexed_frontier:
            return
        for entry in self._waiters.pop(name, ()):
            left = self._unmet[entry[0]] = self._unmet[entry[0]] - 1
            if left == 0:
                self._ready.append(entry)

    def _install_inputs(self):
        with obs_trace.span("install_inputs", cat="engine"):
            self._install_inputs_inner()

    def _install_inputs_inner(self):
        xp = self.explain
        for name, exprs in self.r_i.items():
            c_s = self.eg.add_term(self.gs.tensor(name))
            for e in exprs:
                self.eg.merge(c_s, self.eg.add_term(e),
                              ("input", name) if xp else None)
                for leaf in e.leaves():
                    if leaf.op == "tensor":
                        self._mark_name(leaf.name)
            if exprs:
                self.relation[name] = exprs[0]
        # consts: value-match G_s consts to G_d consts (rank-replicated)
        matched = 0
        for sname, sval in self.gs.consts.items():
            c_s = self.eg.add_term(self.gs.tensor(sname))
            for dname, dval in self.gd.consts.items():
                if sval.shape == dval.shape and sval.dtype == dval.dtype \
                        and np.array_equal(sval, dval):
                    self.eg.merge(c_s, self.eg.add_term(self.gd.tensor(dname)),
                                  ("const", sname) if xp else None)
                    self._mark_name(dname)
                    matched += 1
        self.eg.rebuild()

    # -- frontier (Listing 3) -------------------------------------------------
    def _install_def(self, name: str, term: Term):
        c_out = self.eg.add_term(self.gd.tensor(name))
        self.eg.merge(c_out, self.eg.add_term(term),
                      ("dist_def", name) if self.explain else None)
        for l in term.leaves():
            if l.op == "tensor":
                self._mark_name(l.name)
        self._mark_name(name)

    def _grow_frontier(self) -> bool:
        """Install defining equations of G_d nodes whose inputs are related."""
        t0 = time.perf_counter()
        if CONFIG.indexed_frontier:
            REGISTRY.histogram("engine.frontier_ready").observe(
                len(self._ready))
            grew = False
            while self._ready:
                name, term = self._ready.popleft()
                if name in self._installed:
                    continue
                self._installed.add(name)
                self._install_def(name, term)
                grew = True
        else:
            grew = self._grow_frontier_scan()
        t1 = time.perf_counter()
        self.profile.add_time("frontier", t1 - t0)
        tracer = obs_trace.current()
        if tracer is not None and grew:
            tracer.span_from("frontier", t0, t1)
        if grew:
            self.eg.rebuild()
        return grew

    def _grow_frontier_scan(self) -> bool:
        """Baseline O(pending defs) rescan (CONFIG.indexed_frontier off)."""
        grew = False
        still = []
        for name, term in self.gd_pending:
            leaves = [l.name for l in term.leaves() if l.op == "tensor"]
            if all(l in self.related or l in self.gd.consts for l in leaves):
                self._install_def(name, term)
                grew = True
            else:
                still.append((name, term))
        self.gd_pending = still
        return grew

    def _mark_related(self, expr: Term):
        for leaf in expr.leaves():
            if leaf.op == "tensor":
                self._mark_name(leaf.name)

    # -- timed engine wrappers -------------------------------------------------
    def _saturate(self):
        t0 = time.perf_counter()
        with obs_trace.span("saturate", cat="engine"):
            self.eg.saturate(
                self.lemmas,
                fire_counts=self.fire_counts if self.collect_lemma_stats
                else None)
        # note: includes rebuild time, which the egraph also reports separately
        self.profile.add_time("saturate", time.perf_counter() - t0)

    def _extract(self, cid, leaf_ok):
        t0 = time.perf_counter()
        with obs_trace.span("extract", cat="engine"):
            out = self.eg.extract_clean(self.eg.find(cid), leaf_ok)
        self.profile.add_time("extract", time.perf_counter() - t0)
        return out

    # -- main loop (Listing 1) --------------------------------------------------
    def run(self) -> Certificate:
        t0 = time.perf_counter()
        self._install_inputs()
        self._grow_frontier()
        leaf_ok = lambda n: is_dist_name(n) or n in self.gd.consts

        for i, (out_name, term) in enumerate(self.gs.defs):
            with obs_trace.span(f"op:{out_name}", cat="engine",
                                op=term.op, index=i):
                # fire counts at op start: the delta on failure is the
                # fired-but-did-not-close set for the failure frontier
                fires_at_op = dict(self.fire_counts) if self.explain else None
                c_out = self.eg.add_term(self.gs.tensor(out_name))
                self.eg.merge(c_out, self.eg.add_term(term),
                              ("seq_def", out_name) if self.explain else None)
                self.eg.rebuild()
                # saturate + frontier to fixpoint (Listing 3 loop);
                # extraction is the expensive step, so frontier growth is
                # driven to fixpoint between extractions rather than
                # per-iteration.
                ce = None
                for _ in range(6):
                    for _ in range(10):
                        self._saturate()
                        if not self._grow_frontier():
                            break
                    ce = self._extract(c_out, leaf_ok)
                    if ce is None:
                        if self.eg.pending:
                            continue   # saturation budget-truncated — resume
                        break
                    before = len(self.related)
                    self._mark_related(ce)
                    if len(self.related) == before:
                        break
                if ce is None:
                    diag = self.eg.extract_any(self.eg.find(c_out), leaf_ok)
                    in_maps = {}
                    for leaf in term.leaves():
                        if leaf.op == "tensor" and leaf.name in self.relation:
                            in_maps[leaf.name] = self.relation[leaf.name]
                    err = RefinementError(i, term.op, out_name, in_maps, diag)
                    if self.explain:
                        fired = {k: self.fire_counts.get(k, 0)
                                 - fires_at_op.get(k, 0)
                                 for k in self.fire_counts}
                        err.explanation = build_failure_frontier(
                            self, i, term.op, out_name, in_maps, diag, fired)
                    raise err
                self.relation[out_name] = ce
                self._mark_related(ce)

        # Final filter (Listing 1 line 9): R_o maps G_s outputs to
        # expressions over G_d *outputs* only — intermediate per-rank
        # tensors (e.g. pre-psum partials) are not observable results.
        out_names = set(self.gd.outputs)
        out_ok = lambda n: n in out_names or n in self.gd.consts
        r_o = {}
        for o in self.gs.outputs:
            if o in self.gs.consts or o in self.r_i:
                continue  # passthrough outputs
            c = self.eg.add_term(self.gs.tensor(o))
            ce = self._extract(c, out_ok)
            if ce is None:
                diag = self.eg.extract_any(self.eg.find(c), out_ok)
                err = RefinementError(
                    len(self.gs.defs), "output-filter", o,
                    {o: self.relation.get(o)}, diag,
                    message="output maps to internal G_d tensors but not to "
                            "G_d outputs (Listing 1 line 9 filter)")
                if self.explain:
                    maps = {o: self.relation[o]} if o in self.relation else {}
                    err.explanation = build_failure_frontier(
                        self, len(self.gs.defs), "output-filter", o,
                        maps, diag, {})
                raise err
            r_o[o] = ce
        stats = {
            "time_s": time.perf_counter() - t0,
            "egraph_nodes": self.eg.n_nodes,
            "gs_ops": len(self.gs.defs),
            "gd_ops": len(self.gd.defs),
            "lemma_fires": dict(self.fire_counts),
            "lemmas": self.profile.lemma_stats(
                self.fire_counts if self.collect_lemma_stats else None),
            "phase_s": self.profile.phase_seconds(),
            "counters": self.profile.counter_values(),
            "opt": CONFIG.as_dict(),
        }
        REGISTRY.counter("engine.runs").inc()
        REGISTRY.counter("engine.lemma_fires").inc(
            sum(self.fire_counts.values()))
        REGISTRY.histogram("engine.infer_s").observe(stats["time_s"])
        REGISTRY.histogram("engine.egraph_nodes").observe(self.eg.n_nodes)
        cert = Certificate(r_o, dict(self.relation), stats)
        if self.explain:
            # built after the stats snapshot so every stats field (and the
            # certificate payload) is byte-identical with explanations off
            with obs_trace.span("explain.build", cat="engine"):
                cert.explanation = build_certificate_explanation(self, r_o)
            REGISTRY.counter("engine.explain_steps").inc(
                cert.explanation["total_steps"])
            obs_trace.event("explain", cat="engine", outputs=len(r_o),
                            steps=cert.explanation["total_steps"])
        return cert


def check_refinement(gs: Graph, gd: Graph, r_i: dict,
                     max_nodes: int = 400_000,
                     explain: Optional[bool] = None) -> Certificate:
    """One-shot refinement check: does ``gd`` (multi-rank) refine ``gs``
    given input relation ``r_i``?  Returns a :class:`Certificate` or raises
    :class:`RefinementError` with the first unresolvable operator.
    ``explain=True`` additionally records proof provenance (see
    ``repro.core.explain``); None defers to ``GRAPHGUARD_EXPLAIN``."""
    return GraphGuard(gs, gd, r_i, max_nodes=max_nodes, explain=explain).run()

"""EGraph: equality saturation engine for GraphGuard relation inference.

A pure-Python reimplementation of the egg-style e-graph the paper builds on
(Willsey et al., POPL'21): hash-consed e-nodes, union-find over e-classes,
congruence closure via worklist repair, and a saturation driver that applies
procedural *lemmas* (see ``repro.core.lemmas``).

Differences from egg, driven by GraphGuard's use (paper §4.2.2, §4.3.2):
  * Lemmas are procedural Python matchers rather than declarative patterns —
    lemma conditions need shape arithmetic and (occasionally) the affine
    scalar solver, which is natural in Python.
  * Each e-class carries a shape/dtype analysis; merging classes with
    disagreeing shapes is an internal soundness error (fail loudly).
  * Clean-expression extraction (paper's step 4) is built in: for a class, we
    search for the minimum-cost expression whose interior ops are CLEAN_OPS
    and whose leaves lie in a caller-supplied set of tensors.
  * "Pruning self-provable expressions" (§4.3.2) falls out of extraction: we
    always keep the *simplest* representative; the e-graph stores the rest
    compactly by sharing.

Hot-path engineering (gated by ``repro.core.profile.CONFIG``):
  * ``saturate`` dispatches lemmas through an op-indexed table built once per
    lemma list, instead of scanning every lemma per pending node.
  * Congruence repair (``rebuild``) runs once per saturation round (egg's
    deferred-rebuild result) instead of after every pending node.
  * Extraction is a worklist cost propagation with a per-class cost cache
    keyed on the union version: re-extracting after no growth is a dict hit,
    and after growth only classes whose costs could have changed recompute.
  * ``nodes_of`` caches canonical node sets per class, invalidated by union
    version plus targeted pops on node insertion.

Extraction breaks cost ties with a deterministic term order (``Term.sort_key``)
so certificates are bit-identical whether the optimizations are on or off.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Optional

from ..obs import trace as obs_trace
from .profile import CONFIG
from .terms import Term, CLEAN_OPS, tensor as mk_tensor


class ENode:
    """One operator node in the e-graph: op name, hashable attrs, and
    child e-class ids.  Hash-consed — equal nodes share one entry."""
    __slots__ = ("op", "attrs", "children", "_hash")

    def __init__(self, op: str, attrs: tuple, children: tuple):
        self.op = op
        self.attrs = attrs
        self.children = children
        self._hash = hash((op, attrs, children))

    def __hash__(self):
        return self._hash

    def __eq__(self, other):
        return (self.op == other.op and self.attrs == other.attrs
                and self.children == other.children)

    def canonical(self, find) -> "ENode":
        ch = tuple(find(c) for c in self.children)
        if ch == self.children:
            return self
        return ENode(self.op, self.attrs, ch)

    def __repr__(self):
        return f"ENode({self.op}, {self.attrs}, {self.children})"


def _node_key(n: ENode) -> tuple:
    """Structural sort key for ENodes.  Member sets are Python sets, so
    their iteration order follows hash randomization; everything that
    *iterates* members (``nodes_of``, merge's pending re-queue) sorts by
    this key first, keeping lemma dispatch — and therefore the proof
    journal — identical across processes and PYTHONHASHSEED values."""
    return (n.op, n.children, repr(n.attrs))


class EClassInfo:
    """Per-e-class bookkeeping: member nodes, parent back-edges, the class
    shape/dtype invariant, known tensor leaves, and the GraphGuard T_rel
    frontier marker."""
    __slots__ = ("nodes", "parents", "shape", "dtype", "tensors", "related")

    def __init__(self, shape, dtype):
        self.nodes: set[ENode] = set()
        self.parents: list[tuple[ENode, int]] = []
        self.shape = shape
        self.dtype = dtype
        # tensor names (leaves) known to live in this class
        self.tensors: set[str] = set()
        # GraphGuard T_rel marker (frontier optimization, Listing 3)
        self.related: bool = False


class EGraph:
    """Congruence-closed e-graph over the term language: union-find +
    hashcons + per-class info, with op-indexed lemma dispatch, deferred
    rebuilds, and a node budget (``EGraphLimit`` past ``max_nodes``)."""

    def __init__(self, max_nodes: int = 200_000, explain: bool = False):
        self.uf: list[int] = []
        self.classes: dict[int, EClassInfo] = {}
        self.hashcons: dict[ENode, int] = {}
        self.worklist: list[int] = []
        self.pending: list[tuple[ENode, int]] = []  # (node, class) for lemma queue
        self.max_nodes = max_nodes
        # --- proof provenance (egg-style explanations) -------------------
        # With ``explain`` on, every union is journaled as an edge between
        # its two pre-union roots plus the justification that caused it, and
        # every class id keeps its creating e-node + shape/dtype.  The edge
        # graph has exactly one edge per union, so two ids are uf-equal iff
        # an edge path connects them — ``repro.core.explain`` walks those
        # paths to rebuild lemma chains.  Off (the default), no extra state
        # is kept and behaviour is byte-identical.
        self.explain = bool(explain)
        self.explain_edges: list[tuple[int, int, Optional[tuple]]] = []
        self.node_meta: dict[int, tuple[ENode, tuple, str]] = {}
        self.n_nodes = 0
        self.version = 0  # bumped on every union; cheap fixpoint detection
        self.profile = None  # optional repro.core.profile.Profile
        # --- caches (see module docstring) -------------------------------
        # class root -> ({op: [ENode]}, [ENode]); invalidated by targeted
        # pops: a union pops the two merged roots and the losing side's
        # parent classes (whose members' canonical forms changed), node
        # insertion pops the owning class. Stale *children* inside cached
        # nodes are harmless — all consumers resolve children via find().
        self._nodes_cache: dict[int, tuple] = {}
        # (id(leaf_ok), clean_only, max_cost, max_reach) ->
        #   (version, best: {cid: (Term, cost)}, reach: frozenset, log_len)
        self._extract_cache: dict[tuple, tuple] = {}
        # append-only log of merge roots; extraction seeds recomputation
        # from the suffix written since its cached snapshot
        self._merge_log: list[int] = []
        # (lemma list identity, {op: [Lemma]})
        self._lemma_idx: Optional[tuple] = None

    # -- union-find ---------------------------------------------------------
    def find(self, a: int) -> int:
        while self.uf[a] != a:
            self.uf[a] = self.uf[self.uf[a]]
            a = self.uf[a]
        return a

    def _new_class(self, shape, dtype) -> int:
        cid = len(self.uf)
        self.uf.append(cid)
        self.classes[cid] = EClassInfo(shape, dtype)
        return cid

    # -- adding terms / nodes ------------------------------------------------
    def add_term(self, t: Term) -> int:
        """Intern a Term, returning its e-class id. ``cls`` leaves are
        references to existing e-classes (used by procedural lemmas to build
        rewritten terms over classes rather than concrete terms)."""
        if t.op == "cls":
            return self.find(t.attr("id"))
        if t.op == "tensor":
            node = ENode("tensor", t.attrs, ())
        elif t.op == "lit":
            node = ENode("lit", t.attrs, ())
        else:
            ch = tuple(self.add_term(a) for a in t.args)
            node = ENode(t.op, t.attrs, ch)
        return self.add_enode(node, t.shape, t.dtype)

    def add_enode(self, node: ENode, shape, dtype) -> int:
        node = node.canonical(self.find)
        hit = self.hashcons.get(node)
        if hit is not None:
            return self.find(hit)
        if self.n_nodes >= self.max_nodes:
            raise EGraphLimit(f"egraph node limit {self.max_nodes} exceeded")
        cid = self._new_class(shape, dtype)
        if self.explain:
            self.node_meta[cid] = (node, shape, dtype)
        info = self.classes[cid]
        info.nodes.add(node)
        if node.op == "tensor":
            info.tensors.add(dict(node.attrs)["name"])
        self.hashcons[node] = cid
        for c in node.children:
            self.classes[self.find(c)].parents.append((node, cid))
        self.n_nodes += 1
        self.pending.append((node, cid))
        return cid

    # -- merging -------------------------------------------------------------
    def merge(self, a: int, b: int, reason: Optional[tuple] = None) -> int:
        a, b = self.find(a), self.find(b)
        if a == b:
            return a
        ia, ib = self.classes[a], self.classes[b]
        if ia.shape != ib.shape and ia.shape != () and ib.shape != ():
            raise EGraphShapeError(
                f"merging classes with shapes {ia.shape} vs {ib.shape}")
        if self.explain:
            # journal with the pre-union roots: each union joins exactly two
            # edge-graph components, keeping connectivity ⟺ uf-equality
            self.explain_edges.append((a, b, reason))
        # keep the class with more parents as the root (union by size-ish)
        if len(ia.parents) < len(ib.parents):
            a, b = b, a
            ia, ib = ib, ia
        self.uf[b] = a
        ia.nodes |= ib.nodes
        ia.parents.extend(ib.parents)
        ia.tensors |= ib.tensors
        ia.related |= ib.related
        if ia.shape == ():
            ia.shape = ib.shape
        self.classes.pop(b)
        self.worklist.append(a)
        # Re-queue parents (ops whose children gained representations) and
        # members (constrained lemmas scan sibling reps) of the merged class.
        for pnode, pcid in ia.parents:
            self.pending.append((pnode, pcid))
        for n in sorted(ib.nodes, key=_node_key):
            self.pending.append((n, a))
        self.version += 1
        nc = self._nodes_cache
        nc.pop(a, None)
        nc.pop(b, None)
        # members of b's old parent classes now canonicalize differently
        for _pnode, pcid in ib.parents:
            nc.pop(self.find(pcid), None)
        self._merge_log.append(a)
        return a

    def rebuild(self):
        """Congruence closure repair (egg's rebuild)."""
        if not self.worklist:
            return
        prof = self.profile
        t0 = time.perf_counter() if prof is not None else 0.0
        while self.worklist:
            todo = sorted({self.find(c) for c in self.worklist})
            self.worklist.clear()
            for cid in todo:
                self._repair(cid)
        if prof is not None:
            t1 = time.perf_counter()
            prof.add_time("rebuild", t1 - t0)
            tracer = obs_trace.current()
            if tracer is not None and t1 - t0 >= 1e-4:
                # only spans wide enough to see — congruence repair runs
                # every round and would otherwise dominate the event log
                tracer.span_from("rebuild", t0, t1)

    def _repair(self, cid: int):
        info = self.classes.get(cid)
        if info is None:
            return
        new_parents: dict[ENode, int] = {}
        for pnode, pcid in info.parents:
            stale = self.hashcons.pop(pnode, None)
            canon = pnode.canonical(self.find)
            pcid = self.find(pcid)
            if canon in new_parents:
                self.merge(pcid, new_parents[canon],
                           ("congruence", canon.op) if self.explain else None)
                pcid = self.find(pcid)
            else:
                if stale is None and canon in self.hashcons:
                    self.merge(pcid, self.hashcons[canon],
                               ("congruence", canon.op) if self.explain
                               else None)
                    pcid = self.find(pcid)
            new_parents[canon] = pcid
            self.hashcons[canon] = pcid
            # keep node sets canonical too
            owner = self.classes.get(pcid)
            if owner is not None:
                owner.nodes.add(canon)
                self._nodes_cache.pop(pcid, None)
        info.parents = list(new_parents.items())

    # -- queries --------------------------------------------------------------
    def info(self, cid: int) -> EClassInfo:
        return self.classes[self.find(cid)]

    def nodes_of(self, cid: int, op: Optional[str] = None) -> list[ENode]:
        r = self.find(cid)
        cached = CONFIG.cached_nodes
        if cached:
            ent = self._nodes_cache.get(r)
            if ent is not None:
                if op is None:
                    return ent[1]
                return ent[0].get(op, [])
        info = self.classes[r]
        canon: list[ENode] = []
        by_op: dict[str, list[ENode]] = {}
        seen = set()
        for n in info.nodes:
            cn = n.canonical(self.find)
            if cn in seen:
                continue
            seen.add(cn)
            canon.append(cn)
        # structural order, not set-iteration order: lemma matching walks
        # these lists, and hash-randomized order would make the proof
        # journal differ between processes (see _node_key)
        canon.sort(key=_node_key)
        for cn in canon:
            by_op.setdefault(cn.op, []).append(cn)
        if cached:
            self._nodes_cache[r] = (by_op, canon)
        if op is None:
            return canon
        return by_op.get(op, [])

    def class_of_tensor(self, name: str, shape, dtype="f") -> int:
        return self.add_term(mk_tensor(name, shape, dtype))

    # -- saturation -----------------------------------------------------------
    def _lemma_index(self, lemmas: list) -> dict:
        """Op -> applicable lemmas (original order), built once per list."""
        if self._lemma_idx is not None and self._lemma_idx[0] is lemmas:
            return self._lemma_idx[1]
        ops = set()
        for lem in lemmas:
            if lem.ops is not None:
                ops |= lem.ops
        table = {op: [lem for lem in lemmas
                      if lem.ops is None or op in lem.ops]
                 for op in ops}
        # ops with no op-specific lemma still get the wildcard lemmas
        table[None] = [lem for lem in lemmas if lem.ops is None]
        self._lemma_idx = (lemmas, table)
        return table

    def saturate(self, lemmas: list, max_iters: int = 30,
                 fire_counts: Optional[dict] = None,
                 node_budget: int = 20000) -> None:
        """Run lemma application to (bounded) fixpoint.

        Each lemma is ``lemma(eg, node, cid) -> list[(Term|int, Term|int)]`` of
        equalities to install (paper: bidirectional rewrites; the e-graph makes
        direction irrelevant). ``node_budget`` bounds the nodes added per
        call — exceeding it stops saturation early (a completeness/perf
        trade, like the paper's constrained lemmas; soundness unaffected).
        """
        start_nodes = self.n_nodes
        prof = self.profile
        indexed = CONFIG.indexed_dispatch
        deferred = CONFIG.deferred_rebuild
        table = self._lemma_index(lemmas) if indexed else None
        # tracing-only per-lemma accounting; behaviour (and the Profile
        # per-lemma counters) is identical with the tracer off
        tracer = obs_trace.current()
        lemma_ms: Optional[dict] = {} if tracer is not None else None
        fires_delta: Optional[dict] = {} if tracer is not None else None
        for _ in range(max_iters):
            if self.n_nodes - start_nodes > node_budget:
                break
            batch = self.pending
            self.pending = []
            # dedupe: merges re-queue whole classes; canonicalize first
            seen = set()
            uniq = []
            for node, cid in batch:
                node = node.canonical(self.find)
                cid = self.find(cid)
                if (node, cid) in seen:
                    continue
                seen.add((node, cid))
                uniq.append((node, cid))
            before = self.version
            grew = False
            for node, cid in uniq:
                cid = self.find(cid)
                node = node.canonical(self.find)
                if indexed:
                    cand = table.get(node.op)
                    if cand is None:
                        cand = table[None]
                else:
                    cand = lemmas
                if prof is not None:
                    prof.count("nodes_dispatched")
                    prof.count("lemma_scan_len",
                               len(cand) if indexed else len(lemmas))
                for lem in cand:
                    if not indexed and lem.ops is not None \
                            and node.op not in lem.ops:
                        continue
                    try:
                        if lemma_ms is None:
                            eqs = lem.fn(self, node, cid)
                        else:
                            _lt0 = time.perf_counter()
                            eqs = lem.fn(self, node, cid)
                            lemma_ms[lem.name] = lemma_ms.get(lem.name, 0.0) \
                                + (time.perf_counter() - _lt0) * 1e3
                    except EGraphLimit:
                        raise
                    if prof is not None:
                        prof.count("lemma_calls")
                        prof.count_lemma(lem.name, bool(eqs))
                    if not eqs:
                        continue
                    if prof is not None:
                        prof.count("lemma_hits")
                    if fire_counts is not None:
                        fire_counts[lem.name] = fire_counts.get(lem.name, 0) + len(eqs)
                    if fires_delta is not None:
                        fires_delta[lem.name] = \
                            fires_delta.get(lem.name, 0) + len(eqs)
                    for lhs, rhs in eqs:
                        la = lhs if isinstance(lhs, int) else self.add_term(lhs)
                        ra = rhs if isinstance(rhs, int) else self.add_term(rhs)
                        if self.find(la) != self.find(ra):
                            self.merge(la, ra,
                                       ("lemma", lem.name) if self.explain
                                       else None)
                            grew = True
                if not deferred:
                    self.rebuild()
                if self.n_nodes - start_nodes > node_budget:
                    break
            # batched congruence repair: once per round (egg's deferred
            # rebuild) instead of once per pending node
            self.rebuild()
            if not self.pending and not grew and self.version == before:
                break
        if tracer is not None:
            tracer.event(
                "saturate.batch", cat="engine",
                fires={k: fires_delta[k] for k in sorted(fires_delta)},
                ms={k: round(lemma_ms[k], 3) for k in sorted(lemma_ms)})
            tracer.counter("egraph", nodes=self.n_nodes,
                           classes=len(self.classes))

    # -- clean extraction (paper step 4) ---------------------------------------
    def extract_clean(self, cid: int, leaf_ok: Callable[[str], bool],
                      max_cost: int = 40) -> Optional[Term]:
        """Find min-cost Term for class ``cid`` with interior ops in CLEAN_OPS
        and all tensor leaves satisfying ``leaf_ok(name)``. Literal leaves are
        allowed (they parameterize slices etc.)."""
        return self._extract(cid, leaf_ok, clean_only=True, max_cost=max_cost)

    def extract_any(self, cid: int, leaf_ok: Callable[[str], bool],
                    max_cost: int = 60) -> Optional[tuple[Term, int]]:
        """Extraction minimizing (#unclean ops, size) — for diagnostics.
        Returns (term, n_unclean) or None."""
        costs = self._bellman(cid, leaf_ok, clean_only=False, max_cost=max_cost)
        ent = costs.get(self.find(cid))
        if ent is None:
            return None
        term, (unclean, _) = ent
        return term, unclean

    def _extract(self, cid, leaf_ok, clean_only, max_cost):
        costs = self._bellman(cid, leaf_ok, clean_only, max_cost)
        ent = costs.get(self.find(cid))
        return None if ent is None else ent[0]

    @staticmethod
    def _better(cand: tuple, cur: tuple) -> bool:
        """Deterministic total order on (term, cost): cost first, then the
        structural term key — ties must resolve identically regardless of
        node iteration order so certificates don't depend on opt toggles."""
        if cand[1] != cur[1]:
            return cand[1] < cur[1]
        if cand[0] is cur[0]:
            return False
        return cand[0].sort_key() < cur[0].sort_key()

    def _bellman(self, root, leaf_ok, clean_only, max_cost,
                 max_reach: int = 4000):
        """Worklist cost propagation over the e-graph (handles cycles).

        cost = (unclean_ops, nodes); clean_only treats unclean as infeasible.
        With ``CONFIG.incremental_extract`` the per-class results are cached
        keyed on the union version: an unchanged graph returns the cached
        table outright; after growth only classes whose membership changed
        (plus newly reachable ones) are re-seeded, and improvements propagate
        upward through in-reach parent edges. Costs are monotone under e-graph
        growth (classes only gain representations), so stale entries are
        valid upper bounds — never wrong answers.
        """
        root = self.find(root)
        prof = self.profile
        incremental = CONFIG.incremental_extract
        if not incremental:
            return self._bellman_sweep(root, leaf_ok, clean_only, max_cost,
                                       max_reach)
        # key on the predicate object itself (the dict keeps it alive) —
        # an id() key would alias a GC-reused address to the wrong predicate
        key = (leaf_ok, clean_only, max_cost, max_reach)
        if prof is not None:
            prof.count("extract_calls")
        cached = self._extract_cache.get(key)
        if cached is not None and cached[0] == self.version \
                and root in cached[2]:
            if prof is not None:
                prof.count("extract_cache_hits")
            return cached[1]

        # restrict attention to classes reachable from root; upward
        # propagation reuses the e-graph's maintained parent lists (a
        # superset of in-reach edges, filtered by reach membership below)
        reach, truncated = self._reach(root, max_reach)

        best: dict[int, tuple[Term, tuple[int, int]]] = {}
        if cached is not None:
            cver, cbest, creach, clog = cached
            # cached entries stay valid upper bounds; remap to current roots
            for c, ent in cbest.items():
                r = self.find(c)
                if r not in reach:
                    continue
                cur = best.get(r)
                if cur is None or self._better(ent, cur):
                    best[r] = ent
            creach_now = {self.find(c) for c in creach}
            seed = {r for r in reach if r not in creach_now}
            for c in self._merge_log[clog:]:
                r = self.find(c)
                if r in reach:
                    seed.add(r)
        else:
            seed = set(reach)

        wl = deque(seed)
        inq = set(seed)
        # A merge can make a *parent* newly feasible without improving the
        # merged class's own best (e.g. an infeasible class folded into a
        # feasible one: the winner's recompute shows no improvement, so the
        # improvement cascade alone would never reach the parent). Seed
        # classes therefore notify their in-reach parents unconditionally.
        if cached is not None:
            for c in tuple(seed):
                info = self.classes.get(c)
                if info is None:
                    continue
                for _pnode, pcid in info.parents:
                    p = self.find(pcid)
                    if p in reach and p not in inq:
                        inq.add(p)
                        wl.append(p)
        while wl:
            c = wl.popleft()
            inq.discard(c)
            info = self.classes.get(c)
            if info is None:
                continue
            improved = False
            for n in self.nodes_of(c):
                cand = self._node_cost(n, best, leaf_ok, clean_only,
                                       info, max_cost)
                if cand is None:
                    continue
                cur = best.get(c)
                if cur is None or self._better(cand, cur):
                    best[c] = cand
                    improved = True
            if improved:
                for _pnode, pcid in info.parents:
                    p = self.find(pcid)
                    if p in reach and p not in inq:
                        inq.add(p)
                        wl.append(p)
        if not truncated:
            # a max_reach-truncated table is root-specific (other roots'
            # subtrees were never explored) — never serve it from cache
            self._extract_cache[key] = (self.version, best, frozenset(reach),
                                        len(self._merge_log))
        return best

    def _reach(self, root: int, max_reach: int) -> tuple[set, bool]:
        """Classes reachable from ``root``; truncated=True if max_reach hit."""
        reach: set[int] = set()
        stack = [root]
        while stack:
            c = self.find(stack.pop())
            if c in reach:
                continue
            reach.add(c)
            if len(reach) > max_reach:
                return reach, True
            for n in self.nodes_of(c):
                for ch in n.children:
                    stack.append(self.find(ch))
        return reach, False

    def _bellman_sweep(self, root, leaf_ok, clean_only, max_cost,
                       max_reach: int = 4000):
        """Pre-optimization baseline: full fixed-point re-sweeps over the
        reachable set (the seed engine's extraction). Kept behind
        ``CONFIG.incremental_extract = False`` so benchmarks can measure the
        worklist + cache variant against it on the same commit; uses the same
        ``_better`` tie-break so both produce identical certificates."""
        reach, _truncated = self._reach(root, max_reach)
        best: dict[int, tuple[Term, tuple[int, int]]] = {}
        changed = True
        iters = 0
        while changed and iters < 30:
            changed = False
            iters += 1
            for c in reach:
                info = self.classes.get(c)
                if info is None:
                    continue
                for n in self.nodes_of(c):
                    cand = self._node_cost(n, best, leaf_ok, clean_only,
                                           info, max_cost)
                    if cand is None:
                        continue
                    cur = best.get(c)
                    if cur is None or self._better(cand, cur):
                        best[c] = cand
                        changed = True
        return best

    def _node_cost(self, n: ENode, best, leaf_ok, clean_only, info, max_cost):
        if n.op == "tensor":
            name = dict(n.attrs)["name"]
            if leaf_ok(name):
                return (Term("tensor", (), n.attrs, info.shape, info.dtype),
                        (0, 0))
            return None
        if n.op == "lit":
            return Term("lit", (), n.attrs, (), info.dtype), (0, 0)
        unclean = 0 if n.op in CLEAN_OPS else 1
        if clean_only and unclean:
            return None
        args = []
        tot_u, tot_s = unclean, 1
        for ch in n.children:
            ent = best.get(self.find(ch))
            if ent is None:
                return None
            args.append(ent[0])
            tot_u += ent[1][0]
            tot_s += ent[1][1] + 1
        if tot_s > max_cost:
            return None
        term = Term(n.op, tuple(args), n.attrs, info.shape, info.dtype)
        return term, (tot_u, tot_s)


class EGraphShapeError(AssertionError):
    """Two terms merged into one e-class disagree on shape/dtype — a lemma
    or capture bug, never a user error."""


class EGraphLimit(RuntimeError):
    """The e-graph grew past its ``max_nodes`` budget during saturation."""


class Lemma:
    """A rewrite rule (paper §4.2.1). ``ops``: trigger op names (None = all).
    ``fn(eg, node, cid)`` returns equalities [(lhs, rhs), ...] as Terms or
    class ids."""

    __slots__ = ("name", "ops", "fn", "source")

    def __init__(self, name: str, ops, fn, source: str = "builtin"):
        self.name = name
        self.ops = frozenset(ops) if ops is not None else None
        self.fn = fn
        self.source = source

    def __repr__(self):
        return f"Lemma({self.name})"

"""Generic jaxpr capture frontend: trace *arbitrary* user functions.

The rest of the repo reaches the term language through registered builders
(``repro.dist.strategies`` et al.); this module is the "bring your own
``shard_map`` function" entry the ROADMAP promises.  It traces any jitted /
``shard_map``-style function via ``jax.make_jaxpr``, walks ``jaxpr.eqns``
mapping invars/outvars through a var table (the graphax ``from_jaxpr``
traversal idiom), and lowers each primitive into the term vocabulary of
``terms.py`` — reusing the exact normalization machinery in ``capture.py``
so a function captured here yields a **byte-identical certificate** to the
hand-registered frontend (asserted case-by-case in
``tests/test_from_jaxpr.py``).

The difference from the internal path is the error contract.  The internal
path is *lenient*: a primitive outside the vocabulary becomes an
uninterpreted ``opaque`` term (a user-lemma extension point), and an
over-budget ``scan`` raises a bare ``CaptureError``.  For user-written code
that silence is a trap — an opaque op can never join a relation, so the
verdict degrades to a confusing refinement failure far from the cause.
This frontend is therefore *strict* by default: anything without a clean
lowering raises :class:`UnsupportedPrimitive` naming the offending
primitive and its **source location** (file:line of the user's code, from
the eqn's ``source_info``), e.g.::

    UnsupportedPrimitive: primitive `scan` at my_model.py:42 (ssm_step) has
    no term-language lowering: scan of length 16 exceeds the unroll budget
    of 8 — pass strict=False to capture it as an uninterpreted opaque op

Pass ``strict=False`` to restore the lenient behaviour (and pair it with
``repro.core.register_lemma`` to teach the engine about the opaque op).
"""
from __future__ import annotations

import contextlib
from typing import Callable, Iterator, Optional, Sequence

import jax

from .capture import (COLLECTIVES, CaptureError, Graph, SpmdCapture,
                      _EQN_HOOKS, _EW1_MAP, _EW2_MAP)
from .capture import capture as _capture
from .capture import capture_spmd as _capture_spmd

try:  # jax keeps source-info pretty-printing in a private util module
    from jax._src.source_info_util import summarize as _summarize
except Exception:  # pragma: no cover - very old/new jax
    _summarize = None


# Structural primitives inlined (not lowered) during the eqn walk, plus the
# bounded-unroll scan — mirrored from ``capture._process_eqns``.
STRUCTURAL_PRIMITIVES = frozenset({
    "pjit", "jit", "closed_call", "custom_jvp_call", "custom_vjp_call",
    "custom_vjp_call_jaxpr", "remat", "checkpoint", "custom_jvp_call_jaxpr",
    "core_call", "scan",
})

# Primitives with an unconditional clean lowering in ``capture._normalize``
# (conditionally-supported ones — strided ``slice``, exotic ``gather``
# patterns, interior ``pad`` — raise UnsupportedPrimitive in strict mode
# when their conditions fail, so this set is the *guaranteed* vocabulary).
SUPPORTED_PRIMITIVES = frozenset(
    {"device_put", "integer_pow", "square", "select_n", "clamp",
     "convert_element_type", "broadcast_in_dim", "reshape", "squeeze",
     "expand_dims", "transpose", "rev", "concatenate", "slice", "split",
     "iota", "reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
     "reduce_and", "reduce_or", "argmax", "cumsum", "dot_general",
     "dynamic_slice", "dynamic_update_slice", "pad", "gather",
     "scatter_add"}
    | set(_EW1_MAP) | set(_EW2_MAP) | set(COLLECTIVES))


def source_location(eqn) -> str:
    """Best-effort ``file:line (function)`` of an eqn's user source."""
    si = getattr(eqn, "source_info", None)
    if si is None:
        return "<unknown>"
    if _summarize is not None:
        try:
            return _summarize(si)
        except Exception:  # pragma: no cover - defensive
            pass
    tb = getattr(si, "traceback", None)  # pragma: no cover - fallback path
    if tb is not None:
        frames = tb.frames if hasattr(tb, "frames") else []
        for f in reversed(list(frames)):
            return f"{f.file_name}:{f.line_num} ({f.function_name})"
    return "<unknown>"  # pragma: no cover


class UnsupportedPrimitive(CaptureError):
    """A traced eqn has no clean lowering into the term language.

    Raised by the strict capture frontend instead of silently emitting an
    uninterpreted opaque term.  Carries the offending ``primitive`` name,
    its ``source`` location (``file:line (function)`` of the user code that
    emitted the eqn), and the ``reason`` the lowering was refused.
    """

    def __init__(self, primitive: str, source: str, reason: str = ""):
        self.primitive = str(primitive)
        self.source = str(source)
        self.reason = str(reason)
        msg = (f"primitive `{self.primitive}` at {self.source} has no "
               f"term-language lowering")
        if reason:
            msg += f": {reason}"
        msg += (" — pass strict=False to capture it as an uninterpreted "
                "opaque op (see repro.core.register_lemma)")
        super().__init__(msg)


@contextlib.contextmanager
def strict_capture() -> Iterator[None]:
    """Make every lenient capture fallback raise ``UnsupportedPrimitive``.

    Installs a hook on ``capture._process_eqns`` for the dynamic extent of
    the block: unknown primitives (which would become opaque terms),
    partially-supported primitives whose side conditions fail, and
    over-budget scans all raise with the eqn's primitive name and source
    location attached.
    """
    def hook(eqn, reason):
        raise UnsupportedPrimitive(eqn.primitive.name, source_location(eqn),
                                   reason)
    _EQN_HOOKS.append(hook)
    try:
        yield
    finally:
        _EQN_HOOKS.remove(hook)


def default_input_names(fn: Callable, n: int) -> list:
    """Input names for ``fn``: its positional parameter names when the
    signature is introspectable (and fully positional), else ``arg0..``."""
    try:
        import inspect
        params = list(inspect.signature(fn).parameters.values())
        names = [p.name for p in params
                 if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
        if len(names) == n:
            return names
    except (TypeError, ValueError):
        pass
    return [f"arg{i}" for i in range(n)]


def normalize_mesh(mesh) -> dict:
    """Coerce a mesh argument to the ``{axis name: size}`` dict form.

    Accepts a plain dict, a ``jax.sharding.Mesh`` / ``AbstractMesh`` (their
    ``.shape`` mapping), or any mapping-like object.
    """
    if isinstance(mesh, dict):
        out = {str(k): int(v) for k, v in mesh.items()}
    elif hasattr(mesh, "shape") and hasattr(mesh.shape, "items"):
        out = {str(k): int(v) for k, v in mesh.shape.items()}
    else:
        try:
            out = {str(k): int(v) for k, v in dict(mesh).items()}
        except (TypeError, ValueError):
            raise TypeError(
                f"mesh must be a {{axis: size}} dict or a jax Mesh, got "
                f"{type(mesh).__name__}") from None
    if not out or any(v < 1 for v in out.values()):
        raise ValueError(f"mesh axes must have positive sizes, got {out}")
    return out


def capture_function(fn: Callable, avals: Sequence,
                     names: Optional[Sequence[str]] = None, *,
                     strict: bool = True) -> Graph:
    """Trace ``fn`` via ``jax.make_jaxpr`` and lower it to a :class:`Graph`.

    The generic flavour of ``capture()``: ``names`` defaults to the
    function's own parameter names, and ``strict=True`` (the default)
    raises :class:`UnsupportedPrimitive` for any eqn outside the term
    vocabulary instead of emitting an opaque term.
    """
    if names is None:
        names = default_input_names(fn, len(avals))
    if strict:
        with strict_capture():
            return _capture(fn, list(avals), list(names))
    return _capture(fn, list(avals), list(names))


def capture_spmd_function(fn: Callable, mesh, in_specs: Sequence,
                          avals: Sequence,
                          names: Optional[Sequence[str]] = None, *,
                          strict: bool = True) -> SpmdCapture:
    """Trace a per-rank SPMD ``fn`` under ``shard_map`` (strict by default).

    The generic flavour of ``capture_spmd()``: ``mesh`` may be a
    ``{axis: size}`` dict or a jax ``Mesh``; ``names`` defaults to the
    function's parameter names.  The returned :class:`SpmdCapture` expands
    to a multi-rank graph + input relation via ``expand_spmd``.
    """
    mesh_axes = normalize_mesh(mesh)
    if names is None:
        names = default_input_names(fn, len(avals))
    if strict:
        with strict_capture():
            return _capture_spmd(fn, mesh_axes, list(in_specs),
                                 list(avals), list(names))
    return _capture_spmd(fn, mesh_axes, list(in_specs), list(avals),
                         list(names))

"""Term IR for GraphGuard expressions.

Terms are immutable, hash-consed symbolic expressions over tensors. They are
the unit of exchange between the capture layer (jaxpr -> Graph), the EGraph
(terms are interned as ENodes), relation inference (clean expressions are
Terms), and the numeric evaluator (certificates are executable).

Op vocabulary (normalized from jaxpr primitives by ``repro.core.capture``):

  leaves      tensor(name)  lit(value)
  rearrange   concat(xs..., dim)  slice(x, starts, limits)  transpose(x, perm)
              reshape(x, shape)   broadcast(x, shape, bdims)  convert(x)
  compute     matmul(a, b)        bmm(a, b)          gather_rows(tab, idx)
              ew1 family: neg exp log tanh logistic rsqrt sqrt sin cos abs
                          erf relu floor sign square integer_pow(p) stop_grad
              ew2 family: add sub mul div max2 min2 pow eq lt gt and or
                          (add is n-ary: ``add_n`` builds the flattened
                           normal form; 2-ary adds are the legacy binary op)
              reduce_sum(x, axes) reduce_max(x, axes) reduce_min(x, axes)
              select(pred, on_true, on_false)  iota(shape, dim)
              dus(x, upd, starts)              cumsum(x, axis)
              argmax(x, axis)  one_hot-ish encodings come in via eq/iota
"""
from __future__ import annotations

import math
from typing import Any, Callable, Iterable, Optional

import numpy as np

# ---------------------------------------------------------------------------
# Op sets
# ---------------------------------------------------------------------------

EW1_OPS = frozenset({
    "neg", "exp", "log", "tanh", "logistic", "rsqrt", "sqrt", "sin", "cos",
    "abs", "erf", "relu", "floor", "sign", "square", "stop_grad", "log1p",
    "expm1", "not",
})
EW2_OPS = frozenset({
    "add", "sub", "mul", "div", "max2", "min2", "pow", "eq", "ne", "lt", "le",
    "gt", "ge", "and", "or", "rem", "atan2", "shift_left", "shift_right",
    "nextafter",
})
REDUCE_OPS = frozenset({"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                        "reduce_and", "reduce_or"})
REARRANGE_OPS = frozenset({"concat", "slice", "transpose", "reshape",
                           "broadcast", "convert", "rev"})

# Ops permitted inside a *clean* expression (paper S3.2): element rearrangement
# plus cross-rank reductions (sum). ``add`` is the expanded form of psum /
# gradient accumulation. Anything else (mul/div/matmul/...) in a mapping
# indicates the implementation requires real computation to reconstruct the
# sequential output => bug.
CLEAN_OPS = frozenset({"concat", "slice", "transpose", "reshape", "convert",
                       "add", "rev", "broadcast", "iota"})


# ---------------------------------------------------------------------------
# Term
# ---------------------------------------------------------------------------

_intern: dict = {}


class Term:
    """Immutable hash-consed symbolic expression node."""

    __slots__ = ("op", "args", "attrs", "shape", "dtype", "_hash",
                 "_leaves", "_clean", "_size", "_skey")

    def __new__(cls, op: str, args: tuple = (), attrs: tuple = (),
                shape: tuple = (), dtype: str = "f"):
        key = (op, args, attrs, shape, dtype)
        hit = _intern.get(key)
        if hit is not None:
            return hit
        self = super().__new__(cls)
        self.op = op
        self.args = args
        self.attrs = attrs
        self.shape = shape
        self.dtype = dtype
        self._hash = hash(key)
        self._leaves = None
        self._clean = None
        self._size = None
        self._skey = None
        _intern[key] = self
        return self

    def __init__(self, *a, **k):  # state set in __new__
        pass

    def __hash__(self):
        return self._hash

    def __eq__(self, other):
        return self is other

    # -- convenience -------------------------------------------------------
    @property
    def is_leaf(self) -> bool:
        return self.op in ("tensor", "lit")

    @property
    def name(self) -> str:
        assert self.op == "tensor"
        return self.attrs[0][1]

    @property
    def value(self):
        assert self.op == "lit"
        return self.attrs[0][1]

    def attr(self, key: str, default=None):
        for k, v in self.attrs:
            if k == key:
                return v
        return default

    def size(self) -> int:
        """Number of operator nodes (leaves are free; DAG-memoized)."""
        if self._size is None:
            self._size = 0 if self.is_leaf else \
                1 + sum(a.size() for a in self.args)
        return self._size

    def sort_key(self):
        """Deterministic structural key (DAG-memoized): tuples compare op
        first, so mixed-op comparisons never reach heterogeneous attrs.
        Extraction uses this to break cost ties independent of e-node
        iteration order."""
        if self._skey is None:
            self._skey = (self.op, self.attrs,
                          tuple(a.sort_key() for a in self.args))
        return self._skey

    def leaves(self) -> list["Term"]:
        """Distinct leaf terms (DAG-memoized)."""
        if self._leaves is None:
            if self.is_leaf:
                self._leaves = (self,)
            else:
                seen, out = set(), []
                for a in self.args:
                    for l in a.leaves():
                        if l not in seen:
                            seen.add(l)
                            out.append(l)
                self._leaves = tuple(out)
        return list(self._leaves)

    def ops_used(self) -> set:
        if self.is_leaf:
            return set()
        out = {self.op}
        for a in self.args:
            out |= a.ops_used()
        return out

    def is_clean(self) -> bool:
        """All interior ops are clean rearrangement/reduction ops."""
        if self._clean is None:
            if self.is_leaf:
                self._clean = True
            elif self.op not in CLEAN_OPS:
                self._clean = False
            else:
                self._clean = all(a.is_clean() for a in self.args)
        return self._clean

    def __repr__(self):
        return pretty(self, max_depth=6)


def pretty(t: Term, max_depth: int = 99) -> str:
    """Render a Term as a readable expression, bounded by depth."""
    if t.op == "tensor":
        return t.name
    if t.op == "lit":
        v = t.value
        return f"{v:g}" if isinstance(v, float) else str(v)
    if max_depth == 0:
        return "..."
    inner = ", ".join(pretty(a, max_depth - 1) for a in t.args)
    extras = ", ".join(f"{k}={v}" for k, v in t.attrs)
    if extras:
        inner = f"{inner}, {extras}" if inner else extras
    return f"{t.op}({inner})"


# ---------------------------------------------------------------------------
# Constructors with shape inference
# ---------------------------------------------------------------------------

def tensor(name: str, shape: tuple, dtype: str = "f") -> Term:
    """Named tensor leaf."""
    return Term("tensor", (), (("name", name),), tuple(shape), dtype)


def lit(value) -> Term:
    """Scalar literal leaf (numpy scalars/bools normalized)."""
    if isinstance(value, (np.floating,)):
        value = float(value)
    if isinstance(value, (np.integer,)):
        value = int(value)
    if isinstance(value, bool):
        value = int(value)
    dt = "f" if isinstance(value, float) else "i"
    return Term("lit", (), (("value", value),), (), dt)


def ew1(op: str, x: Term) -> Term:
    """Unary elementwise op (shape/dtype preserved)."""
    assert op in EW1_OPS, op
    return Term(op, (x,), (), x.shape, x.dtype)


def integer_pow(x: Term, p: int) -> Term:
    """x ** p for integer literal p."""
    return Term("integer_pow", (x,), (("p", p),), x.shape, x.dtype)


def ew2(op: str, x: Term, y: Term) -> Term:
    """Binary elementwise op; scalars lift, comparisons yield bools."""
    assert op in EW2_OPS, op
    assert x.shape == y.shape or x.shape == () or y.shape == (), \
        f"ew2 {op} shape mismatch {x.shape} vs {y.shape}"
    shape = x.shape if x.shape else y.shape
    dt = "b" if op in ("eq", "ne", "lt", "le", "gt", "ge", "and", "or") else \
        (x.dtype if x.shape else y.dtype)
    return Term(op, (x, y), (), shape, dt)


def add(x: Term, y: Term) -> Term:
    """Binary add (see ``add_n`` for the engine normal form)."""
    return ew2("add", x, y)


def add_n(xs: Iterable[Term]) -> Term:
    """Flattened n-ary add — the engine's add normal form.

    ``add`` nodes carry *any* number of addends (>= 2); nested adds are
    flattened at construction so a psum over a 16-rank group is one 16-ary
    node instead of a depth-15 binary chain (whose assoc/comm saturation
    blew up the 2D-mesh and FSDP cases — see EXPERIMENTS.md).  A 2-ary add
    is exactly the old binary node, so existing certificates are unchanged.
    """
    flat: list = []
    stack = list(xs)[::-1]
    while stack:                    # flatten to fixpoint, preserving order
        x = stack.pop()
        if x.op == "add":
            stack.extend(reversed(x.args))
        else:
            flat.append(x)
    assert flat
    if len(flat) == 1:
        return flat[0]
    if len(flat) == 2:
        return ew2("add", flat[0], flat[1])
    shape: tuple = ()
    for x in flat:
        assert x.shape == shape or x.shape == () or shape == (), \
            f"add_n shape mismatch {x.shape} vs {shape}"
        shape = shape or x.shape
    dt = next((x.dtype for x in flat if x.shape), flat[0].dtype)
    return Term("add", tuple(flat), (), shape, dt)


def matmul(a: Term, b: Term) -> Term:
    """Generalized matmul: (..., k) x (k, n) -> (..., n) (np.dot-style)."""
    assert len(a.shape) >= 1 and len(b.shape) == 2 and a.shape[-1] == b.shape[0], \
        f"matmul {a.shape} x {b.shape}"
    return Term("matmul", (a, b), (), a.shape[:-1] + (b.shape[1],), a.dtype)


def bmm(a: Term, b: Term) -> Term:
    """Batched matmul: (..., m, k) x (..., k, n) with identical batch dims."""
    assert len(a.shape) >= 2 and a.shape[:-2] == b.shape[:-2] and \
        a.shape[-1] == b.shape[-2], f"bmm {a.shape} x {b.shape}"
    return Term("bmm", (a, b), (), a.shape[:-2] + (a.shape[-2], b.shape[-1]),
                a.dtype)


def concat(xs: Iterable[Term], dim: int) -> Term:
    """Concatenate along ``dim`` (singleton lists collapse)."""
    xs = tuple(xs)
    assert xs
    if len(xs) == 1:
        return xs[0]
    base = xs[0].shape
    for x in xs[1:]:
        assert len(x.shape) == len(base) and all(
            x.shape[i] == base[i] for i in range(len(base)) if i != dim), \
            f"concat mismatch {[x.shape for x in xs]} dim={dim}"
    shape = tuple(sum(x.shape[dim] for x in xs) if i == dim else base[i]
                  for i in range(len(base)))
    return Term("concat", xs, (("dim", dim),), shape, xs[0].dtype)


def slice_(x: Term, starts: tuple, limits: tuple) -> Term:
    """Contiguous slice [starts, limits); full slices collapse."""
    starts, limits = tuple(starts), tuple(limits)
    assert len(starts) == len(x.shape) == len(limits)
    for s, l, d in zip(starts, limits, x.shape):
        assert 0 <= s <= l <= d, f"slice oob {starts} {limits} of {x.shape}"
    shape = tuple(l - s for s, l in zip(starts, limits))
    if shape == x.shape:
        return x
    return Term("slice", (x,), (("starts", starts), ("limits", limits)),
                shape, x.dtype)


def transpose(x: Term, perm: tuple) -> Term:
    """Axis permutation; identity permutations collapse."""
    perm = tuple(perm)
    assert sorted(perm) == list(range(len(x.shape)))
    if perm == tuple(range(len(x.shape))):
        return x
    shape = tuple(x.shape[p] for p in perm)
    return Term("transpose", (x,), (("perm", perm),), shape, x.dtype)


def reshape(x: Term, shape: tuple) -> Term:
    """Reshape to ``shape`` (same element count); no-ops collapse."""
    shape = tuple(shape)
    assert int(np.prod(shape, dtype=np.int64)) == int(np.prod(x.shape, dtype=np.int64)), \
        f"reshape {x.shape} -> {shape}"
    if shape == x.shape:
        return x
    return Term("reshape", (x,), (("shape", shape),), shape, x.dtype)


def broadcast(x: Term, shape: tuple, bdims: tuple) -> Term:
    """broadcast_in_dim: x's axes map to positions ``bdims`` of ``shape``."""
    shape, bdims = tuple(shape), tuple(bdims)
    assert len(bdims) == len(x.shape)
    for xd, od in zip(x.shape, bdims):
        assert xd == shape[od] or xd == 1
    return Term("broadcast", (x,), (("shape", shape), ("bdims", bdims)),
                shape, x.dtype)


def convert(x: Term, dtype: str = "f") -> Term:
    """Dtype cast."""
    return Term("convert", (x,), (("to", dtype),), x.shape, dtype)


def rev(x: Term, dims: tuple) -> Term:
    """Reverse along ``dims``."""
    return Term("rev", (x,), (("dims", tuple(dims)),), x.shape, x.dtype)


def reduce_(op: str, x: Term, axes: tuple) -> Term:
    """Reduction over ``axes`` (sum/max/min/prod/and/or)."""
    axes = tuple(sorted(axes))
    assert op in REDUCE_OPS
    shape = tuple(d for i, d in enumerate(x.shape) if i not in axes)
    return Term(op, (x,), (("axes", axes),), shape, x.dtype)


def reduce_sum(x: Term, axes: tuple) -> Term:
    """Sum reduction over ``axes``."""
    return reduce_("reduce_sum", x, axes)


def gather_rows(table: Term, idx: Term) -> Term:
    """Embedding lookup: table (V, D) indexed by integer idx (...,) -> (..., D)."""
    assert len(table.shape) == 2
    return Term("gather_rows", (table, idx), (),
                idx.shape + (table.shape[1],), table.dtype)


def select(pred: Term, on_true: Term, on_false: Term) -> Term:
    """Elementwise predicate select."""
    assert on_true.shape == on_false.shape
    return Term("select", (pred, on_true, on_false), (), on_true.shape,
                on_true.dtype)


def iota(shape: tuple, dim: int, dtype: str = "i") -> Term:
    """Index ramp along ``dim``."""
    return Term("iota", (), (("shape", tuple(shape)), ("dim", dim)),
                tuple(shape), dtype)


def dus(x: Term, upd: Term, starts: tuple) -> Term:
    """dynamic_update_slice: write ``upd`` into ``x`` at ``starts``."""
    return Term("dus", (x, upd), (("starts", tuple(starts)),), x.shape, x.dtype)


def cumsum(x: Term, axis: int) -> Term:
    """Cumulative sum along ``axis``."""
    return Term("cumsum", (x,), (("axis", axis),), x.shape, x.dtype)


def argmax(x: Term, axis: int) -> Term:
    """Integer argmax along ``axis`` (axis removed)."""
    shape = tuple(d for i, d in enumerate(x.shape) if i != axis)
    return Term("argmax", (x,), (("axis", axis),), shape, "i")


def opaque(name: str, args: tuple, shape: tuple, dtype: str = "f",
           attrs: tuple = ()) -> Term:
    """Uninterpreted operator (user kernels without lemmas)."""
    return Term(f"opaque:{name}", tuple(args), attrs, tuple(shape), dtype)


# ---------------------------------------------------------------------------
# Numeric evaluation (numpy) — used by property tests and certificate replay
# ---------------------------------------------------------------------------

def _np_ew1(op: str) -> Callable:
    return {
        "neg": np.negative, "exp": np.exp, "log": np.log, "tanh": np.tanh,
        "logistic": lambda x: 1 / (1 + np.exp(-x)), "rsqrt": lambda x: 1 / np.sqrt(x),
        "sqrt": np.sqrt, "sin": np.sin, "cos": np.cos, "abs": np.abs,
        "erf": _erf, "relu": lambda x: np.maximum(x, 0), "floor": np.floor,
        "sign": np.sign, "square": np.square, "stop_grad": lambda x: x,
        "log1p": np.log1p, "expm1": np.expm1, "not": np.logical_not,
    }[op]


def _erf(x):
    v = np.vectorize(math.erf)
    return v(x).astype(np.asarray(x).dtype) if np.asarray(x).dtype.kind == "f" else v(x)


def _np_ew2(op: str) -> Callable:
    return {
        "add": np.add, "sub": np.subtract, "mul": np.multiply,
        "div": np.divide, "max2": np.maximum, "min2": np.minimum,
        "pow": np.power, "eq": np.equal, "ne": np.not_equal, "lt": np.less,
        "le": np.less_equal, "gt": np.greater, "ge": np.greater_equal,
        "and": np.logical_and, "or": np.logical_or, "rem": np.remainder,
        "atan2": np.arctan2, "nextafter": np.nextafter,
        "shift_left": np.left_shift, "shift_right": np.right_shift,
    }[op]


def eval_term(t: Term, env: dict) -> np.ndarray:
    """Evaluate a term against ``env: name -> ndarray``."""
    memo: dict = {}

    def go(u: Term):
        if u in memo:
            return memo[u]
        r = _eval1(u, go, env)
        memo[u] = r
        return r

    return go(t)


def _eval1(u: Term, go, env):
    op = u.op
    if op == "tensor":
        return np.asarray(env[u.name])
    if op == "lit":
        return np.asarray(u.value)
    if op in EW1_OPS:
        return _np_ew1(op)(go(u.args[0]))
    if op == "integer_pow":
        return go(u.args[0]) ** u.attr("p")
    if op in EW2_OPS:
        if op == "add" and len(u.args) != 2:   # n-ary add normal form
            out = go(u.args[0])
            for a in u.args[1:]:
                out = np.add(out, go(a))
            return out
        return _np_ew2(op)(go(u.args[0]), go(u.args[1]))
    if op == "matmul" or op == "bmm":
        return go(u.args[0]) @ go(u.args[1])
    if op == "concat":
        return np.concatenate([go(a) for a in u.args], axis=u.attr("dim"))
    if op == "slice":
        starts, limits = u.attr("starts"), u.attr("limits")
        return go(u.args[0])[tuple(slice(s, l) for s, l in zip(starts, limits))]
    if op == "transpose":
        return np.transpose(go(u.args[0]), u.attr("perm"))
    if op == "reshape":
        return np.reshape(go(u.args[0]), u.attr("shape"))
    if op == "broadcast":
        x, shape, bdims = go(u.args[0]), u.attr("shape"), u.attr("bdims")
        expanded = np.reshape(x, tuple(
            x.shape[bdims.index(i)] if i in bdims else 1
            for i in range(len(shape))))
        return np.broadcast_to(expanded, shape)
    if op == "convert":
        return go(u.args[0]).astype(np.float64 if u.attr("to") == "f"
                                    else np.int64 if u.attr("to") == "i" else bool)
    if op == "rev":
        x = go(u.args[0])
        idx = tuple(slice(None, None, -1) if i in u.attr("dims") else slice(None)
                    for i in range(x.ndim))
        return x[idx]
    if op in REDUCE_OPS:
        fn = {"reduce_sum": np.sum, "reduce_max": np.max, "reduce_min": np.min,
              "reduce_prod": np.prod, "reduce_and": np.all,
              "reduce_or": np.any}[op]
        return fn(go(u.args[0]), axis=u.attr("axes"))
    if op == "gather_rows":
        return go(u.args[0])[go(u.args[1]).astype(np.int64)]
    if op == "select":
        return np.where(go(u.args[0]).astype(bool), go(u.args[1]), go(u.args[2]))
    if op == "iota":
        shape, dim = u.attr("shape"), u.attr("dim")
        out = np.arange(shape[dim])
        out = np.reshape(out, tuple(shape[dim] if i == dim else 1
                                    for i in range(len(shape))))
        return np.broadcast_to(out, shape)
    if op == "dus":
        x = np.array(go(u.args[0]))
        upd = go(u.args[1])
        starts = u.attr("starts")
        idx = tuple(slice(s, s + d) for s, d in zip(starts, upd.shape))
        x[idx] = upd
        return x
    if op == "cumsum":
        return np.cumsum(go(u.args[0]), axis=u.attr("axis"))
    if op == "argmax":
        return np.argmax(go(u.args[0]), axis=u.attr("axis"))
    raise NotImplementedError(f"eval of {op}")

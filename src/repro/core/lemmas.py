"""GraphGuard lemma library (paper §4.2.1, §5).

Lemmas are procedural rewrite rules over the e-graph: each is triggered by an
e-node of a given op and returns equalities to install. The e-graph makes
rewrites bidirectional automatically (both sides land in one e-class).

The library covers the normalized jaxpr op set (see ``terms.py``); it plays
the role of the paper's 92 ATen lemmas — normalization at capture time means
far fewer rules cover the same models. Lemma *sources* mirror the paper's
provenance split: ``taso`` marks rules ported from the TASO/Tensat families
(block matmul, transpose algebra), ``builtin`` marks rules we derived from
operator semantics, and user lemmas can be registered with
``register_lemma`` (evaluated in §6.5-analogue benchmark).

Constrained lemmas (paper §4.3.2) only fire when their expansive target
already exists in the e-graph — see ``lemma_slice_cover``.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .egraph import EGraph, ENode, Lemma
from .terms import (EW1_OPS, EW2_OPS, REDUCE_OPS, Term, add_n, bmm, broadcast,
                    concat, convert, dus, ew1, ew2, gather_rows, integer_pow,
                    lit, matmul, reduce_, reshape, select, slice_, transpose)

# Widest n-ary add the normal form maintains: a 16-rank multi-axis psum is a
# 16-ary node; flattening stops growing chains past this (soundness is
# unaffected — only joinability of absurdly wide chains).
MAX_ADD_WIDTH = 64


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def cls(eg: EGraph, cid: int) -> Term:
    """Build a leaf Term referring to e-class ``cid``."""
    info = eg.info(cid)
    return Term("cls", (), (("id", eg.find(cid)),), info.shape, info.dtype)


def concat_reps(eg: EGraph, cid: int):
    """All concat representations of a class: [(dim, [child cids])]."""
    out = []
    for n in eg.nodes_of(cid, "concat"):
        out.append((dict(n.attrs)["dim"], list(n.children)))
    return out


def slice_reps(eg: EGraph, cid: int):
    """All slice representations of a class: [(base cid, starts, limits)]."""
    out = []
    for n in eg.nodes_of(cid, "slice"):
        a = dict(n.attrs)
        out.append((n.children[0], a["starts"], a["limits"]))
    return out


def broadcast_reps(eg: EGraph, cid: int):
    """All broadcast representations of a class: [(src, shape, bdims)]."""
    out = []
    for n in eg.nodes_of(cid, "broadcast"):
        a = dict(n.attrs)
        out.append((n.children[0], a["shape"], a["bdims"]))
    return out


def _piece_terms(eg, cids):
    return [cls(eg, c) for c in cids]


def _rebuild_unary(node: ENode, arg: Term) -> Term:
    """Re-apply a unary-ish op (possibly with attrs) to a new argument."""
    op = node.op
    if op in EW1_OPS:
        return ew1(op, arg)
    if op == "integer_pow":
        return integer_pow(arg, dict(node.attrs)["p"])
    if op == "convert":
        return convert(arg, dict(node.attrs)["to"])
    raise AssertionError(op)


MAX_FANOUT = 16  # do not build rewrites over absurdly wide concats


# ---------------------------------------------------------------------------
# matmul / bmm block lemmas  [TASO/Tensat family]
# ---------------------------------------------------------------------------

def _matmul_block(eg: EGraph, node: ENode, cid: int):
    """Generalized matmul (..., k) x (k, n): k-split pairs with rhs row
    split; any other lhs-dim split distributes; rhs col split distributes."""
    ca, cb = node.children
    eqs = []
    a_sh = eg.info(ca).shape
    kdim = len(a_sh) - 1
    for dim, xs in concat_reps(eg, ca):
        if len(xs) > MAX_FANOUT:
            continue
        if dim == kdim:  # k split: need matching split of b on dim 0
            sizes = [eg.info(x).shape[kdim] for x in xs]
            for bdim, ys in concat_reps(eg, cb):
                if bdim != 0 or len(ys) != len(xs):
                    continue
                if [eg.info(y).shape[0] for y in ys] != sizes:
                    continue
                eqs.append((cid, add_n(matmul(cls(eg, x), cls(eg, y))
                                       for x, y in zip(xs, ys))))
        else:  # free-dim split
            eqs.append((cid, concat([matmul(cls(eg, x), cls(eg, cb))
                                     for x in xs], dim)))
    for dim, ys in concat_reps(eg, cb):
        if dim == 1 and len(ys) <= MAX_FANOUT:  # n split
            eqs.append((cid, concat([matmul(cls(eg, ca), cls(eg, y))
                                     for y in ys], kdim)))
    return eqs


def _bmm_block(eg: EGraph, node: ENode, cid: int):
    ca, cb = node.children
    a_sh = eg.info(ca).shape
    nd = len(a_sh)
    k_a, m_a = nd - 1, nd - 2
    eqs = []
    for dim, xs in concat_reps(eg, ca):
        if len(xs) > MAX_FANOUT:
            continue
        if dim == k_a:  # contraction split
            sizes = [eg.info(x).shape[k_a] for x in xs]
            for bdim, ys in concat_reps(eg, cb):
                if bdim != nd - 2 or len(ys) != len(xs):
                    continue
                if [eg.info(y).shape[nd - 2] for y in ys] != sizes:
                    continue
                eqs.append((cid, add_n(bmm(cls(eg, x), cls(eg, y))
                                       for x, y in zip(xs, ys))))
        elif dim == m_a:  # rows split
            eqs.append((cid, concat([bmm(cls(eg, x), cls(eg, cb))
                                     for x in xs], m_a)))
        else:  # batch split: need same split on b
            sizes = [eg.info(x).shape[dim] for x in xs]
            for bdim, ys in concat_reps(eg, cb):
                if bdim != dim or len(ys) != len(xs):
                    continue
                if [eg.info(y).shape[dim] for y in ys] != sizes:
                    continue
                eqs.append((cid, concat([bmm(cls(eg, x), cls(eg, y))
                                         for x, y in zip(xs, ys)], dim)))
    for dim, ys in concat_reps(eg, cb):
        if dim == nd - 1 and len(ys) <= MAX_FANOUT:  # cols split
            eqs.append((cid, concat([bmm(cls(eg, ca), cls(eg, y))
                                     for y in ys], nd - 1)))
    return eqs


# ---------------------------------------------------------------------------
# elementwise distribution over concat / broadcast
# ---------------------------------------------------------------------------

def _ew1_concat(eg: EGraph, node: ENode, cid: int):
    (cx,) = node.children
    eqs = []
    for dim, xs in concat_reps(eg, cx):
        if len(xs) > MAX_FANOUT:
            continue
        eqs.append((cid, concat([_rebuild_unary(node, cls(eg, x))
                                 for x in xs], dim)))
    return eqs


def _bcast_piece(eg: EGraph, cw: int, full_shape, bdims, piece_shape, dim) -> Optional[Term]:
    """broadcast(w) restricted to a concat piece along ``dim`` — valid iff the
    broadcast is constant along ``dim`` (source axis absent or extent 1)."""
    w_info = eg.info(cw)
    if dim in bdims:
        src_ext = w_info.shape[bdims.index(dim)]
        if src_ext != 1:
            return None
    return broadcast(cls(eg, cw), piece_shape, bdims)


def _addn_concat(eg: EGraph, node: ENode, cid: int):
    """n-ary add distributes over concat: every addend must decompose as a
    matching concat on one dim (same piece sizes), or be a broadcast
    constant along it."""
    chs = node.children
    if len(chs) > MAX_ADD_WIDTH:
        return []
    eqs = []
    seen = set()
    for anchor in chs:
        for dim, xs in concat_reps(eg, anchor):
            if len(xs) > MAX_FANOUT:
                continue
            sizes = tuple(eg.info(x).shape[dim] for x in xs)
            if (dim, sizes) in seen:
                continue
            seen.add((dim, sizes))
            cols = []
            ok = True
            for ch in chs:
                col = None
                for d2, ys in concat_reps(eg, ch):
                    if d2 == dim and len(ys) == len(xs) and \
                            tuple(eg.info(y).shape[dim] for y in ys) == sizes:
                        col = [cls(eg, y) for y in ys]
                        break
                if col is None:
                    for cw, shape, bdims in broadcast_reps(eg, ch):
                        pieces = [_bcast_piece(eg, cw, shape, bdims,
                                               eg.info(x).shape, dim)
                                  for x in xs]
                        if all(p is not None for p in pieces):
                            col = pieces
                            break
                if col is None:
                    ok = False
                    break
                cols.append(col)
            if ok:
                eqs.append((cid, concat([add_n([col[i] for col in cols])
                                         for i in range(len(xs))], dim)))
    return eqs


def _ew2_concat(eg: EGraph, node: ENode, cid: int):
    op = node.op
    if op == "add" and len(node.children) != 2:   # n-ary add normal form
        return _addn_concat(eg, node, cid)
    ca, cb = node.children
    sh_a, sh_b = eg.info(ca).shape, eg.info(cb).shape
    if sh_a != sh_b:
        return []  # scalar-lifting handled by capture normalization
    eqs = []
    for dim, xs in concat_reps(eg, ca):
        if len(xs) > MAX_FANOUT:
            continue
        sizes = [eg.info(x).shape[dim] for x in xs]
        # (1) matching concat on b
        for bdim, ys in concat_reps(eg, cb):
            if bdim != dim or len(ys) != len(xs):
                continue
            if [eg.info(y).shape[dim] for y in ys] != sizes:
                continue
            eqs.append((cid, concat([ew2(op, cls(eg, x), cls(eg, y))
                                     for x, y in zip(xs, ys)], dim)))
        # (2) b is a broadcast constant along dim
        for cw, shape, bdims in broadcast_reps(eg, cb):
            pieces = []
            ok = True
            for x in xs:
                p = _bcast_piece(eg, cw, shape, bdims, eg.info(x).shape, dim)
                if p is None:
                    ok = False
                    break
                pieces.append(ew2(op, cls(eg, x), p))
            if ok:
                eqs.append((cid, concat(pieces, dim)))
    # symmetric: concat on b, broadcast on a
    for dim, ys in concat_reps(eg, cb):
        if len(ys) > MAX_FANOUT:
            continue
        for cw, shape, bdims in broadcast_reps(eg, ca):
            pieces = []
            ok = True
            for y in ys:
                p = _bcast_piece(eg, cw, shape, bdims, eg.info(y).shape, dim)
                if p is None:
                    ok = False
                    break
                pieces.append(ew2(op, p, cls(eg, y)))
            if ok:
                eqs.append((cid, concat(pieces, dim)))
    return eqs


def _select_concat(eg: EGraph, node: ENode, cid: int):
    cp, ct, cf = node.children
    eqs = []
    for dim, ts in concat_reps(eg, ct):
        if len(ts) > MAX_FANOUT:
            continue
        sizes = [eg.info(t).shape[dim] for t in ts]
        for fdim, fs in concat_reps(eg, cf):
            if fdim != dim or [eg.info(f).shape[dim] for f in fs] != sizes:
                continue
            # pred: matching concat, or broadcast constant along dim
            for pdim, ps in concat_reps(eg, cp):
                if pdim != dim or [eg.info(p).shape[dim] for p in ps] != sizes:
                    continue
                eqs.append((cid, concat(
                    [select(cls(eg, p), cls(eg, t), cls(eg, f))
                     for p, t, f in zip(ps, ts, fs)], dim)))
            for cw, shape, bdims in broadcast_reps(eg, cp):
                pieces = []
                ok = True
                for t, f in zip(ts, fs):
                    p = _bcast_piece(eg, cw, shape, bdims, eg.info(t).shape, dim)
                    if p is None:
                        ok = False
                        break
                    pieces.append(select(p, cls(eg, t), cls(eg, f)))
                if ok:
                    eqs.append((cid, concat(pieces, dim)))
    return eqs


# ---------------------------------------------------------------------------
# reductions
# ---------------------------------------------------------------------------

def _reduce_concat(eg: EGraph, node: ENode, cid: int):
    op = node.op
    (cx,) = node.children
    axes = dict(node.attrs)["axes"]
    eqs = []
    for dim, xs in concat_reps(eg, cx):
        if len(xs) > MAX_FANOUT:
            continue
        if dim in axes:
            pieces = [reduce_(op, cls(eg, x), axes) for x in xs]
            if op == "reduce_sum":
                eqs.append((cid, add_n(pieces)))
            elif op == "reduce_max":
                t = pieces[0]
                for p in pieces[1:]:
                    t = ew2("max2", t, p)
                eqs.append((cid, t))
            elif op == "reduce_min":
                t = pieces[0]
                for p in pieces[1:]:
                    t = ew2("min2", t, p)
                eqs.append((cid, t))
        else:
            nd = dim - sum(1 for a in axes if a < dim)
            eqs.append((cid, concat([reduce_(op, cls(eg, x), axes)
                                     for x in xs], nd)))
    return eqs


def _reduce_trivial(eg: EGraph, node: ENode, cid: int):
    """Reducing axes of extent 1 is a reshape."""
    (cx,) = node.children
    axes = dict(node.attrs)["axes"]
    in_shape = eg.info(cx).shape
    if not all(in_shape[a] == 1 for a in axes):
        return []
    out_shape = tuple(d for i, d in enumerate(in_shape) if i not in axes)
    return [(cid, reshape(cls(eg, cx), out_shape))]


def _reduce_broadcast(eg: EGraph, node: ENode, cid: int):
    """reduce_sum over an axis where the input is broadcast-constant equals
    extent * value — NOT clean, but exposes scaling relationships (used in
    diagnostics for the aux-loss / grad-accum bug families)."""
    if node.op != "reduce_sum":
        return []
    (cx,) = node.children
    axes = dict(node.attrs)["axes"]
    eqs = []
    for cw, shape, bdims in broadcast_reps(eg, cx):
        if not all((a not in bdims) or eg.info(cw).shape[bdims.index(a)] == 1
                   for a in axes):
            continue
        scale = int(np.prod([shape[a] for a in axes], dtype=np.int64))
        w_info = eg.info(cw)
        kept = [i for i in range(len(shape)) if i not in axes]
        new_bdims = tuple(kept.index(b) for b in bdims if b in kept)
        inner_axes = tuple(i for i, b in enumerate(bdims) if b in axes)
        src = cls(eg, cw)
        if inner_axes:
            src = reduce_("reduce_sum", src, inner_axes)
            new_bdims = tuple(kept.index(b) for b in bdims if b not in axes)
        out_shape = tuple(shape[i] for i in kept)
        rhs = ew2("mul", broadcast(src, out_shape, new_bdims),
                  broadcast(lit(float(scale)), out_shape, ()))
        eqs.append((cid, rhs))
    return eqs


# ---------------------------------------------------------------------------
# slice / concat algebra
# ---------------------------------------------------------------------------

def _slice_of_concat(eg: EGraph, node: ENode, cid: int):
    (cx,) = node.children
    a = dict(node.attrs)
    starts, limits = a["starts"], a["limits"]
    eqs = []
    for dim, xs in concat_reps(eg, cx):
        if len(xs) > MAX_FANOUT:
            continue
        s, l = starts[dim], limits[dim]
        off = 0
        pieces = []
        ok = True
        for x in xs:
            ext = eg.info(x).shape[dim]
            lo, hi = max(s - off, 0), min(l - off, ext)
            if lo < hi:
                ps = tuple(lo if i == dim else starts[i]
                           for i in range(len(starts)))
                pl = tuple(hi if i == dim else limits[i]
                           for i in range(len(limits)))
                try:
                    pieces.append(slice_(cls(eg, x), ps, pl))
                except AssertionError:
                    ok = False
                    break
            off += ext
        if ok and pieces:
            eqs.append((cid, concat(pieces, dim) if len(pieces) > 1 else pieces[0]))
    return eqs


def _slice_of_slice(eg: EGraph, node: ENode, cid: int):
    (cx,) = node.children
    a = dict(node.attrs)
    starts, limits = a["starts"], a["limits"]
    eqs = []
    for base, bs, bl in slice_reps(eg, cx):
        ns = tuple(b + s for b, s in zip(bs, starts))
        nl = tuple(b + l for b, l in zip(bs, limits))
        eqs.append((cid, slice_(cls(eg, base), ns, nl)))
    return eqs


def _slice_of_ew(eg: EGraph, node: ENode, cid: int):
    """slice(f(x)) = f(slice(x)) for elementwise f — constrained: only fires
    if slice(x) with the same bounds already exists (avoids blowup)."""
    (cx,) = node.children
    a = dict(node.attrs)
    starts, limits = a["starts"], a["limits"]
    eqs = []
    for n in eg.nodes_of(cx):
        if n.op in EW1_OPS or n.op in ("integer_pow", "convert"):
            inner = n.children[0]
            probe = ENode("slice", (("starts", starts), ("limits", limits)),
                          (eg.find(inner),))
            if probe in eg.hashcons:  # constrained
                sub = cls(eg, eg.hashcons[probe])
                eqs.append((cid, _rebuild_unary(n, sub)))
        elif n.op in EW2_OPS:
            probes = [ENode("slice", (("starts", starts), ("limits", limits)),
                            (eg.find(c),)) for c in n.children]
            if all(p in eg.hashcons for p in probes):
                args = [cls(eg, eg.hashcons[p]) for p in probes]
                if len(args) == 2:
                    eqs.append((cid, ew2(n.op, args[0], args[1])))
                elif n.op == "add":            # n-ary add normal form
                    eqs.append((cid, add_n(args)))
    return eqs


def _concat_merge(eg: EGraph, node: ENode, cid: int):
    """concat of adjacent slices of the same base -> merged slice; also
    flatten nested concats on the same dim."""
    dim = dict(node.attrs)["dim"]
    eqs = []
    # flatten nested concat
    flat = []
    changed = False
    for ch in node.children:
        sub = None
        for n2 in eg.nodes_of(ch, "concat"):
            if dict(n2.attrs)["dim"] == dim:
                sub = n2
                break
        if sub is not None:
            flat.extend(sub.children)
            changed = True
        else:
            flat.append(ch)
    if changed and len(flat) <= 2 * MAX_FANOUT:
        eqs.append((cid, concat([cls(eg, c) for c in flat], dim)))
    # adjacent slice merge (pairwise; saturation composes)
    chs = node.children
    for i in range(len(chs) - 1):
        for b1, s1, l1 in slice_reps(eg, chs[i]):
            for b2, s2, l2 in slice_reps(eg, chs[i + 1]):
                if eg.find(b1) != eg.find(b2):
                    continue
                if l1[dim] != s2[dim]:
                    continue
                if any(k != dim and (s1[k] != s2[k] or l1[k] != l2[k])
                       for k in range(len(s1))):
                    continue
                merged = slice_(cls(eg, b1),
                                s1, tuple(l2[k] if k == dim else l1[k]
                                          for k in range(len(l1))))
                rest = ([cls(eg, c) for c in chs[:i]] + [merged]
                        + [cls(eg, c) for c in chs[i + 2:]])
                eqs.append((cid, concat(rest, dim) if len(rest) > 1 else rest[0]))
    return eqs


def _concat_exchange(eg: EGraph, node: ENode, cid: int):
    """Block-matrix concat transposition: concat_d(A, B, ...) where every
    child decomposes as a concat on a common dim d2 != d with the *same*
    piece sizes along d2 equals concat_d2 of the per-piece concat_d's:

        concat_1(concat_0(A1, A2), concat_0(B1, B2))
          = concat_0(concat_1(A1, B1), concat_1(A2, B2))

    This is what connects per-rank outputs assembled along one axis with a
    rank split along another (e.g. rotary halves concatenated on features
    under a sequence-parallel rank split)."""
    dim = dict(node.attrs)["dim"]
    chs = node.children
    if len(chs) > MAX_FANOUT:
        return []
    eqs = []
    for d2, xs in concat_reps(eg, chs[0]):
        if d2 == dim or len(xs) > MAX_FANOUT:
            continue
        sizes = [eg.info(x).shape[d2] for x in xs]
        cols = [xs]
        ok = True
        for ch in chs[1:]:
            match = None
            for dd, ys in concat_reps(eg, ch):
                if dd == d2 and len(ys) == len(xs) and \
                        [eg.info(y).shape[d2] for y in ys] == sizes:
                    match = ys
                    break
            if match is None:
                ok = False
                break
            cols.append(match)
        if not ok:
            continue
        rows = [concat([cls(eg, col[i]) for col in cols], dim)
                for i in range(len(xs))]
        eqs.append((cid, concat(rows, d2)))
    return eqs


def _concat_inject(eg: EGraph, node: ENode, cid: int):
    """Concat is injective given the split sizes: two concat representations
    of one class on the same dim with identical piece-size lists have equal
    corresponding pieces.  This is the shard-replica equality a multi-axis
    mesh needs: an input sharded on `dp` and replicated on `tp` yields one
    concat mapping per tp-replica, and only piece-wise equality connects
    rank (i, 0)'s shard with rank (i, 1)'s."""
    dim = dict(node.attrs)["dim"]
    chs = node.children
    if len(chs) > MAX_FANOUT:
        return []
    sizes = [eg.info(c).shape[dim] for c in chs]
    eqs = []
    for d2, ys in concat_reps(eg, cid):
        if d2 != dim or len(ys) != len(chs):
            continue
        if [eg.info(y).shape[dim] for y in ys] != sizes:
            continue
        for a, b in zip(chs, ys):
            if eg.find(a) != eg.find(b):
                eqs.append((a, cls(eg, b)))
    return eqs


def _reduce_add(eg: EGraph, node: ENode, cid: int):
    """reduce_sum distributes over add — CONSTRAINED (paper §4.3.2): only
    fires when both per-addend reductions already exist as e-nodes.  This is
    the reduce/psum exchange a composed 2D mesh needs: it relates the
    sequential ``sum(y)`` through ``y = psum_tp(yp)`` to the per-rank
    ``psum_{dp,tp}(sum(yp))`` without generatively splitting every sum."""
    (cx,) = node.children
    axes = dict(node.attrs)["axes"]
    eqs = []
    for n2 in eg.nodes_of(cx, "add"):
        chs = n2.children                   # n-ary add normal form
        probes = [ENode("reduce_sum", (("axes", axes),), (eg.find(c),))
                  for c in chs]
        hits = [p in eg.hashcons for p in probes]
        if not any(hits):
            continue
        # at least one addend's reduction must pre-exist; the rest may be
        # built, so one fire resolves the whole (flattened) psum chain
        terms = [cls(eg, eg.hashcons[p]) if h
                 else reduce_("reduce_sum", cls(eg, c), axes)
                 for c, p, h in zip(chs, probes, hits)]
        eqs.append((cid, add_n(terms)))
    return eqs


def _reduce_reshape(eg: EGraph, node: ENode, cid: int):
    """Reduction across a reshape boundary: when the reduced axes of
    ``reduce(reshape(x, s'), axes)`` cover *complete* segments of the
    reshape's greedy factorization (see ``_segments``), the reduction
    commutes with the reshape —

        reduce_sum(reshape(x, (-1,)), (0,)) = reduce_sum(x, (0, 1))

    This is the aux-loss pattern: G_s sums a flattened view while G_d
    reduces both axes of the local shard at once (EXPERIMENTS.md used to
    carry it as a documented completeness gap)."""
    op = node.op
    (cx,) = node.children
    axes = set(dict(node.attrs)["axes"])
    new_shape = eg.info(cx).shape
    eqs = []
    for n2 in eg.nodes_of(cx, "reshape"):
        cb = n2.children[0]
        old_shape = eg.info(cb).shape
        segs = _segments(old_shape, new_shape)
        if segs is None:
            continue
        base_axes, ok = [], True
        for old_axes, new_axes in segs:
            hit = [a for a in new_axes if a in axes]
            if not hit:
                continue
            if len(hit) != len(new_axes):  # partially-reduced segment
                ok = False
                break
            base_axes.extend(old_axes)
        if not ok or not base_axes:
            continue
        inner = reduce_(op, cls(eg, cb), tuple(sorted(base_axes)))
        out_shape = tuple(d for i, d in enumerate(new_shape) if i not in axes)
        eqs.append((cid, inner if inner.shape == out_shape
                    else reshape(inner, out_shape)))
    return eqs


def _scalar_factor(eg: EGraph, node: ENode, cid: int):
    """Constant scalar factors distribute over ``add`` (and therefore over a
    psum's expanded cross-rank add chain):

        div(add(a, b), c) = add(div(a, c), div(b, c))
        mul(add(a, b), c) = add(mul(a, c), mul(b, c))

    for a literal (or broadcast-literal) ``c`` — the converse direction of
    ``add_div_dist``, triggered on the mul/div side so a sequential
    ``psum(x) / n`` can chase the per-rank ``x / n`` pieces.

    CONSTRAINED (paper §4.3.2): at least one addend's scaled node must
    already exist in the e-graph; the rest may be built, so one fire
    resolves the whole flattened n-ary add instead of generatively scaling
    every add in sight (unconstrained, it blows up the 8-rank chains)."""
    op = node.op
    ca, cb = node.children
    eqs = []
    for left, right in ((ca, cb), (cb, ca)):
        v = _lit_of(eg, right)
        if v is None or v == 0:
            continue
        if op == "div" and left is not ca:
            continue                     # only x/c distributes, not c/x
        cr = eg.find(right)
        for n2 in eg.nodes_of(left, "add"):
            chs = n2.children            # n-ary add normal form
            hits = []
            for ch in chs:
                hit = None
                for order in (((eg.find(ch), cr)), ((cr, eg.find(ch)))):
                    pn = ENode(op, (), order)
                    if pn in eg.hashcons:
                        hit = eg.hashcons[pn]
                        break
                    if op == "div":      # div is not commutative
                        break
                hits.append(hit)
            if all(h is None for h in hits):
                continue
            terms = [cls(eg, h) if h is not None
                     else ew2(op, cls(eg, ch), cls(eg, right))
                     for ch, h in zip(chs, hits)]
            eqs.append((cid, add_n(terms)))
    return eqs


def _slice_cover(eg: EGraph, node: ENode, cid: int):
    """CONSTRAINED lemma (paper §4.3.2): X = concat(X[0:a], X[a:b], ...) only
    when complementary slices already exist as e-nodes. Triggered on slice."""
    (cx,) = node.children
    a = dict(node.attrs)
    starts, limits = a["starts"], a["limits"]
    base_info = eg.info(cx)
    nd = len(base_info.shape)
    dims = [i for i in range(nd)
            if not (starts[i] == 0 and limits[i] == base_info.shape[i])]
    if len(dims) != 1:
        return []
    d = dims[0]
    # collect sibling slices of cx along d with other dims full
    sibs = []
    for pnode, pcid in eg.info(cx).parents:
        pn = pnode.canonical(eg.find)
        if pn.op != "slice" or eg.find(pn.children[0]) != eg.find(cx):
            continue
        pa = dict(pn.attrs)
        ps, pl2 = pa["starts"], pa["limits"]
        if all(i == d or (ps[i] == 0 and pl2[i] == base_info.shape[i])
               for i in range(nd)):
            sibs.append((ps[d], pl2[d], eg.find(pcid)))
    sibs = sorted(set(sibs))
    # greedy chain from 0 to extent
    chain, pos = [], 0
    for s, l, c in sibs:
        if s == pos and l > pos:
            chain.append((s, l, c))
            pos = l
        elif s < pos:
            continue
        elif s > pos:
            # gap: chain broken; restart if this piece starts at 0
            if s == 0:
                chain, pos = [(s, l, c)], l
            else:
                return []
    if pos != base_info.shape[d] or len(chain) < 2:
        return []
    return [(eg.find(cx), concat([cls(eg, c) for _, _, c in chain], d))]


# ---------------------------------------------------------------------------
# transpose / reshape structure
# ---------------------------------------------------------------------------

def _transpose_lemmas(eg: EGraph, node: ENode, cid: int):
    (cx,) = node.children
    perm = dict(node.attrs)["perm"]
    eqs = []
    for dim, xs in concat_reps(eg, cx):
        if len(xs) > MAX_FANOUT:
            continue
        eqs.append((cid, concat([transpose(cls(eg, x), perm) for x in xs],
                                perm.index(dim))))
    for base, s, l in slice_reps(eg, cx):
        ns = tuple(s[p] for p in perm)
        nl = tuple(l[p] for p in perm)
        eqs.append((cid, slice_(transpose(cls(eg, base), perm), ns, nl)))
    for n2 in eg.nodes_of(cx, "transpose"):
        inner_perm = dict(n2.attrs)["perm"]
        comp = tuple(inner_perm[p] for p in perm)
        eqs.append((cid, transpose(cls(eg, n2.children[0]), comp)))
    # 2-D: transpose(matmul(a,b)) = matmul(b^T, a^T)
    if perm == (1, 0):
        for n2 in eg.nodes_of(cx, "matmul"):
            a2, b2 = n2.children
            eqs.append((cid, matmul(transpose(cls(eg, b2), (1, 0)),
                                    transpose(cls(eg, a2), (1, 0)))))
    return eqs


def _segments(old_shape, new_shape):
    """Greedy factorization of a reshape into segments: returns a list of
    (old_axes, new_axes) groups with equal products, or None."""
    segs = []
    i = j = 0
    no, nn = len(old_shape), len(new_shape)
    while i < no or j < nn:
        oi, nj = [i], [j]
        if i >= no or j >= nn:
            # trailing 1s
            while i < no:
                if old_shape[i] != 1:
                    return None
                segs.append(((i,), ()))
                i += 1
            while j < nn:
                if new_shape[j] != 1:
                    return None
                segs.append(((), (j,)))
                j += 1
            break
        po, pn = old_shape[i], new_shape[j]
        i += 1
        j += 1
        while po != pn:
            if po < pn:
                if i >= no:
                    return None
                po *= old_shape[i]
                oi.append(i)
                i += 1
            else:
                if j >= nn:
                    return None
                pn *= new_shape[j]
                nj.append(j)
                j += 1
        segs.append((tuple(oi), tuple(nj)))
    return segs


def _reshape_lemmas(eg: EGraph, node: ENode, cid: int):
    (cx,) = node.children
    new_shape = dict(node.attrs)["shape"]
    old_shape = eg.info(cx).shape
    eqs = []
    for n2 in eg.nodes_of(cx, "reshape"):
        eqs.append((cid, reshape(cls(eg, n2.children[0]), new_shape)))
    segs = _segments(old_shape, new_shape)
    for dim, xs in concat_reps(eg, cx):
        if len(xs) > MAX_FANOUT or segs is None:
            continue
        seg = next((s for s in segs if dim in s[0]), None)
        if seg is None or not seg[1]:
            continue
        old_axes, new_axes = seg
        if old_axes.index(dim) != 0:
            continue  # concat axis must be outermost in its segment
        # trailing factor within the segment that each piece must divide
        inner_old = int(np.prod([old_shape[a] for a in old_axes[1:]],
                                dtype=np.int64))
        inner_new = int(np.prod([new_shape[a] for a in new_axes[1:]],
                                dtype=np.int64))
        ndim0 = new_axes[0]
        ok = True
        pieces = []
        for x in xs:
            pc = eg.info(x).shape[dim]
            tot = pc * inner_old
            if tot % inner_new:
                ok = False
                break
            pshape = tuple(tot // inner_new if k == ndim0 else new_shape[k]
                           for k in range(len(new_shape)))
            pieces.append(reshape(cls(eg, x), pshape))
        if ok:
            eqs.append((cid, concat(pieces, ndim0)))
    return eqs


# ---------------------------------------------------------------------------
# broadcast structure
# ---------------------------------------------------------------------------

def _broadcast_lemmas(eg: EGraph, node: ENode, cid: int):
    (cx,) = node.children
    a = dict(node.attrs)
    shape, bdims = a["shape"], a["bdims"]
    eqs = []
    # broadcast of concat distributes when the concat dim survives
    for dim, xs in concat_reps(eg, cx):
        if len(xs) > MAX_FANOUT:
            continue
        od = bdims[dim]
        if eg.info(cx).shape[dim] == shape[od]:
            pieces = []
            for x in xs:
                psh = tuple(eg.info(x).shape[dim] if k == od else shape[k]
                            for k in range(len(shape)))
                pieces.append(broadcast(cls(eg, x), psh, bdims))
            eqs.append((cid, concat(pieces, od)))
    # broadcast of broadcast composes
    for cw, sh2, bd2 in broadcast_reps(eg, cx):
        comp = tuple(bdims[b] for b in bd2)
        eqs.append((cid, broadcast(cls(eg, cw), shape, comp)))
    # identity broadcast
    if eg.info(cx).shape == shape and bdims == tuple(range(len(shape))):
        eqs.append((cid, eg.find(cx)))
    # CONSTRAINED broadcast split (symmetric): among broadcasts of the same
    # source with the same bdims differing in one constant dim, the larger
    # equals a concat of copies of the smaller.
    src_info = eg.info(cx)
    for pnode, pcid in src_info.parents:
        pn = pnode.canonical(eg.find)
        if pn.op != "broadcast" or eg.find(pn.children[0]) != eg.find(cx):
            continue
        pa = dict(pn.attrs)
        if pa["bdims"] != bdims:
            continue
        pshape = pa["shape"]
        if len(pshape) != len(shape):
            continue
        diff = [i for i in range(len(shape)) if pshape[i] != shape[i]]
        if len(diff) != 1:
            continue
        d = diff[0]
        small, big = sorted([(pshape[d], eg.find(pcid)), (shape[d], cid)])
        if small[0] == 0 or big[0] % small[0]:
            continue
        if d in bdims and src_info.shape[bdims.index(d)] != 1:
            continue  # not constant along d
        k = big[0] // small[0]
        if k > MAX_FANOUT or k < 2:
            continue
        piece = cls(eg, small[1])
        eqs.append((big[1], concat([piece] * k, d)))
    return eqs


# ---------------------------------------------------------------------------
# gather (embedding) lemmas
# ---------------------------------------------------------------------------

def _gather_lemmas(eg: EGraph, node: ENode, cid: int):
    ctab, cidx = node.children
    eqs = []
    idx_nd = len(eg.info(cidx).shape)
    for dim, ix in concat_reps(eg, cidx):
        if len(ix) > MAX_FANOUT:
            continue
        eqs.append((cid, concat([gather_rows(cls(eg, ctab), cls(eg, i))
                                 for i in ix], dim)))
    for dim, ts in concat_reps(eg, ctab):
        if dim == 1 and len(ts) <= MAX_FANOUT:  # feature split
            eqs.append((cid, concat([gather_rows(cls(eg, t), cls(eg, cidx))
                                     for t in ts], idx_nd)))
    return eqs


# ---------------------------------------------------------------------------
# algebraic normalization
# ---------------------------------------------------------------------------

def _add_norm(eg: EGraph, node: ENode, cid: int):
    """Flattened n-ary add normal form (replaces assoc/comm saturation).

    Every ``add`` e-node is driven toward one canonical representation:
    addends that are themselves adds are inlined (flattening — this is
    associativity, resolved structurally instead of by generative
    rotation), and the flattened addend list is re-installed sorted by
    e-class id (commutativity — two adds over the same multiset of
    classes meet in the sorted node).  One rewrite per node per round vs
    the old ``add_mul_acom``'s O(Catalan) regrouping saturation, which is
    what blew up the 16-rank ``tp_dp_2d@(4,4)`` psum chains and taxed
    ``fsdp_mlp@8`` ~21 s (EXPERIMENTS.md).  ``mul`` keeps plain binary
    commutativity."""
    op = node.op
    if op == "mul":
        ca, cb = node.children
        return [(cid, ew2("mul", cls(eg, cb), cls(eg, ca)))]
    chs = [eg.find(c) for c in node.children]
    flat = []
    for c in chs:
        reps = sorted(eg.nodes_of(c, "add"),
                      key=lambda n: (len(n.children), n.children))
        if reps and len(flat) + len(reps[0].children) <= MAX_ADD_WIDTH:
            flat.extend(eg.find(x) for x in reps[0].children)
        else:
            flat.append(c)
    canon = sorted(flat)
    if canon == chs:
        return []
    return [(cid, add_n([cls(eg, c) for c in canon]))]


def _sub_to_add(eg: EGraph, node: ENode, cid: int):
    ca, cb = node.children
    return [(cid, ew2("add", cls(eg, ca), ew1("neg", cls(eg, cb))))]


def _neg_identity(eg: EGraph, node: ENode, cid: int):
    (cx,) = node.children
    eqs = []
    for n2 in eg.nodes_of(cx, "neg"):
        eqs.append((cid, eg.find(n2.children[0])))
    return eqs


def _dus_full(eg: EGraph, node: ENode, cid: int):
    cx, cu = node.children
    if eg.info(cx).shape == eg.info(cu).shape:
        return [(cid, eg.find(cu))]
    return []


def _dus_concat(eg: EGraph, node: ENode, cid: int):
    """CONSTRAINED (paper §4.3.2): a *complete* dynamic_update_slice chain
    over a zero-initialized buffer is the concat of its updates:

        dus(dus(zeros, u0, (0, 0)), u1, (k, 0)) = concat(u0, u1, dim=0)

    when the updates exactly tile the buffer (contiguous, non-overlapping)
    along one dim with every other dim written in full.  This is the
    microbatch-accumulation scatter-buffer pattern — per-microbatch grads
    written into a zeros buffer then re-reduced — which EXPERIMENTS.md
    carried as the ``grad_accum`` completeness gap: without this lemma the
    buffer's reduce never equals the sum of the pieces.  Only the chain
    head covers the full buffer, so inner dus nodes bail cheaply."""
    base_shape = eg.info(cid).shape
    nd = len(base_shape)
    d = None
    pieces = []                      # (start, limit, update class)
    cur = node
    base = None
    for _ in range(MAX_FANOUT + 1):
        cx, cu = cur.children
        starts = dict(cur.attrs)["starts"]
        u_shape = eg.info(cu).shape
        if len(u_shape) != nd:
            return []
        dims = [i for i in range(nd)
                if not (starts[i] == 0 and u_shape[i] == base_shape[i])]
        if len(dims) != 1:
            # a full-buffer write anywhere in the chain makes the pieces
            # below it dead (dus_full covers the head case) — treating it
            # as a tile along the other writes' dim would be UNSOUND
            return []
        if d is None:
            d = dims[0]
        elif dims[0] != d:
            return []
        pieces.append((starts[d], starts[d] + u_shape[d], eg.find(cu)))
        subs = sorted(eg.nodes_of(eg.find(cx), "dus"),
                      key=lambda n: (n.attrs, n.children))
        if not subs:
            base = eg.find(cx)
            break
        cur = subs[0]
    # the chain must bottom out in a zero-init buffer (a literal 0 or a
    # broadcast of one — `_lit_of` chases both, cycle-safely)
    if base is None or _lit_of(eg, base) != 0 or d is None:
        return []
    # later writes win, so require a strict non-overlapping exact tiling
    pieces.sort()
    if len(pieces) < 2 or pieces[0][0] != 0 \
            or pieces[-1][1] != base_shape[d]:
        return []
    for (s1, l1, _), (s2, _l2, _) in zip(pieces, pieces[1:]):
        if l1 != s2:
            return []
    return [(cid, concat([cls(eg, c) for _, _, c in pieces], d))]


def _dus_unfold(eg: EGraph, node: ENode, cid: int):
    """A dynamic_update_slice is the concat of the untouched prefix, the
    written window, and the untouched suffix along the first dim the update
    does not cover in full:

        dus(x, u, s) = concat(x[:s_d], inner, x[s_d+u_d:], dim=d)

    where ``inner`` is ``u`` itself when ``d`` is the only partial dim, and
    a residual dus into the sliced slab otherwise (peeling one dim per
    fire).  This is the cache-write normal form servecheck's decode-step
    obligations reduce through: a KV-cache write meets its per-rank sharded
    implementation in slice/concat algebra, where the block lemmas and the
    relation machinery live, instead of as an opaque dus.

    Bounded: one concat and at most two slices per fire, at most ``ndim``
    fires per chain link (chain *heads* over a zero buffer additionally
    collapse to a flat concat via ``dus_concat``)."""
    cx, cu = node.children
    starts = dict(node.attrs)["starts"]
    base_shape = eg.info(cid).shape
    u_shape = eg.info(cu).shape
    nd = len(base_shape)
    if len(u_shape) != nd:
        return []
    d = next((i for i in range(nd)
              if not (starts[i] == 0 and u_shape[i] == base_shape[i])), None)
    if d is None:
        return []                        # full overwrite — dus_full's case
    x, u = cls(eg, cx), cls(eg, cu)
    lo, hi = starts[d], starts[d] + u_shape[d]
    if hi > base_shape[d]:
        return []                        # malformed write — leave it opaque
    others_partial = any(
        i != d and not (starts[i] == 0 and u_shape[i] == base_shape[i])
        for i in range(nd))
    if others_partial:
        slab = slice_(x, tuple(lo if i == d else 0 for i in range(nd)),
                      tuple(hi if i == d else base_shape[i]
                            for i in range(nd)))
        inner = dus(slab, u, tuple(0 if i == d else starts[i]
                                   for i in range(nd)))
    else:
        inner = u
    pieces = []
    if lo > 0:
        pieces.append(slice_(x, (0,) * nd,
                             tuple(lo if i == d else base_shape[i]
                                   for i in range(nd))))
    pieces.append(inner)
    if hi < base_shape[d]:
        pieces.append(slice_(x, tuple(hi if i == d else 0 for i in range(nd)),
                             base_shape))
    return [(cid, concat(pieces, d))]


def _lit_of(eg: EGraph, cid: int, _seen: Optional[set] = None):
    """Return the scalar literal value if this class is lit or broadcast(lit).
    Cycle-safe: merged classes can hold broadcast chains that loop."""
    cid = eg.find(cid)
    if _seen is None:
        _seen = set()
    if cid in _seen:
        return None
    _seen.add(cid)
    for n in eg.nodes_of(cid, "lit"):
        return dict(n.attrs)["value"]
    for n in eg.nodes_of(cid, "broadcast"):
        v = _lit_of(eg, n.children[0], _seen)
        if v is not None:
            return v
    return None


def _mul_lit_fold(eg: EGraph, node: ENode, cid: int):
    """mul(mul(x, c1), c2) = mul(x, c1*c2); div(x, c) = mul(x, 1/c);
    mul(x, 1) = x — scalar-literal algebra (grad-scaling bug family)."""
    op = node.op
    ca, cb = node.children
    eqs = []
    shape = eg.info(cid).shape

    def bl(v):
        t = lit(float(v))
        return broadcast(t, shape, ()) if shape else t

    for left, right in ((ca, cb), (cb, ca)):
        v = _lit_of(eg, right)
        if v is None or v == 0:
            continue
        if op == "div":
            if left is ca:   # only x/c, not c/x
                eqs.append((cid, ew2("mul", cls(eg, ca), bl(1.0 / v))))
            continue
        # op == mul
        if v == 1:
            eqs.append((cid, eg.find(left)))
        for n2 in eg.nodes_of(left, "mul"):
            xa, xb = n2.children
            for l2, r2 in ((xa, xb), (xb, xa)):
                v2 = _lit_of(eg, r2)
                if v2 is not None:
                    eqs.append((cid, ew2("mul", cls(eg, l2), bl(v * v2))))
        for n2 in eg.nodes_of(left, "div"):
            v2 = _lit_of(eg, n2.children[1])
            if v2:
                eqs.append((cid, ew2("mul", cls(eg, n2.children[0]),
                                     bl(v / v2))))
        if op == "mul" and left is ca and right is cb:
            break   # symmetric handling done via loop
    return eqs


def _zero_one_identity(eg: EGraph, node: ENode, cid: int):
    """add(x, 0) = x; mul(x, 1) = x; mul(x, 0) = 0; add(x, x) = 2x.
    n-ary adds drop their literal-zero addends."""
    op = node.op
    eqs = []
    shape = eg.info(cid).shape

    def bl(v):
        t = lit(float(v))
        return broadcast(t, shape, ()) if shape else t

    if op == "add" and len(node.children) != 2:   # n-ary add normal form
        keep = [c for c in node.children if _lit_of(eg, c) != 0]
        if len(keep) == len(node.children):
            return []
        if not keep:
            return [(cid, bl(0.0))]
        if len(keep) == 1:
            return [(cid, eg.find(keep[0]))]
        return [(cid, add_n([cls(eg, c) for c in keep]))]
    ca, cb = node.children

    for left, right in ((ca, cb), (cb, ca)):
        v = _lit_of(eg, right)
        if v is None:
            continue
        if op == "add" and v == 0:
            eqs.append((cid, eg.find(left)))
        if op == "mul" and v == 0:
            eqs.append((cid, bl(0.0)))
    if op == "add" and eg.find(ca) == eg.find(cb) and len(shape) <= 1:
        eqs.append((cid, ew2("mul", cls(eg, ca), bl(2.0))))
    return eqs


def _add_div_dist(eg: EGraph, node: ENode, cid: int):
    """add(div(a,c), ..., div(z,c)) = div(add(a,...,z), c) and the mul
    analogue for literal c — non-generative factoring for the loss-scaling
    bug family, over the flattened n-ary add normal form (every addend
    must carry the same literal factor)."""
    chs = node.children
    eqs = []
    # div: candidate divisors come from the first addend's div reps
    for na in eg.nodes_of(chs[0], "div"):
        va = _lit_of(eg, na.children[1])
        if va is None:
            continue
        nums = [cls(eg, na.children[0])]
        ok = True
        for ch in chs[1:]:
            m = None
            for nb in eg.nodes_of(ch, "div"):
                if _lit_of(eg, nb.children[1]) == va:
                    m = nb.children[0]
                    break
            if m is None:
                ok = False
                break
            nums.append(cls(eg, m))
        if ok:
            eqs.append((cid, ew2("div", add_n(nums),
                                 cls(eg, na.children[1]))))
    for na in eg.nodes_of(chs[0], "mul"):
        for ia in (0, 1):
            va = _lit_of(eg, na.children[ia])
            if va is None:
                continue
            nums = [cls(eg, na.children[1 - ia])]
            ok = True
            for ch in chs[1:]:
                m = None
                for nb in eg.nodes_of(ch, "mul"):
                    for ib in (0, 1):
                        if _lit_of(eg, nb.children[ib]) == va:
                            m = nb.children[1 - ib]
                            break
                    if m is not None:
                        break
                if m is None:
                    ok = False
                    break
                nums.append(cls(eg, m))
            if ok:
                eqs.append((cid, ew2("mul", add_n(nums),
                                     cls(eg, na.children[ia]))))
    return eqs


def _convert_convert(eg: EGraph, node: ENode, cid: int):
    (cx,) = node.children
    to = dict(node.attrs)["to"]
    eqs = []
    for n2 in eg.nodes_of(cx, "convert"):
        eqs.append((cid, convert(cls(eg, n2.children[0]), to)))
    if eg.info(cx).dtype == to:
        eqs.append((cid, eg.find(cx)))
    return eqs


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

LEMMAS: list[Lemma] = [
    Lemma("matmul_block", {"matmul"}, _matmul_block, source="taso"),
    Lemma("bmm_block", {"bmm"}, _bmm_block, source="taso"),
    Lemma("ew1_concat", EW1_OPS | {"integer_pow", "convert"}, _ew1_concat),
    Lemma("ew2_concat", EW2_OPS, _ew2_concat),
    Lemma("select_concat", {"select"}, _select_concat),
    Lemma("reduce_concat", REDUCE_OPS, _reduce_concat),
    Lemma("reduce_broadcast", {"reduce_sum"}, _reduce_broadcast),
    Lemma("reduce_trivial", REDUCE_OPS, _reduce_trivial),
    Lemma("reduce_reshape", {"reduce_sum", "reduce_max", "reduce_min"},
          _reduce_reshape),
    Lemma("scalar_factor", {"mul", "div"}, _scalar_factor),
    Lemma("slice_of_concat", {"slice"}, _slice_of_concat, source="taso"),
    Lemma("slice_of_slice", {"slice"}, _slice_of_slice, source="taso"),
    Lemma("slice_of_ew", {"slice"}, _slice_of_ew),
    Lemma("concat_merge", {"concat"}, _concat_merge, source="taso"),
    Lemma("concat_exchange", {"concat"}, _concat_exchange, source="taso"),
    Lemma("concat_inject", {"concat"}, _concat_inject),
    Lemma("reduce_add", {"reduce_sum"}, _reduce_add),
    Lemma("slice_cover", {"slice"}, _slice_cover),
    Lemma("transpose_alg", {"transpose"}, _transpose_lemmas, source="taso"),
    Lemma("reshape_alg", {"reshape"}, _reshape_lemmas),
    Lemma("broadcast_alg", {"broadcast"}, _broadcast_lemmas),
    Lemma("gather_split", {"gather_rows"}, _gather_lemmas),
    Lemma("add_norm", {"add", "mul"}, _add_norm),
    Lemma("mul_lit_fold", {"mul", "div"}, _mul_lit_fold),
    Lemma("zero_one_identity", {"add", "mul"}, _zero_one_identity),
    Lemma("add_div_dist", {"add"}, _add_div_dist),
    Lemma("sub_to_add", {"sub"}, _sub_to_add),
    Lemma("neg_neg", {"neg"}, _neg_identity),
    Lemma("dus_full", {"dus"}, _dus_full),
    Lemma("dus_concat", {"dus"}, _dus_concat),
    Lemma("dus_unfold", {"dus"}, _dus_unfold),
    Lemma("convert_fold", {"convert"}, _convert_convert),
]

_USER_LEMMAS: list[Lemma] = []


def register_lemma(name: str, ops, fn, source: str = "user") -> Lemma:
    """User extension point (paper §6.5): register a lemma for a custom op."""
    lem = Lemma(name, ops, fn, source=source)
    _USER_LEMMAS.append(lem)
    return lem


def all_lemmas() -> list[Lemma]:
    """The active rule set: built-in LEMMAS plus registered user lemmas."""
    return LEMMAS + _USER_LEMMAS

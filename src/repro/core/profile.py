"""Lightweight per-phase instrumentation + engine optimization toggles.

The inference hot path (saturate / rebuild / frontier / extract) is timed with
plain ``perf_counter`` accumulation — no context-manager overhead in the inner
loops. ``Certificate.stats["phase_s"]`` surfaces the accumulated seconds and
``stats["counters"]`` the dispatch/cache counters, so every benchmark run can
attribute wall time to a phase.

``OptConfig`` gates each of the engine optimizations independently so the
benchmark harness can measure the un-optimized baseline on the same commit
(``GRAPHGUARD_OPT=0 python benchmarks/run.py`` or
``set_optimizations(False)``):

  indexed_dispatch    op-indexed lemma table in ``EGraph.saturate`` instead of
                      scanning every lemma per pending node
  deferred_rebuild    congruence repair once per saturation round instead of
                      after every pending node
  incremental_extract worklist cost propagation + per-class cost cache keyed
                      on ``EGraph.version`` (re-extraction after no growth is
                      a dict lookup)
  indexed_frontier    leaf-name -> pending-def index with unmet-dependency
                      counts in ``GraphGuard._grow_frontier`` instead of
                      rescanning all pending G_d defs
  cached_nodes        canonical node sets of ``EGraph.nodes_of`` cached per
                      class, invalidated by union version + targeted pops

All toggles are behaviour-preserving: they change *when* work happens, never
which equalities hold, so certificates are identical either way (covered by
``tests/test_graphguard.py::test_optimizations_behaviour_preserving``).
"""
from __future__ import annotations

import os
from dataclasses import dataclass, fields


@dataclass
class OptConfig:
    """Engine optimization toggles (all on by default; certificates are
    byte-identical across settings).  Set via ``GRAPHGUARD_OPT`` or
    ``set_optimizations``."""
    indexed_dispatch: bool = True
    deferred_rebuild: bool = True
    incremental_extract: bool = True
    indexed_frontier: bool = True
    cached_nodes: bool = True

    @classmethod
    def from_env(cls) -> "OptConfig":
        on = os.environ.get("GRAPHGUARD_OPT", "1").lower() \
            not in ("0", "off", "false", "no")
        return cls(**{f.name: on for f in fields(cls)})

    def as_dict(self) -> dict:
        return {f.name: getattr(self, f.name) for f in fields(self)}


# Process-wide config (mutated in place so modules that imported CONFIG see
# toggles applied later, e.g. by the benchmark ablation section).
CONFIG = OptConfig.from_env()


def set_optimizations(enabled: bool, **overrides) -> None:
    """Toggle all engine optimizations (keyword args override per-flag)."""
    for f in fields(OptConfig):
        setattr(CONFIG, f.name, overrides.get(f.name, enabled))


def explain_enabled(override=None) -> bool:
    """Resolve the proof-provenance toggle: an explicit ``explain=`` engine
    option wins; otherwise ``GRAPHGUARD_EXPLAIN`` is the ambient default
    (inherited by spawn pool workers through the environment)."""
    if override is not None:
        return bool(override)
    return os.environ.get("GRAPHGUARD_EXPLAIN", "0").lower() \
        not in ("0", "off", "false", "no", "")


class Profile:
    """Accumulating per-phase timers and counters (all costs are adds)."""

    __slots__ = ("timers", "counters", "lemma_calls_by", "lemma_hits_by")

    def __init__(self):
        self.timers: dict[str, float] = {}
        self.counters: dict[str, int] = {}
        self.lemma_calls_by: dict[str, int] = {}
        self.lemma_hits_by: dict[str, int] = {}

    def add_time(self, phase: str, dt: float) -> None:
        self.timers[phase] = self.timers.get(phase, 0.0) + dt

    def count(self, key: str, n: int = 1) -> None:
        self.counters[key] = self.counters.get(key, 0) + n

    def count_lemma(self, name: str, hit: bool) -> None:
        """One lemma invocation (``hit``: it produced equalities)."""
        self.lemma_calls_by[name] = self.lemma_calls_by.get(name, 0) + 1
        if hit:
            self.lemma_hits_by[name] = self.lemma_hits_by.get(name, 0) + 1

    def lemma_stats(self, fire_counts: dict | None = None) -> dict:
        """Per-lemma calls/hits (+fires when collected), sorted by name.

        Deterministic across worker counts and tracing on/off — it is
        surfaced as ``Certificate.stats["lemmas"]``, which ships in the
        (cached, golden-diffed) certificate payload.
        """
        out: dict[str, dict] = {}
        for name in sorted(self.lemma_calls_by):
            out[name] = {"calls": self.lemma_calls_by[name],
                         "hits": self.lemma_hits_by.get(name, 0)}
            if fire_counts is not None:
                out[name]["fires"] = fire_counts.get(name, 0)
        return out

    def phase_seconds(self) -> dict:
        return dict(self.timers)

    def counter_values(self) -> dict:
        out = dict(self.counters)
        calls = out.get("lemma_calls", 0)
        if calls:
            out["lemma_hit_rate"] = round(out.get("lemma_hits", 0) / calls, 4)
        probes = out.get("extract_calls", 0)
        if probes:
            out["extract_cache_hit_rate"] = round(
                out.get("extract_cache_hits", 0) / probes, 4)
        return out

"""Computation-graph capture: jaxpr -> GraphGuard Graph IR.

The paper's capture layer is TorchDynamo (§5.1); ours is ``jax.make_jaxpr``.
Two capture paths:

  * ``capture(fn, avals, names)`` — the sequential model ``G_s``.
  * ``capture_spmd(fn, mesh_axes, in_specs, avals, names)`` — the distributed
    implementation as a shard_map program. The inner jaxpr is the *per-rank*
    SPMD program with explicit collective primitives (psum / all_gather /
    reduce_scatter / all_to_all / ppermute / axis_index). ``expand_spmd``
    instantiates it once per rank coordinate, folding ``axis_index`` to a
    literal and translating each collective into *pure cross-rank ops*:

        psum            ->  add over the rank group
        all_gather      ->  concat over the rank group
        reduce_scatter  ->  slice(add over group, rank block)
        all_to_all      ->  concat of per-source slices
        ppermute        ->  renaming (or zeros for uncovered ranks)

    so the lemma engine never needs to know about communication.

Primitive normalization maps jaxpr primitives to the small op vocabulary in
``terms.py``; ``dot_general`` is canonicalized to ``matmul``/``bmm`` with
explicit transposes/reshapes; ``pad`` becomes concat-with-zero-blocks (which
is what makes pad/slice mismatch bugs provable); scalar operands are lifted
to explicit ``broadcast`` so elementwise lemmas stay shape-uniform.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import numpy as np

import jax
import jax.extend.core  # noqa: F401  (jax.extend requires explicit import)
import jax.numpy as jnp
from jax.sharding import AbstractMesh, PartitionSpec

from . import terms as T
from .terms import Term

# --- shard_map API compatibility (jax >= 0.6 vs 0.4.x) ---------------------
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # jax 0.4.x: experimental namespace, check_rep instead of check_vma
    from jax.experimental.shard_map import shard_map as _shard_map


def _make_abstract_mesh(mesh_axes: dict) -> AbstractMesh:
    axis_names = tuple(mesh_axes)
    sizes = tuple(mesh_axes.values())
    if hasattr(jax.sharding, "AxisType"):  # new-style constructor
        return AbstractMesh(sizes, axis_names,
                            axis_types=(jax.sharding.AxisType.Auto,)
                            * len(axis_names))
    return AbstractMesh(tuple(zip(axis_names, sizes)))


def _wrap_shard_map(fn, mesh, in_specs):
    try:
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=PartitionSpec(), check_vma=False)
    except TypeError:
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=PartitionSpec(), check_rep=False)


def _eqn_in_specs(eqn) -> list:
    """Per-operand PartitionSpecs of a shard_map eqn, across jax versions
    (new: ``in_specs`` param; 0.4.x: ``in_names`` dim->axes dicts)."""
    if "in_specs" in eqn.params:
        return list(eqn.params["in_specs"])
    specs = []
    for names in eqn.params["in_names"]:
        nd = max(names) + 1 if names else 0
        specs.append(PartitionSpec(*(names.get(d) for d in range(nd))))
    return specs


# ---------------------------------------------------------------------------
# Graph IR
# ---------------------------------------------------------------------------

@dataclass
class Graph:
    """Straight-line tensor program: ordered ``defs`` of name := Term(leaves
    are previously-defined names / inputs / consts / literals)."""
    inputs: list
    outputs: list
    defs: list          # [(name, Term)]
    shapes: dict        # name -> shape tuple
    dtypes: dict        # name -> 'f' | 'i' | 'b'
    consts: dict = field(default_factory=dict)   # name -> np.ndarray

    def tensor(self, name: str) -> Term:
        return T.tensor(name, self.shapes[name], self.dtypes[name])

    @property
    def n_ops(self) -> int:
        return len(self.defs)


def _dt(dtype) -> str:
    k = np.dtype(dtype).kind
    return {"f": "f", "b": "b", "i": "i", "u": "i", "V": "f"}.get(k, "f")


# ---------------------------------------------------------------------------
# Capture driver
# ---------------------------------------------------------------------------

# Strict-mode hook stack (installed by ``from_jaxpr.strict_capture``): each
# entry is called as ``hook(eqn, reason)`` right before a lenient fallback —
# an unknown primitive becoming an opaque term, or an over-budget scan
# raising a bare CaptureError — so the generic frontend can raise a
# structured ``UnsupportedPrimitive`` naming the eqn and its source location.
_EQN_HOOKS: list = []


def _on_unsupported(eqn, reason: str) -> None:
    """Notify strict-mode hooks that ``eqn`` has no clean term lowering."""
    for hook in reversed(_EQN_HOOKS):
        hook(eqn, reason)


class _Namer:
    def __init__(self):
        self.n = 0
        self.map = {}

    def of(self, var) -> str:
        if var not in self.map:
            self.map[var] = f"t{self.n}"
            self.n += 1
        return self.map[var]

    def fresh(self) -> str:
        nm = f"t{self.n}"
        self.n += 1
        return nm

    def set(self, var, name):
        self.map[var] = name


def capture(fn: Callable, avals: Sequence, names: Sequence[str],
            graph_tag: str = "") -> Graph:
    """Capture ``fn(*args)`` into a Graph. ``avals`` are ShapeDtypeStructs."""
    closed = jax.make_jaxpr(fn)(*avals)
    return _jaxpr_to_graph(closed, list(names), graph_tag)


def capture_chain(stages, init_avals, init_names):
    """Capture a *named-block sequence* instead of one opaque jaxpr.

    ``stages`` is a list of ``(name, fn, extra_avals, extra_names)``; stage
    *k* is traced as ``fn(*carry, *extras)`` where ``carry`` is the previous
    stage's output avals (the model activations flowing block to block) and
    ``extras`` are the stage's own parameters.  Carried tensors are named
    ``{stage}.out{j}`` and parameters ``{stage}.{param}``, so graph *k+1*'s
    input names are exactly graph *k*'s output names — the seam contract
    ``repro.modelcheck`` verifies per block.

    Returns ``(graphs, carry_avals, carry_names)`` where ``graphs`` is the
    ordered ``[(stage name, Graph)]`` list and the carry reflects the final
    stage's outputs.
    """
    carry_avals = list(init_avals)
    carry_names = list(init_names)
    graphs = []
    for name, fn, extra_avals, extra_names in stages:
        avals = carry_avals + list(extra_avals)
        names = carry_names + [f"{name}.{n}" for n in extra_names]
        g = capture(fn, avals, names)
        out_shape = jax.eval_shape(fn, *avals)
        leaves = jax.tree_util.tree_leaves(out_shape)
        carry_avals = [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]
        carry_names = [f"{name}.out{j}" for j in range(len(leaves))]
        graphs.append((name, g))
    return graphs, carry_avals, carry_names


@dataclass
class SpmdCapture:
    """A traced per-rank SPMD program before rank expansion: the single-rank
    graph (collectives still symbolic) plus the mesh and input specs
    ``expand_spmd`` needs to instantiate it per rank and derive R_i."""
    graph: Graph                  # per-rank program with collective ops
    mesh_axes: dict               # axis name -> size
    in_specs: list                # PartitionSpec per input
    names: list


def capture_spmd(fn: Callable, mesh_axes: dict, in_specs: Sequence,
                 avals: Sequence, names: Sequence[str]) -> SpmdCapture:
    """Trace a per-rank SPMD ``fn`` under ``shard_map`` on an abstract mesh
    and lower the unwrapped body to a single-rank :class:`Graph` (collectives
    kept as symbolic ops for ``expand_spmd`` to instantiate)."""
    axis_names = tuple(mesh_axes)
    mesh = _make_abstract_mesh(mesh_axes)
    sm = _wrap_shard_map(fn, mesh, tuple(in_specs))
    closed = jax.make_jaxpr(sm)(*avals)
    # unwrap the single shard_map eqn
    eqn = None
    for e in closed.jaxpr.eqns:
        if e.primitive.name == "shard_map":
            eqn = e
            break
    assert eqn is not None, "expected a shard_map eqn"
    inner = eqn.params["jaxpr"]   # open jaxpr, per-rank avals

    # Closed-over consts of fn appear as extra leading eqn invars: align
    # names/specs per eqn invar, and mark const positions.
    outer_pos = {v: i for i, v in enumerate(closed.jaxpr.invars)}
    const_map = dict(zip(closed.jaxpr.constvars, closed.consts))
    eqn_specs = _eqn_in_specs(eqn)
    inner_names, const_positions = [], {}
    arg_names, arg_specs = [], []
    for pos, atom in enumerate(eqn.invars):
        if isinstance(atom, jax.extend.core.Literal):
            const_positions[pos] = np.asarray(atom.val)
            inner_names.append(f"cin{pos}")
            continue
        if atom in outer_pos:
            nm = names[outer_pos[atom]]
            inner_names.append(nm)
            arg_names.append(nm)
            arg_specs.append(eqn_specs[pos])
        elif atom in const_map:
            const_positions[pos] = np.asarray(const_map[atom])
            inner_names.append(f"cin{pos}")
        else:
            raise CaptureError(
                "shard_map operand computed by outer ops — trace the "
                "distributed fn directly (no outer transformations)")
    inner_closed = jax.extend.core.ClosedJaxpr(inner, ())
    g = _jaxpr_to_graph(inner_closed, inner_names, "")
    for pos, val in const_positions.items():
        nm = inner_names[pos]
        g.consts[nm] = val
        g.inputs.remove(nm)
    return SpmdCapture(g, dict(mesh_axes), list(arg_specs), list(arg_names))


def _jaxpr_to_graph(closed, names, tag) -> Graph:
    jaxpr = closed.jaxpr
    namer = _Namer()
    g = Graph([], [], [], {}, {}, {})

    def declare(var, name=None):
        nm = name or namer.of(var)
        namer.set(var, nm)
        g.shapes[nm] = tuple(var.aval.shape)
        g.dtypes[nm] = _dt(var.aval.dtype)
        return nm

    for i, v in enumerate(jaxpr.invars):
        nm = declare(v, names[i] if i < len(names) else None)
        g.inputs.append(nm)
    for i, (cv, cval) in enumerate(zip(jaxpr.constvars, closed.consts)):
        nm = declare(cv, f"const{i}{tag}")
        g.consts[nm] = np.asarray(cval)

    env: dict = {}

    def read(atom) -> Term:
        if isinstance(atom, jax.extend.core.Literal):
            v = atom.val
            if np.ndim(v) == 0:
                return T.lit(v.item() if hasattr(v, "item") else v)
            nm = f"lconst{len(g.consts)}{tag}"
            g.consts[nm] = np.asarray(v)
            g.shapes[nm] = tuple(np.shape(v))
            g.dtypes[nm] = _dt(np.asarray(v).dtype)
            return g.tensor(nm)
        nm = namer.of(atom)
        return T.tensor(nm, tuple(atom.aval.shape), _dt(atom.aval.dtype))

    def emit(var, term: Term):
        nm = declare(var)
        assert term.shape == tuple(var.aval.shape), \
            f"{var.aval.shape} vs {term.shape} for {term.op}"
        g.defs.append((nm, term))

    _process_eqns(jaxpr.eqns, read, emit, g, namer, declare)

    for v in jaxpr.outvars:
        if isinstance(v, jax.extend.core.Literal):
            nm = f"outlit{len(g.consts)}"
            g.consts[nm] = np.asarray(v.val)
            g.shapes[nm] = tuple(np.shape(v.val))
            g.dtypes[nm] = _dt(np.asarray(v.val).dtype)
            g.outputs.append(nm)
        else:
            g.outputs.append(namer.of(v))
    return g


def _process_eqns(eqns, read, emit, g, namer, declare):
    for eqn in eqns:
        prim = eqn.primitive.name
        # -- structural inlining ------------------------------------------
        if prim in ("pjit", "jit", "closed_call", "custom_jvp_call",
                    "custom_vjp_call", "custom_vjp_call_jaxpr", "remat",
                    "checkpoint", "custom_jvp_call_jaxpr", "core_call"):
            sub = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                   or eqn.params.get("fun_jaxpr"))
            if hasattr(sub, "jaxpr"):  # ClosedJaxpr
                consts = sub.consts
                sub = sub.jaxpr
            else:
                consts = ()
            # Scoped inlining: the same sub-jaxpr may be inlined repeatedly
            # (e.g. silu's custom_jvp), so its vars must NOT share global
            # name bindings — use a local env overlay.
            env_map: dict = {}
            for cv, cval in zip(sub.constvars, consts):
                nm = f"iconst{len(g.consts)}"
                g.consts[nm] = np.asarray(cval)
                g.shapes[nm] = tuple(cv.aval.shape)
                g.dtypes[nm] = _dt(cv.aval.dtype)
                env_map[cv] = T.tensor(nm, tuple(cv.aval.shape),
                                       _dt(cv.aval.dtype))
            for iv, atom in zip(sub.invars, eqn.invars):
                env_map[iv] = read(atom)

            def rd(atom, env_map=env_map):
                if isinstance(atom, jax.extend.core.Literal):
                    return read(atom)
                if atom in env_map:
                    return env_map[atom]
                return read(atom)

            def em(var, term, env_map=env_map):
                nm = namer.fresh()
                g.shapes[nm] = term.shape
                g.dtypes[nm] = term.dtype
                g.defs.append((nm, term))
                env_map[var] = T.tensor(nm, term.shape, term.dtype)

            _process_eqns(sub.eqns, rd, em, g, namer, declare)
            for ov, iv in zip(eqn.outvars, sub.outvars):
                tm = rd(iv)
                if tm.op == "tensor":
                    namer.set(ov, tm.name)
                    g.shapes[tm.name] = tm.shape
                    g.dtypes[tm.name] = tm.dtype
                else:
                    emit(ov, tm)
            continue
        if prim == "scan":
            _inline_scan(eqn, read, emit, g, namer, declare)
            continue
        # -- regular primitive --------------------------------------------
        try:
            outs = _normalize(eqn, read)
        except CaptureError as e:
            # a partially-supported primitive (e.g. interior padding) — let
            # strict mode attach the eqn + source location before the raise
            _on_unsupported(eqn, str(e))
            raise
        if outs is None:
            # uninterpreted: keep as opaque op (user lemma extension point)
            _on_unsupported(eqn, "no normalization to the term vocabulary")
            args = tuple(read(a) for a in eqn.invars)
            for k, ov in enumerate(eqn.outvars):
                tag = f"#{k}" if len(eqn.outvars) > 1 else ""
                emit(ov, T.opaque(prim + tag, args, tuple(ov.aval.shape),
                                  _dt(ov.aval.dtype)))
        else:
            assert len(outs) == len(eqn.outvars), prim
            for ov, tm in zip(eqn.outvars, outs):
                emit(ov, tm)


def _inline_scan(eqn, read, emit, g, namer, declare):
    p = eqn.params
    length, nc, ncar = p["length"], p["num_consts"], p["num_carry"]
    if length > 8:
        _on_unsupported(eqn, f"scan of length {length} exceeds the unroll "
                             f"budget of 8")
        raise CaptureError(
            f"scan of length {length} in a verification graph — unroll "
            f"explicitly or verify a single layer (paper §6.3 verifies one "
            f"layer; so do we)")
    closed = p["jaxpr"]
    consts_in = eqn.invars[:nc]
    carry_in = eqn.invars[nc:nc + ncar]
    xs_in = eqn.invars[nc + ncar:]
    carry_terms = [read(a) for a in carry_in]
    ys_acc: list = [[] for _ in range(len(eqn.outvars) - ncar)]
    for it in range(length):
        sub = closed.jaxpr
        local = _Namer()
        env_map = {}
        for cv, cval in zip(sub.constvars, closed.consts):
            nm = f"sconst{len(g.consts)}"
            g.consts[nm] = np.asarray(cval)
            g.shapes[nm] = tuple(cv.aval.shape)
            g.dtypes[nm] = _dt(cv.aval.dtype)
            env_map[cv] = T.tensor(nm, tuple(cv.aval.shape), _dt(cv.aval.dtype))
        invars = sub.invars
        for v, a in zip(invars[:nc], consts_in):
            env_map[v] = read(a)
        for v, t in zip(invars[nc:nc + ncar], carry_terms):
            env_map[v] = t
        for v, a in zip(invars[nc + ncar:], xs_in):
            xs_t = read(a)
            sl = T.slice_(xs_t, (it,) + (0,) * (len(xs_t.shape) - 1),
                          (it + 1,) + xs_t.shape[1:])
            env_map[v] = T.reshape(sl, xs_t.shape[1:])

        def rd(atom, env_map=env_map):
            if isinstance(atom, jax.extend.core.Literal):
                return read(atom)
            if atom in env_map:
                return env_map[atom]
            return read(atom)

        def em(var, term, env_map=env_map):
            env_map[var] = term
            nm = declare(var, f"{namer.of(var)}.i{it}")
            g.shapes[nm] = term.shape
            g.dtypes[nm] = term.dtype
            g.defs.append((nm, term))
            env_map[var] = T.tensor(nm, term.shape, term.dtype)

        _process_eqns(sub.eqns, rd, em, g, namer, declare)
        outs = [rd(v) for v in sub.outvars]
        carry_terms = outs[:ncar]
        for j, y in enumerate(outs[ncar:]):
            ys_acc[j].append(T.reshape(y, (1,) + y.shape))
    for ov, t in zip(eqn.outvars[:ncar], carry_terms):
        emit(ov, t)
    for ov, pieces in zip(eqn.outvars[ncar:], ys_acc):
        emit(ov, T.concat(pieces, 0))


class CaptureError(RuntimeError):
    """A jaxpr could not be lowered to the term language (e.g. an
    over-budget scan or an unsupported primitive configuration)."""


# ---------------------------------------------------------------------------
# Primitive normalization
# ---------------------------------------------------------------------------

_EW1_MAP = {
    "neg": "neg", "exp": "exp", "log": "log", "tanh": "tanh",
    "logistic": "logistic", "rsqrt": "rsqrt", "sqrt": "sqrt", "sin": "sin",
    "cos": "cos", "abs": "abs", "erf": "erf", "floor": "floor",
    "sign": "sign", "stop_gradient": "stop_grad", "log1p": "log1p",
    "expm1": "expm1", "not": "not", "copy": None, "reduce_precision": None,
}
_EW2_MAP = {
    "add": "add", "add_any": "add", "sub": "sub", "mul": "mul", "div": "div", "max": "max2",
    "min": "min2", "pow": "pow", "eq": "eq", "ne": "ne", "lt": "lt",
    "le": "le", "gt": "gt", "ge": "ge", "and": "and", "or": "or",
    "rem": "rem", "atan2": "atan2", "nextafter": "nextafter",
    "shift_left": "shift_left", "shift_right_logical": "shift_right",
    "shift_right_arithmetic": "shift_right",
}

COLLECTIVES = {"psum", "psum_invariant", "all_gather", "reduce_scatter",
               "all_to_all", "ppermute", "pvary", "axis_index", "pbroadcast"}


def _lift(t: Term, shape) -> Term:
    """Broadcast scalars/size-1 dims so ew2 operands are shape-uniform."""
    shape = tuple(shape)
    if t.shape == shape or shape == ():
        return t
    if t.shape == ():
        return T.broadcast(t, shape, ())
    if len(t.shape) == len(shape) and all(
            td == sd or td == 1 for td, sd in zip(t.shape, shape)):
        return T.broadcast(t, shape, tuple(range(len(shape))))
    raise AssertionError(f"cannot lift {t.shape} to {shape}")


def _normalize(eqn, read) -> Optional[list]:
    """Return output Terms for an eqn, or None -> opaque."""
    prim = eqn.primitive.name
    p = eqn.params
    out_aval = eqn.outvars[0].aval if eqn.outvars else None

    if prim == "device_put":  # layout/transfer no-op in a verification graph
        return [read(a) for a in eqn.invars]
    if prim in _EW1_MAP:
        x = read(eqn.invars[0])
        mapped = _EW1_MAP[prim]
        return [x] if mapped is None else [T.ew1(mapped, x)]
    if prim == "integer_pow":
        return [T.integer_pow(read(eqn.invars[0]), p["y"])]
    if prim == "square":
        return [T.integer_pow(read(eqn.invars[0]), 2)]
    if prim in _EW2_MAP:
        a, b = read(eqn.invars[0]), read(eqn.invars[1])
        sh = tuple(out_aval.shape)
        return [T.ew2(_EW2_MAP[prim], _lift(a, sh), _lift(b, sh))]
    if prim == "select_n":
        which = read(eqn.invars[0])
        cases = [read(a) for a in eqn.invars[1:]]
        if len(cases) != 2:
            return None
        sh = tuple(out_aval.shape)
        # select_n(pred, a, b) = b where pred else a  (pred indexes cases!)
        return [T.select(_lift(which, sh), _lift(cases[1], sh),
                         _lift(cases[0], sh))]
    if prim == "clamp":
        lo, x, hi = (read(a) for a in eqn.invars)
        sh = tuple(out_aval.shape)
        return [T.ew2("max2", T.ew2("min2", _lift(x, sh), _lift(hi, sh)),
                      _lift(lo, sh))]
    if prim == "convert_element_type":
        return [T.convert(read(eqn.invars[0]), _dt(p["new_dtype"]))]
    if prim == "broadcast_in_dim":
        x = read(eqn.invars[0])
        return [T.broadcast(x, tuple(p["shape"]),
                            tuple(p["broadcast_dimensions"]))]
    if prim == "reshape":
        return [T.reshape(read(eqn.invars[0]), tuple(p["new_sizes"]))]
    if prim == "squeeze":
        return [T.reshape(read(eqn.invars[0]), tuple(out_aval.shape))]
    if prim == "expand_dims":
        return [T.reshape(read(eqn.invars[0]), tuple(out_aval.shape))]
    if prim == "transpose":
        return [T.transpose(read(eqn.invars[0]), tuple(p["permutation"]))]
    if prim == "rev":
        return [T.rev(read(eqn.invars[0]), tuple(p["dimensions"]))]
    if prim == "concatenate":
        return [T.concat([read(a) for a in eqn.invars], p["dimension"])]
    if prim == "slice":
        if p.get("strides") and any(s != 1 for s in p["strides"]):
            return None
        return [T.slice_(read(eqn.invars[0]), tuple(p["start_indices"]),
                         tuple(p["limit_indices"]))]
    if prim == "split":
        x = read(eqn.invars[0])
        axis = p["axis"]
        outs = []
        off = 0
        for sz in p["sizes"]:
            starts = tuple(off if i == axis else 0
                           for i in range(len(x.shape)))
            limits = tuple(off + sz if i == axis else x.shape[i]
                           for i in range(len(x.shape)))
            outs.append(T.slice_(x, starts, limits))
            off += sz
        return outs
    if prim == "iota":
        return [T.iota(tuple(p["shape"]), p["dimension"], _dt(p["dtype"]))]
    if prim in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                "reduce_and", "reduce_or"):
        return [T.reduce_(f"reduce_{prim.split('_')[1]}", read(eqn.invars[0]),
                          tuple(int(a) for a in p["axes"]))]
    if prim in ("argmax", "argmin"):
        axes = p["axes"]
        if len(axes) != 1:
            return None
        return [T.argmax(read(eqn.invars[0]), axes[0])] if prim == "argmax" \
            else None
    if prim == "cumsum":
        return [T.cumsum(read(eqn.invars[0]), p["axis"])]
    if prim == "dot_general":
        return [_norm_dot(eqn, read)]
    if prim == "dynamic_slice":
        x = read(eqn.invars[0])
        starts = tuple(read(a) for a in eqn.invars[1:])
        if all(s.op == "lit" for s in starts):
            st = tuple(int(s.value) for s in starts)
            st = tuple(min(max(s, 0), d - z)
                       for s, d, z in zip(st, x.shape, p["slice_sizes"]))
            return [T.slice_(x, st, tuple(s + z for s, z in
                                          zip(st, p["slice_sizes"])))]
        return [Term("dyn_slice", (x,) + starts,
                     (("sizes", tuple(p["slice_sizes"])),),
                     tuple(p["slice_sizes"]), x.dtype)]
    if prim == "dynamic_update_slice":
        x, u = read(eqn.invars[0]), read(eqn.invars[1])
        starts = tuple(read(a) for a in eqn.invars[2:])
        if all(s.op == "lit" for s in starts):
            st = tuple(min(max(int(s.value), 0), d - z)
                       for s, d, z in zip(starts, x.shape, u.shape))
            return [T.dus(x, u, st)]
        return [Term("dyn_update_slice", (x, u) + starts, (), x.shape, x.dtype)]
    if prim == "pad":
        return [_norm_pad(eqn, read)]
    if prim == "gather":
        return _norm_gather(eqn, read)
    if prim in COLLECTIVES:
        return _norm_collective(eqn, read)
    if prim == "scatter-add" or prim == "scatter_add":
        x, idx, upd = (read(a) for a in eqn.invars)
        dn = p["dimension_numbers"]
        return [Term("scatter_add", (x, idx, upd),
                     (("dnums", repr(dn)),), x.shape, x.dtype)]
    return None


def _norm_dot(eqn, read) -> Term:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    a, b = read(eqn.invars[0]), read(eqn.invars[1])
    la, lb_n = len(a.shape), len(b.shape)
    lfree = [i for i in range(la) if i not in lc and i not in lb]
    rfree = [i for i in range(lb_n) if i not in rc and i not in rb]

    if not lb:  # no batch dims: general matmul (..., k) x (k, n)
        # lhs -> (lfree..., K)
        perm_a = tuple(lfree) + tuple(lc)
        ta = T.transpose(a, perm_a)
        if len(lc) > 1:
            k = int(np.prod([a.shape[i] for i in lc], dtype=np.int64))
            ta = T.reshape(ta, tuple(a.shape[i] for i in lfree) + (k,))
        # rhs -> (K, rfree...)
        perm_b = tuple(rc) + tuple(rfree)
        tb = T.transpose(b, perm_b)
        k = ta.shape[-1]
        nfree = tuple(b.shape[i] for i in rfree)
        n = int(np.prod(nfree, dtype=np.int64)) if nfree else 1
        tb = T.reshape(tb, (k, n))
        out = Term("matmul", (ta, tb), (), ta.shape[:-1] + (n,), a.dtype)
        final = tuple(a.shape[i] for i in lfree) + nfree
        return T.reshape(out, final)

    # batch case -> bmm (B..., M, K) x (B..., K, N)
    perm_a = tuple(lb) + tuple(lfree) + tuple(lc)
    ta = T.transpose(a, perm_a)
    bshape = tuple(a.shape[i] for i in lb)
    m = int(np.prod([a.shape[i] for i in lfree], dtype=np.int64)) if lfree else 1
    k = int(np.prod([a.shape[i] for i in lc], dtype=np.int64))
    ta = T.reshape(ta, bshape + (m, k))
    perm_b = tuple(rb) + tuple(rc) + tuple(rfree)
    tb = T.transpose(b, perm_b)
    nfree = tuple(b.shape[i] for i in rfree)
    n = int(np.prod(nfree, dtype=np.int64)) if nfree else 1
    tb = T.reshape(tb, bshape + (k, n))
    out = T.bmm(ta, tb)
    final = bshape + tuple(a.shape[i] for i in lfree) + nfree
    return T.reshape(out, final)


def _norm_pad(eqn, read) -> Term:
    x = read(eqn.invars[0])
    pv = read(eqn.invars[1])  # scalar
    cfg = eqn.params["padding_config"]
    if any(c[2] != 0 for c in cfg):
        raise CaptureError("interior padding unsupported")
    if any(c[0] < 0 or c[1] < 0 for c in cfg):
        raise CaptureError("negative padding unsupported")
    out = x
    for d, (lo, hi, _) in enumerate(cfg):
        pieces = []
        if lo:
            sh = tuple(lo if i == d else out.shape[i]
                       for i in range(len(out.shape)))
            pieces.append(T.broadcast(pv, sh, ()))
        pieces.append(out)
        if hi:
            sh = tuple(hi if i == d else out.shape[i]
                       for i in range(len(out.shape)))
            pieces.append(T.broadcast(pv, sh, ()))
        if len(pieces) > 1:
            out = T.concat(pieces, d)
    return out


def _norm_gather(eqn, read) -> Optional[list]:
    """Match the embedding/take pattern: table (V, D) gathered on rows."""
    p = eqn.params
    dn = p["dimension_numbers"]
    tab = read(eqn.invars[0])
    idx = read(eqn.invars[1])
    ss = tuple(p["slice_sizes"])
    if (len(tab.shape) == 2 and dn.start_index_map == (0,)
            and dn.collapsed_slice_dims == (0,)
            and ss == (1, tab.shape[1])
            and idx.shape and idx.shape[-1] == 1):
        idx2 = T.reshape(idx, idx.shape[:-1])
        return [T.gather_rows(tab, idx2)]
    if (len(tab.shape) == 1 and dn.start_index_map == (0,)
            and dn.collapsed_slice_dims == (0,) and ss == (1,)
            and idx.shape and idx.shape[-1] == 1):
        t2 = T.reshape(tab, tab.shape + (1,))
        idx2 = T.reshape(idx, idx.shape[:-1])
        g = T.gather_rows(t2, idx2)
        return [T.reshape(g, g.shape[:-1])]
    return None


def _norm_collective(eqn, read) -> list:
    prim = eqn.primitive.name
    p = eqn.params
    if prim == "pvary" or prim == "pbroadcast":
        return [read(a) for a in eqn.invars]
    if prim == "axis_index":
        return [Term("axis_index", (), (("axis", p["axis_name"]),), (), "i")]
    if prim in ("psum", "psum_invariant"):
        axes = tuple(a for a in p["axes"] if isinstance(a, str))
        outs = []
        for a in eqn.invars:
            x = read(a)
            outs.append(Term("psum", (x,), (("axes", axes),), x.shape, x.dtype))
        return outs
    x = read(eqn.invars[0])
    if prim == "all_gather":
        axes = p["axis_name"]
        axes = tuple(axes) if isinstance(axes, tuple) else (axes,)
        d = p["all_gather_dimension"]
        sz = p["axis_size"]
        shape = tuple(x.shape[i] * sz if i == d else x.shape[i]
                      for i in range(len(x.shape)))
        if not p["tiled"]:
            shape = x.shape[:d] + (sz,) + x.shape[d:]
        return [Term("all_gather", (x,),
                     (("axes", axes), ("dim", d), ("tiled", p["tiled"])),
                     shape, x.dtype)]
    if prim == "reduce_scatter":
        axes = p["axis_name"]
        axes = tuple(axes) if isinstance(axes, tuple) else (axes,)
        d = p["scatter_dimension"]
        sz = p["axis_size"]
        assert p["tiled"], "only tiled reduce_scatter supported"
        shape = tuple(x.shape[i] // sz if i == d else x.shape[i]
                      for i in range(len(x.shape)))
        return [Term("reduce_scatter", (x,), (("axes", axes), ("dim", d)),
                     shape, x.dtype)]
    if prim == "all_to_all":
        ax = p["axis_name"]
        axes = tuple(ax) if isinstance(ax, tuple) else (ax,)
        sa, ca = p["split_axis"], p["concat_axis"]
        assert p.get("tiled", True), "only tiled all_to_all supported"
        ov = eqn.outvars[0].aval  # shape from outvar (depends on group size)
        return [Term("all_to_all", (x,),
                     (("axes", axes), ("split", sa), ("concat", ca)),
                     tuple(ov.shape), x.dtype)]
    if prim == "ppermute":
        ax = p["axis_name"]
        if isinstance(ax, tuple):
            assert len(ax) == 1, "multi-axis ppermute unsupported"
            ax = ax[0]
        return [Term("ppermute", (x,),
                     (("axis", ax), ("perm", tuple(map(tuple, p["perm"])))),
                     x.shape, x.dtype)]
    raise AssertionError(prim)


# ---------------------------------------------------------------------------
# SPMD expansion: per-rank instantiation + collective translation
# ---------------------------------------------------------------------------

def rank_tag(axis_names, coords) -> str:
    """Name suffix identifying one rank, e.g. ``@dp0,tp1``."""
    return "@" + ",".join(f"{a}{c}" for a, c in zip(axis_names, coords))


def expand_spmd(cap: SpmdCapture) -> tuple[Graph, dict]:
    """Expand the per-rank SPMD graph into a multi-rank Graph.

    Returns (expanded graph, input relation R_i) where R_i maps each logical
    (sequential) input name to a list of clean Terms over expanded input
    tensors — derived from the in_specs (§2.1: the distribution strategy's
    input relation; deriving it from the sharding spec is our extension).
    """
    g = cap.graph
    axis_names = tuple(cap.mesh_axes)
    sizes = tuple(cap.mesh_axes[a] for a in axis_names)
    all_coords = list(itertools.product(*[range(s) for s in sizes]))

    out = Graph([], [], [], {}, {}, {})

    def reg(name, shape, dtype):
        out.shapes[name] = shape
        out.dtypes[name] = dtype

    # per-rank inputs
    for name in g.inputs:
        for c in all_coords:
            nm = name + rank_tag(axis_names, c)
            reg(nm, g.shapes[name], g.dtypes[name])
            out.inputs.append(nm)
    # consts are rank-invariant: register once per rank (same value)
    for cname, val in g.consts.items():
        for c in all_coords:
            nm = cname + rank_tag(axis_names, c)
            out.consts[nm] = val
            reg(nm, tuple(val.shape), _dt(val.dtype))

    def group(coords, axes):
        """Rank-group of ``coords`` varying ``axes`` (ordered by coordinate)."""
        idxs = [axis_names.index(a) for a in axes]
        ranges = [range(sizes[i]) for i in idxs]
        members = []
        for combo in itertools.product(*ranges):
            c = list(coords)
            for i, v in zip(idxs, combo):
                c[i] = v
            members.append(tuple(c))
        return members

    # per-rank scalar-constant propagation: axis_index arithmetic becomes
    # literal per rank, letting dynamic slices fold to static slices.
    scalar_env: dict = {}
    for name, term in g.defs:
        for c in all_coords:
            tag = rank_tag(axis_names, c)
            inst = _instantiate(term, tag, c, axis_names, sizes, group, out,
                                scalar_env)
            nm = name + tag
            if inst.shape == ():
                v = _fold_scalar(inst)
                if v is not None:
                    scalar_env[nm] = v
                    inst = T.lit(v)
            reg(nm, inst.shape, inst.dtype)
            out.defs.append((nm, inst))

    for name in g.outputs:
        for c in all_coords:
            out.outputs.append(name + rank_tag(axis_names, c))

    r_i = derive_input_relation(g, cap.in_specs, axis_names, sizes, all_coords)
    return out, r_i


def _instantiate(term: Term, tag: str, coords, axis_names, sizes, group,
                 out_graph, scalar_env=None) -> Term:
    """Instantiate a per-rank term for a specific rank coordinate."""
    scalar_env = scalar_env or {}

    def go(t: Term) -> Term:
        if t.op == "tensor":
            nm = t.name + tag
            if nm in scalar_env:
                return T.lit(scalar_env[nm])
            return T.tensor(nm, t.shape, t.dtype)
        if t.op == "lit":
            return t
        if t.op == "axis_index":
            return T.lit(coords[axis_names.index(t.attr("axis"))])
        if t.op == "psum":
            members = group(coords, t.attr("axes"))
            return T.add_n(_retag(t.args[0], rank_tag(axis_names, m), m,
                                  axis_names, sizes, group)
                           for m in members)
        if t.op == "all_gather":
            gmembers = group(coords, t.attr("axes"))
            d, tiled = t.attr("dim"), t.attr("tiled")
            pieces = [_retag(t.args[0], rank_tag(axis_names, m), m,
                             axis_names, sizes, group) for m in gmembers]
            if tiled:
                return T.concat(pieces, d)
            pieces = [T.reshape(p, p.shape[:d] + (1,) + p.shape[d:])
                      for p in pieces]
            return T.concat(pieces, d) if len(pieces) > 1 else pieces[0]
        if t.op == "reduce_scatter":
            gmembers = group(coords, t.attr("axes"))
            d = t.attr("dim")
            pieces = [_retag(t.args[0], rank_tag(axis_names, m), m,
                             axis_names, sizes, group) for m in gmembers]
            s = T.add_n(pieces)
            k = gmembers.index(coords)
            blk = s.shape[d] // len(gmembers)
            starts = tuple(k * blk if i == d else 0 for i in range(len(s.shape)))
            limits = tuple((k + 1) * blk if i == d else s.shape[i]
                           for i in range(len(s.shape)))
            return T.slice_(s, starts, limits)
        if t.op == "all_to_all":
            gmembers = group(coords, t.attr("axes"))
            sa, ca = t.attr("split"), t.attr("concat")
            n = len(gmembers)
            k = gmembers.index(coords)
            pieces = []
            for m in gmembers:
                x = _retag(t.args[0], rank_tag(axis_names, m), m,
                           axis_names, sizes, group)
                blk = x.shape[sa] // n
                starts = tuple(k * blk if i == sa else 0
                               for i in range(len(x.shape)))
                limits = tuple((k + 1) * blk if i == sa else x.shape[i]
                               for i in range(len(x.shape)))
                pieces.append(T.slice_(x, starts, limits))
            return T.concat(pieces, ca)
        if t.op == "ppermute":
            perm = dict(t.attr("perm"))
            axis = t.attr("axis")
            ai = axis_names.index(axis)
            me = coords[ai]
            src = next((s for s, dst in perm.items() if dst == me), None)
            if src is None:
                return T.broadcast(T.lit(0.0 if t.dtype == "f" else 0),
                                   t.shape, ())
            sc = tuple(src if i == ai else coords[i]
                       for i in range(len(coords)))
            return _retag(t.args[0], rank_tag(axis_names, sc), sc,
                          axis_names, sizes, group)
        args = tuple(go(a) for a in t.args)
        if t.op in ("dyn_slice", "dyn_update_slice"):
            return _fold_dynamic(t, args)
        if t.op == "select":
            # rank-conditional writes (``jnp.where(axis_index(a) == k, ...)``)
            # fold per rank once axis_index is a literal: chase the predicate
            # through its broadcast and take the branch it selects
            pred = args[0]
            while pred.op == "broadcast":
                pred = pred.args[0]
            v = _fold_scalar(pred)
            if v is not None:
                return args[1] if v else args[2]
        return Term(t.op, args, t.attrs, t.shape, t.dtype)

    return go(term)


def _retag(term: Term, tag: str, coords, axis_names, sizes, group) -> Term:
    return _instantiate(term, tag, coords, axis_names, sizes, group, None)


def _fold_dynamic(t: Term, args) -> Term:
    """Fold dynamic slices whose start indices are now literal."""
    if t.op == "dyn_slice":
        x, starts = args[0], args[1:]
        vals = _fold_scalars(starts)
        if vals is None:
            return Term(t.op, args, t.attrs, t.shape, t.dtype)
        sizes = t.attr("sizes")
        st = tuple(min(max(v, 0), d - z)
                   for v, d, z in zip(vals, x.shape, sizes))
        return T.slice_(x, st, tuple(s + z for s, z in zip(st, sizes)))
    x, u, starts = args[0], args[1], args[2:]
    vals = _fold_scalars(starts)
    if vals is None:
        return Term(t.op, args, t.attrs, t.shape, t.dtype)
    st = tuple(min(max(v, 0), d - z)
               for v, d, z in zip(vals, x.shape, u.shape))
    return T.dus(x, u, st)


def _fold_scalars(ts) -> Optional[tuple]:
    out = []
    for t in ts:
        v = _fold_scalar(t)
        if v is None:
            return None
        out.append(int(v))
    return tuple(out)


def _fold_scalar(t: Term):
    """Constant-fold a scalar term (post axis_index substitution)."""
    if t.op == "lit":
        return t.value
    if t.shape != ():
        return None
    try:
        if any(l.op == "tensor" for l in t.leaves()):
            return None
        return T.eval_term(t, {}).item()
    except Exception:
        return None


# ---------------------------------------------------------------------------
# Input relation derivation (from PartitionSpecs)
# ---------------------------------------------------------------------------

def derive_input_relation(g: Graph, in_specs, axis_names, sizes, all_coords):
    """R_i: logical input name -> [clean Terms over per-rank input names].

    A dim sharded over mesh axes (a, b, ...) splits major-to-minor; the
    global tensor is the nested concat of per-rank pieces. Unsharded mesh
    axes replicate: each replica yields its own mapping (paper: a relation
    may contain several mappings for one tensor)."""
    r_i: dict = {}
    for name, spec in zip(g.inputs, in_specs):
        local = tuple(g.shapes[name])  # inner-jaxpr shapes are per-shard
        dt = g.dtypes[name]
        spec = tuple(spec) if spec is not None else ()
        spec = spec + (None,) * (len(local) - len(spec))
        used = []
        for entry in spec:
            if entry is None:
                continue
            entries = entry if isinstance(entry, tuple) else (entry,)
            used.extend(entries)
        unused = [a for a in axis_names if a not in used]

        def build(rep_coords: dict) -> Term:
            """Nested concat over sharded axes for one replica assignment."""
            def rec(d: int, fixed: dict) -> Term:
                if d == len(spec):
                    coords = tuple(fixed.get(a, rep_coords.get(a, 0))
                                   for a in axis_names)
                    return T.tensor(name + rank_tag(axis_names, coords),
                                    local, dt)
                entry = spec[d]
                if entry is None:
                    return rec(d + 1, fixed)
                entries = entry if isinstance(entry, tuple) else (entry,)
                def split(ei: int, fixed2: dict) -> Term:
                    if ei == len(entries):
                        return rec(d + 1, fixed2)
                    a = entries[ei]
                    n = sizes[axis_names.index(a)]
                    return T.concat([split(ei + 1, {**fixed2, a: k})
                                     for k in range(n)], d)
                return split(0, fixed)
            return rec(0, {})

        maps = []
        if unused:
            for combo in itertools.product(*[range(sizes[axis_names.index(a)])
                                             for a in unused]):
                maps.append(build(dict(zip(unused, combo))))
        else:
            maps.append(build({}))
        r_i[name] = maps
    return r_i

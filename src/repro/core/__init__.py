"""GraphGuard core: static verification of distributed model refinement.

Public API:
    capture, capture_spmd, expand_spmd   — graph capture (jaxpr -> Graph)
    capture_function, capture_spmd_function, UnsupportedPrimitive
                                         — generic strict capture frontend
    check_refinement, GraphGuard         — iterative relation inference
    Certificate, RefinementError         — results
    register_lemma                       — user lemma extension point
"""
from .capture import (Graph, CaptureError, capture, capture_chain,
                      capture_spmd, expand_spmd, derive_input_relation)
from .from_jaxpr import (SUPPORTED_PRIMITIVES, UnsupportedPrimitive,
                         capture_function, capture_spmd_function,
                         normalize_mesh, strict_capture)
from .egraph import EGraph, Lemma, EGraphLimit, EGraphShapeError
from .infer import Certificate, GraphGuard, RefinementError, check_refinement
from .lemmas import all_lemmas, register_lemma
from .profile import CONFIG, OptConfig, Profile, set_optimizations
from .symbolic import AffExpr, ScalarSolver, NonAffine
from . import terms

__all__ = [
    "Graph", "CaptureError", "capture", "capture_chain", "capture_spmd",
    "expand_spmd", "SUPPORTED_PRIMITIVES", "UnsupportedPrimitive",
    "capture_function", "capture_spmd_function", "normalize_mesh",
    "strict_capture",
    "derive_input_relation", "EGraph", "Lemma", "EGraphLimit",
    "EGraphShapeError", "Certificate", "GraphGuard", "RefinementError",
    "check_refinement", "all_lemmas", "register_lemma", "AffExpr",
    "ScalarSolver", "NonAffine", "terms", "CONFIG", "OptConfig", "Profile",
    "set_optimizations",
]

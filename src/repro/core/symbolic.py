"""Symbolic scalar reasoning (paper §5.2 analogue).

The paper encodes symbolic shape/offset scalars in SMT-LIB and discharges
equality/inequality queries with an SMT solver. JAX shapes are static, so in
this framework symbolic scalars arise only from rank indices (``axis_index``)
and user-parameterized slice bounds. We implement the decidable fragment we
need — affine integer arithmetic — directly:

    AffExpr = c0 + sum_i c_i * var_i

Equality of affine expressions is decidable by canonicalization. Inequality
is decided when the difference is constant, or when user-supplied bounds
(var ranges) make the sign of the difference definite; otherwise we answer
``None`` ("unknown"), and the querying lemma simply does not fire — trading
completeness for soundness exactly like the paper's SMT timeout path.
"""
from __future__ import annotations

from typing import Optional, Union


class AffExpr:
    """Affine integer expression: const + sum(coef * var)."""

    __slots__ = ("const", "coefs")

    def __init__(self, const: int = 0, coefs: Optional[dict] = None):
        self.const = const
        self.coefs = {k: v for k, v in (coefs or {}).items() if v != 0}

    # -- constructors -------------------------------------------------------
    @staticmethod
    def var(name: str) -> "AffExpr":
        return AffExpr(0, {name: 1})

    @staticmethod
    def of(v: Union[int, "AffExpr"]) -> "AffExpr":
        return v if isinstance(v, AffExpr) else AffExpr(int(v))

    # -- arithmetic ----------------------------------------------------------
    def __add__(self, o):
        o = AffExpr.of(o)
        coefs = dict(self.coefs)
        for k, v in o.coefs.items():
            coefs[k] = coefs.get(k, 0) + v
        return AffExpr(self.const + o.const, coefs)

    __radd__ = __add__

    def __neg__(self):
        return AffExpr(-self.const, {k: -v for k, v in self.coefs.items()})

    def __sub__(self, o):
        return self + (-AffExpr.of(o))

    def __rsub__(self, o):
        return AffExpr.of(o) - self

    def __mul__(self, o):
        if isinstance(o, AffExpr):
            if not o.coefs:
                o = o.const
            elif not self.coefs:
                return o * self.const
            else:
                raise NonAffine("product of two symbolic expressions")
        return AffExpr(self.const * o, {k: v * o for k, v in self.coefs.items()})

    __rmul__ = __mul__

    # -- status --------------------------------------------------------------
    @property
    def is_const(self) -> bool:
        return not self.coefs

    def as_int(self) -> int:
        if not self.is_const:
            raise NonAffine(f"not constant: {self}")
        return self.const

    def key(self):
        return (self.const, tuple(sorted(self.coefs.items())))

    def __eq__(self, o):
        if isinstance(o, (int, AffExpr)):
            return self.key() == AffExpr.of(o).key()
        return NotImplemented

    def __hash__(self):
        return hash(self.key())

    def __repr__(self):
        parts = [str(self.const)] if self.const or not self.coefs else []
        parts += [f"{v}*{k}" if v != 1 else k
                  for k, v in sorted(self.coefs.items())]
        return " + ".join(parts)


class NonAffine(Exception):
    """An index expression fell outside the affine fragment the scalar
    solver can decide."""


class ScalarSolver:
    """Decides comparisons between affine expressions under var bounds."""

    def __init__(self):
        self.bounds: dict[str, tuple[Optional[int], Optional[int]]] = {}

    def assume_range(self, var: str, lo: Optional[int], hi: Optional[int]):
        self.bounds[var] = (lo, hi)

    def _range(self, e: AffExpr) -> tuple[Optional[int], Optional[int]]:
        lo = hi = e.const
        for k, c in e.coefs.items():
            blo, bhi = self.bounds.get(k, (None, None))
            if c >= 0:
                l, h = blo, bhi
            else:
                l, h = bhi, blo
            lo = None if (lo is None or l is None) else lo + c * l
            hi = None if (hi is None or h is None) else hi + c * h
        return lo, hi

    def eq(self, a, b) -> Optional[bool]:
        a, b = AffExpr.of(a), AffExpr.of(b)
        d = a - b
        if d.is_const:
            return d.const == 0
        lo, hi = self._range(d)
        if lo is not None and lo > 0:
            return False
        if hi is not None and hi < 0:
            return False
        return None  # unknown

    def le(self, a, b) -> Optional[bool]:
        d = AffExpr.of(b) - AffExpr.of(a)
        if d.is_const:
            return d.const >= 0
        lo, hi = self._range(d)
        if lo is not None and lo >= 0:
            return True
        if hi is not None and hi < 0:
            return False
        return None

    def lt(self, a, b) -> Optional[bool]:
        le = self.le(AffExpr.of(a) + 1, b)
        return le

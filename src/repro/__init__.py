"""GraphGuard-JAX: verified distributed model refinement + the multi-pod
JAX training/serving framework it checks. See README.md."""
__version__ = "1.0.0"

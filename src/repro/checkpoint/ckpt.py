"""Msgpack tensor checkpointing (sharded-tree aware, atomic writes)."""
from __future__ import annotations

import os
import tempfile

import msgpack
import numpy as np

import jax


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _pack_array(a):
    a = np.asarray(a)
    return {b"dtype": a.dtype.str, b"shape": list(a.shape),
            b"data": a.tobytes()}


def _unpack_array(d):
    return np.frombuffer(d[b"data"], dtype=np.dtype(d[b"dtype"])) \
        .reshape(d[b"shape"])


def save_checkpoint(path: str, step: int, tree) -> str:
    os.makedirs(path, exist_ok=True)
    flat = {k: _pack_array(jax.device_get(v))
            for k, v in _flatten(tree).items()}
    payload = msgpack.packb({"step": step, "tensors": flat})
    fname = os.path.join(path, f"ckpt_{step:08d}.msgpack")
    fd, tmp = tempfile.mkstemp(dir=path)
    with os.fdopen(fd, "wb") as f:
        f.write(payload)
    os.replace(tmp, fname)
    return fname


def latest_step(path: str):
    if not os.path.isdir(path):
        return None
    steps = [int(f[5:13]) for f in os.listdir(path)
             if f.startswith("ckpt_") and f.endswith(".msgpack")]
    return max(steps) if steps else None


def restore_checkpoint(path: str, step: int, like_tree):
    fname = os.path.join(path, f"ckpt_{step:08d}.msgpack")
    with open(fname, "rb") as f:
        payload = msgpack.unpackb(f.read(), strict_map_key=False)
    tensors = {k: _unpack_array(v) for k, v in payload["tensors"].items()}

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(tree[k], f"{prefix}{k}/") for k in tree}
        if isinstance(tree, (tuple, list)):
            vals = [rebuild(v, f"{prefix}#{i}/") for i, v in enumerate(tree)]
            return type(tree)(vals)
        key = prefix[:-1]
        arr = tensors[key]
        return arr.astype(tree.dtype) if hasattr(tree, "dtype") else arr

    return payload["step"], rebuild(like_tree)

"""Production meshes and divisibility-aware sharding rules."""
from __future__ import annotations

import jax

from ..models.config import ModelConfig
from ..sharding.specs import ShardingRules, default_rules


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) (data, model) = 256 chips (TPU v5e pod).
    Multi-pod: (2, 16, 16) (pod, data, model) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def rules_for_config(cfg: ModelConfig, mesh,
                     base: ShardingRules | None = None) -> ShardingRules:
    """Adapt the default rules to the architecture: any logical dim not
    divisible by its mesh axis falls back to replication (e.g. 10 heads on a
    16-way model axis). This keeps every assigned arch lowerable on the
    production mesh without per-arch hand tuning."""
    rules = base or default_rules(multi_pod="pod" in mesh.axis_names)
    model_n = mesh_axis_size(mesh, "model")
    data_n = mesh_axis_size(mesh, "data")

    def ok(dim_size, n):
        return dim_size % n == 0 and dim_size >= n

    upd = {}
    if not ok(cfg.n_heads, model_n):
        # replicate attention heads when they don't divide the TP axis —
        # a fused (H*hd) fallback misaligns head boundaries and forces
        # involuntary resharding inside the attention einsums.
        upd["heads"] = None
        upd["act_heads"] = None
    if not ok(cfg.n_kv_heads, model_n):
        upd["kv_heads"] = None
    if cfg.d_ff and not ok(cfg.d_ff, model_n):
        upd["ff"] = None
        upd["act_ff"] = None
    if cfg.vocab % model_n:
        upd["vocab"] = None
    if cfg.n_experts and not ok(cfg.n_experts, model_n):
        upd["experts"] = None
    if cfg.n_experts and ok(cfg.moe_d_ff, data_n):
        upd["expert_fsdp"] = "data"
    # Parameter sharding plan: ZeRO-1 by default (params model-sharded,
    # replicated over data; optimizer state sharded over data — see
    # build_train). Full FSDP (params' embed dim over data) only when the
    # model-sharded params alone exceed half of HBM, because XLA's SPMD
    # backward for FSDP-sharded weights all-gathers batch activations
    # (measured in EXPERIMENTS.md SPerf).
    from ..models import registry as _registry
    param_gib = _registry.n_params(cfg) * 2 / 2**30
    if param_gib / max(model_n, 1) < 8.0:
        upd["embed_fsdp"] = None
        upd["expert_fsdp"] = None
    if cfg.d_model % data_n:
        upd["embed_fsdp"] = None
    # ssm/hybrid channel dims
    if cfg.family == "ssm":
        ch = cfg.d_inner + 2 * cfg.ssm_state
        if ch % model_n or (cfg.d_inner + 2 * cfg.ssm_state + cfg.ssm_heads) % model_n:
            upd["heads"] = None
        if not ok(cfg.ssm_heads, model_n):
            upd.setdefault("heads", None)
    if cfg.family == "hybrid" and cfg.lru_width % model_n:
        upd["ff"] = None
        upd["act_ff"] = None
    return rules.with_(**upd)

"""Training launcher: build mesh + shardings and run the training loop.

    PYTHONPATH=src python -m repro.launch.train --arch gpt --steps 100
(CPU demo runs the reduced config; on a real TPU pod pass --full.)
"""
import argparse

import jax

from ..data.pipeline import SyntheticTextDataset
from ..models import registry
from ..optim import adamw
from ..train.loop import TrainConfig, make_train_step
from ..checkpoint import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (needs a real pod)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    cfg = registry.load_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    step_fn = jax.jit(make_train_step(cfg, TrainConfig()),
                      donate_argnums=(0, 1))
    ds = SyntheticTextDataset(vocab=cfg.vocab, seq_len=args.seq,
                              batch=args.batch)
    for step in range(args.steps):
        params, opt, m = step_fn(params, opt, ds.batch_at(step))
        if step % 10 == 0:
            print(f"step {step} loss {float(m['loss']):.4f}")
    if args.ckpt:
        save_checkpoint(args.ckpt, args.steps, {"params": params})


if __name__ == "__main__":
    main()

"""GraphGuard pre-launch verification CLI (thin shim over ``repro.api``).

    python -m repro.launch.verify --case tp_layer [--bug rope_offset] \
        [--degree 2] [--json] [--list]

Captures the sequential layer and its shard_map distributed implementation,
derives R_i from the PartitionSpecs, runs iterative relation inference, and
prints the certificate R_o (or the localized bug report).

The case matrix lives in the ``repro.api`` registry (populated by
``repro.dist.strategies`` and any third-party ``@register_strategy``
call sites) — this module keeps the historical ``run_case``/``CASES``
surface and CLI output stable on top of it.  ``--list`` prints the
registered cases and bugs; ``--json`` emits the structured
``repro.api.Report`` instead of the human-readable text.  For matrix runs
use the suite runner: ``python -m repro.api``.
"""
from __future__ import annotations

import argparse
import json
import sys

from ..api import (build_spec, degree_token, get_strategy, list_bugs,
                   list_strategies, parse_degree, run_spec, verify)
from ..core import RefinementError
from ..dist.strategies import STRATEGY_CASES as CASES  # legacy view re-export


def run_case(case: str, bug=None, degree: int = 2, max_nodes=400_000,
             quiet=False):
    spec = build_spec(case, degree=degree, bug=bug)
    cert = run_spec(spec, engine_opts={"max_nodes": max_nodes})
    if not quiet:
        print(f"[verify] {case} degree={degree} bug={bug}: "
              f"G_s ops={cert.stats['gs_ops']} G_d ops={cert.stats['gd_ops']}")
        print("R_o certificate:")
        for k, v in cert.r_o.items():
            print(f"  {k} = {v}")
        print(f"  ({cert.stats['time_s']*1e3:.1f} ms, "
              f"{cert.stats['egraph_nodes']} e-nodes)")
    return cert


def _print_registry():
    print("registered cases (repro.api registry):")
    for name in list_strategies():
        entry = get_strategy(name)
        bugs = ", ".join(entry.bug_names()) or "-"
        degs = "/".join(degree_token(d) for d in entry.degrees)
        print(f"  {name:12s} degrees={degs:8s} expected={entry.expected:12s} "
              f"bugs: {bugs}")
    print("registered bugs (bug -> host case, detection):")
    for bug, (host, bspec) in sorted(list_bugs().items()):
        print(f"  {bug:16s} -> {host:12s} ({bspec.expected})")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--case", default="tp_layer", choices=list_strategies())
    ap.add_argument("--bug", default=None, choices=sorted(list_bugs()),
                    help="inject a bug class (must be hosted by --case)")
    ap.add_argument("--degree", type=parse_degree, default=2,
                    help="int, or per-mesh-axis like `4x2` for 2D cases")
    ap.add_argument("--list", action="store_true",
                    help="print registered cases/bugs and exit")
    ap.add_argument("--json", action="store_true",
                    help="emit the structured Report as JSON")
    args = ap.parse_args(argv)
    if args.list:
        _print_registry()
        return
    if args.json:
        report = verify(args.case, degree=args.degree, bug=args.bug)
        print(json.dumps(report.to_json(), indent=2, sort_keys=True))
        if report.verdict != "certificate":
            sys.exit(1)
        return
    try:
        run_case(args.case, args.bug, args.degree)
        print("REFINEMENT HOLDS (certificate above)")
    except RefinementError as e:
        print("REFINEMENT FAILED — bug localized:")
        print(e)
        sys.exit(1)


if __name__ == "__main__":
    main()

"""GraphGuard pre-launch verification CLI (thin shim over ``repro.api``).

Single-layer strategy cases (the paper-§6 matrix):

    python -m repro.launch.verify --case tp_layer [--bug rope_offset] \
        [--degree 2] [--json] [--list]

Whole-model verification (the ``repro.modelcheck`` subsystem — block-by-
block decomposition with obligation dedup):

    python -m repro.launch.verify --model gpt --plan dp2xtp2 \
        [--inject-bug wrong_spec [--bug-layer 3]] [--workers 4] [--json]

The case matrix lives in the ``repro.api`` registry (populated by
``repro.dist.strategies``); model-level tasks resolve through
``repro.modelcheck``.  ``--list`` prints both.  ``--json`` emits the
structured report (a ``repro.api.Report`` or ``ModelReport``) wrapped in a
stable envelope carrying ``schema_version`` and per-phase ``timing`` stats
so downstream tooling can gate on it.  For matrix runs use the suite
runner: ``python -m repro.api``.
"""
from __future__ import annotations

import argparse
import json
import sys

from ..api import (build_spec, degree_token, get_strategy, list_bugs,
                   list_model_tasks, list_strategies, parse_degree, run_spec,
                   verify)
from ..core import RefinementError
from ..dist.strategies import STRATEGY_CASES as CASES  # legacy view re-export

# the --json envelope: {"schema_version", "kind", "timing", "report"}
JSON_SCHEMA_VERSION = 2


def run_case(case: str, bug=None, degree: int = 2, max_nodes=400_000,
             quiet=False):
    spec = build_spec(case, degree=degree, bug=bug)
    cert = run_spec(spec, engine_opts={"max_nodes": max_nodes})
    if not quiet:
        print(f"[verify] {case} degree={degree} bug={bug}: "
              f"G_s ops={cert.stats['gs_ops']} G_d ops={cert.stats['gd_ops']}")
        print("R_o certificate:")
        for k, v in cert.r_o.items():
            print(f"  {k} = {v}")
        print(f"  ({cert.stats['time_s']*1e3:.1f} ms, "
              f"{cert.stats['egraph_nodes']} e-nodes)")
    return cert


def _print_registry():
    print("registered cases (repro.api registry):")
    for name in list_strategies():
        entry = get_strategy(name)
        bugs = ", ".join(entry.bug_names()) or "-"
        degs = "/".join(degree_token(d) for d in entry.degrees)
        print(f"  {name:12s} degrees={degs:8s} expected={entry.expected:12s} "
              f"bugs: {bugs}")
    print("registered bugs (bug -> host case, detection):")
    for bug, (host, bspec) in sorted(list_bugs().items()):
        print(f"  {bug:16s} -> {host:12s} ({bspec.expected})")
    print("model-level tasks (repro.modelcheck; --model M --plan P):")
    for task in list_model_tasks():
        print(f"  {task}")


def _json_envelope(kind: str, report_json: dict, timing: dict) -> str:
    return json.dumps({
        "schema_version": JSON_SCHEMA_VERSION,
        "kind": kind,
        "timing": timing,
        "report": report_json,
    }, indent=2, sort_keys=True)


def _case_timing(report) -> dict:
    stats = report.stats or {}
    return {
        "wall_s": report.wall_s,
        "infer_s": stats.get("time_s", 0.0),
        "phase_s": dict(stats.get("phase_s") or {}),
    }


def _run_model(args) -> int:
    from ..modelcheck import ModelCheckError, check_model
    try:
        report = check_model(args.model, args.plan, bug=args.inject_bug,
                             bug_layer=args.bug_layer, workers=args.workers)
    except (ModelCheckError, ValueError) as e:
        print(f"[modelcheck] {e}", file=sys.stderr)
        return 2
    if args.json:
        print(_json_envelope("model", report.to_json(), report.timing()))
    else:
        print(report.to_markdown())
        if report.verdict == "certificate":
            print("WHOLE-MODEL REFINEMENT HOLDS "
                  f"({report.unique_obligations} obligations verified for "
                  f"{report.total_blocks} blocks, "
                  f"dedup {report.dedup_ratio:.1f}x)")
        else:
            print(f"WHOLE-MODEL VERDICT: {report.verdict} — failing "
                  f"blocks {report.failing_blocks}")
    # exit codes: 0 clean certificate; 1 expected failure (an injected bug
    # detected AND localized to its block — report.ok encodes that); 2 a
    # harness problem (clean run not ok, or a bug run failing in the wrong
    # block), so CI gates that assert rc==1 catch mis-localization.
    if args.inject_bug is not None:
        if not report.ok:
            print(f"[modelcheck] injected bug NOT correctly localized "
                  f"(expected block {1 + (report.bug_layer or 0)}, failing "
                  f"blocks {report.failing_blocks})", file=sys.stderr)
            return 2
        return 1
    return 0 if report.ok else 1


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--case", default=None, choices=list_strategies(),
                    help="single-layer strategy case (default: tp_layer "
                         "unless --model is given)")
    ap.add_argument("--bug", default=None, choices=sorted(list_bugs()),
                    help="inject a bug class (must be hosted by --case)")
    ap.add_argument("--degree", type=parse_degree, default=2,
                    help="int, or per-mesh-axis like `4x2` for 2D cases")
    ap.add_argument("--model", default=None,
                    help="whole-model verification: a model id like `gpt` "
                         "(see --list)")
    ap.add_argument("--plan", default="dp2xtp2",
                    help="mesh plan for --model, e.g. dp2 / tp2 / dp2xtp2")
    ap.add_argument("--inject-bug", default=None, choices=("wrong_spec",),
                    help="inject a whole-model bug into one layer")
    ap.add_argument("--bug-layer", type=int, default=None,
                    help="layer index for --inject-bug (default: middle)")
    ap.add_argument("--workers", type=int, default=None,
                    help="process-pool size for --model (default: auto)")
    ap.add_argument("--list", action="store_true",
                    help="print registered cases/bugs/model tasks and exit")
    ap.add_argument("--json", action="store_true",
                    help="emit the structured report as JSON (with "
                         "schema_version + per-phase timing)")
    args = ap.parse_args(argv)
    if args.list:
        _print_registry()
        return
    if args.model is not None:
        if args.case is not None or args.bug is not None:
            ap.error("--model/--plan and --case/--bug are separate paths")
        rc = _run_model(args)
        if rc:
            sys.exit(rc)
        return
    if args.inject_bug is not None or args.bug_layer is not None \
            or args.workers is not None:
        ap.error("--inject-bug/--bug-layer/--workers require --model "
                 "(the case path takes --bug)")
    if args.case is None:
        args.case = "tp_layer"
    if args.json:
        report = verify(args.case, degree=args.degree, bug=args.bug)
        print(_json_envelope("case", report.to_json(),
                             _case_timing(report)))
        if report.verdict != "certificate":
            sys.exit(1)
        return
    try:
        run_case(args.case, args.bug, args.degree)
        print("REFINEMENT HOLDS (certificate above)")
    except RefinementError as e:
        print("REFINEMENT FAILED — bug localized:")
        print(e)
        sys.exit(1)


if __name__ == "__main__":
    main()

"""GraphGuard pre-launch verification CLI (thin shim over ``repro.api``).

Single-layer strategy cases (the paper-§6 matrix):

    python -m repro.launch.verify --case tp_layer [--bug rope_offset] \
        [--degree 2] [--json] [--list]

Whole-model verification (the ``repro.modelcheck`` subsystem — block-by-
block decomposition with obligation dedup):

    python -m repro.launch.verify --model gpt --plan dp2xtp2 \
        [--inject-bug wrong_spec [--bug-layer 3]] [--workers 4] [--json]

Training-step verification (the ``repro.gradcheck`` subsystem —
per-parameter gradient obligations, relations transposed from the
forward specs):

    python -m repro.launch.verify --train dp_accum \
        [--inject-bug accum_no_rescale] [--degree 2] [--workers 2] [--json]

Serving-path verification (the ``repro.servecheck`` subsystem —
sharded-KV-cache decode steps deduped by position class, plus the
prefill read proving the chain composes):

    python -m repro.launch.verify --serve tp_decode \
        [--inject-bug stale_cache_shard] [--degree 2] [--workers 2] [--json]

Bring-your-own-function verification (the generic jaxpr frontend,
``repro.core.from_jaxpr`` + ``repro.api.verify_functions``): point
``--fn`` at a ``module:callable`` whose callable returns the task —
a dict with ``fn_seq``/``fn_dist``/``mesh``/``in_specs``/``avals``
(or ``example_args``), a ``StrategySpec``, or the legacy 6-tuple:

    python -m repro.launch.verify \
        --fn examples/verify_your_own_fn.py:make_task [--json]

The case matrix lives in the ``repro.api`` registry (populated by
``repro.dist.strategies``); model-level tasks resolve through
``repro.modelcheck``, train-step tasks through ``repro.gradcheck`` and
serving tasks through ``repro.servecheck``.  ``--list`` prints all four
with a kind tag per entry.  ``--json`` emits the structured report (a
``repro.api.Report``, ``ModelReport``, ``TrainReport``, or
``ServeReport``) wrapped in a stable envelope carrying
``schema_version`` and per-phase ``timing`` stats so downstream tooling
can gate on it.  For matrix runs use the suite runner:
``python -m repro.api``.

Observability (the ``repro.obs`` subsystem — see docs/OBSERVABILITY.md):

    python -m repro.launch.verify --serve tp_decode --trace trace.json
    python -m repro.obs report trace.json

``--trace PATH`` records every engine/pool/cache span of the run into a
Chrome/Perfetto-loadable ``trace.json`` (plus a grep-friendly
``PATH.jsonl``), merging pool-worker spans onto the same timeline;
``--metrics`` prints the process-local metrics registry to stderr and —
under ``--json`` — adds a ``metrics`` key to the envelope.  Neither flag
changes certificates or stable summaries.
"""
from __future__ import annotations

import argparse
import json
import sys

from ..api import (build_spec, degree_token, get_strategy, list_bugs,
                   list_model_tasks, list_strategies, list_train_tasks,
                   parse_degree, run_spec, task_id, verify)
from ..core import RefinementError
from ..dist.strategies import STRATEGY_CASES as CASES  # legacy view re-export

# the --json envelope: {"schema_version", "kind", "timing", "report"}
# (+ opt-in "metrics"/"explanation" keys — only when --metrics/--explain
# are passed, so default envelopes keep their pinned four-key shape)
JSON_SCHEMA_VERSION = 2


def run_case(case: str, bug=None, degree: int = 2, max_nodes=400_000,
             quiet=False):
    spec = build_spec(case, degree=degree, bug=bug)
    cert = run_spec(spec, engine_opts={"max_nodes": max_nodes})
    if not quiet:
        print(f"[verify] {case} degree={degree} bug={bug}: "
              f"G_s ops={cert.stats['gs_ops']} G_d ops={cert.stats['gd_ops']}")
        print("R_o certificate:")
        for k, v in cert.r_o.items():
            print(f"  {k} = {v}")
        print(f"  ({cert.stats['time_s']*1e3:.1f} ms, "
              f"{cert.stats['egraph_nodes']} e-nodes)")
    return cert


def _print_registry():
    """One line per registered task, each tagged by kind:

    ``[case]`` single-layer strategies (``--case``), ``[model]``
    whole-model tasks (``--model``/``--plan``), ``[train]`` training-step
    tasks (``--train``), ``[serve]`` serving-path tasks (``--serve``) —
    the four task registries side by side.
    """
    from ..gradcheck import get_train_strategy, list_train_bugs
    from ..servecheck import get_serve_strategy, list_serve_bugs

    print("registered tasks (kind-tagged; see --case / --model / --train "
          "/ --serve):")
    for name in list_strategies():
        entry = get_strategy(name)
        bugs = ", ".join(entry.bug_names()) or "-"
        degs = "/".join(degree_token(d) for d in entry.degrees)
        print(f"  [case]  {name:16s} degrees={degs:10s} "
              f"expected={entry.expected:12s} bugs: {bugs}")
    for task in list_model_tasks():
        model, _, plan = task.partition("@")
        print(f"  [model] {task:16s} (--model {model} --plan {plan})")
    for task in list_train_tasks():
        entry = get_train_strategy(task.partition("@")[2])
        bugs = ", ".join(entry.bug_names()) or "-"
        degs = "/".join(degree_token(d) for d in entry.degrees)
        print(f"  [train] {task:16s} degrees={degs:10s} "
              f"params={','.join(entry.params):8s} bugs: {bugs}")
    from ..api import list_serve_tasks
    for task in list_serve_tasks():
        entry = get_serve_strategy(task.partition("@")[2])
        bugs = ", ".join(entry.bug_names()) or "-"
        degs = "/".join(degree_token(d) for d in entry.degrees)
        print(f"  [serve] {task:16s} degrees={degs:10s} "
              f"steps={entry.n_steps:<8d} bugs: {bugs}")
    from ..modelcheck.decompose import BUGS as MODEL_BUGS

    print("registered bugs (bug -> host, detection):")
    for bug, (host, bspec) in sorted(list_bugs().items()):
        print(f"  [case]  {bug:22s} -> {host:12s} ({bspec.expected})")
    for bug in MODEL_BUGS:
        print(f"  [model] {bug:22s} -> --model tasks (refinement_error)")
    for bug, (host, bspec) in sorted(list_train_bugs().items()):
        print(f"  [train] {bug:22s} -> train@{host:12s} ({bspec.expected})")
    for bug, (host, bspec) in sorted(list_serve_bugs().items()):
        print(f"  [serve] {bug:22s} -> serve@{host:12s} ({bspec.expected})")


def _json_envelope(kind: str, report_json: dict, timing: dict,
                   metrics=None, explain: bool = False) -> str:
    env = {
        "schema_version": JSON_SCHEMA_VERSION,
        "kind": kind,
        "timing": timing,
        "report": report_json,
    }
    if metrics is not None:
        env["metrics"] = metrics
    if explain:
        # hoist the proof provenance to the envelope level (best-effort:
        # None when the engine produced no explanation, e.g. on a harness
        # error before inference started)
        env["explanation"] = report_json.pop("explanation", None)
    return json.dumps(env, indent=2, sort_keys=True)


def _metrics_snapshot(args):
    """The registry snapshot for the envelope — None unless --metrics."""
    if not getattr(args, "metrics", False):
        return None
    from ..obs.metrics import REGISTRY
    return REGISTRY.snapshot()


def _cli_engine_opts(args):
    """Engine options the CLI flags map onto — None when defaulted."""
    if getattr(args, "explain", False):
        return {"explain": True}
    return None


def _print_narrative(expl) -> None:
    """Render an explanation (any kind) to stdout under --explain."""
    from ..core.explain import render_narrative
    if not expl:
        print("[explain] no explanation available for this run")
        return
    print("[explain] proof provenance:")
    for line in render_narrative(expl):
        print(f"  {line}")


def _case_timing(report) -> dict:
    stats = report.stats or {}
    return {
        "wall_s": report.wall_s,
        "infer_s": stats.get("time_s", 0.0),
        "phase_s": dict(stats.get("phase_s") or {}),
    }


def _run_model(args, cache) -> int:
    from ..modelcheck import ModelCheckError, check_model
    from ..modelcheck.schedule import DEFAULT_TIMEOUT_S
    try:
        report = check_model(args.model, args.plan, bug=args.inject_bug,
                             bug_layer=args.bug_layer, workers=args.workers,
                             engine_opts=_cli_engine_opts(args),
                             timeout_s=args.timeout or DEFAULT_TIMEOUT_S,
                             cache=cache)
    except (ModelCheckError, ValueError) as e:
        print(f"[modelcheck] {e}", file=sys.stderr)
        return 2
    if args.json:
        print(_json_envelope("model", report.to_json(), report.timing(),
                             metrics=_metrics_snapshot(args),
                             explain=args.explain))
    else:
        print(report.to_markdown())
        if args.explain:
            _print_narrative(report.explanation)
        if report.verdict == "certificate":
            print("WHOLE-MODEL REFINEMENT HOLDS "
                  f"({report.unique_obligations} obligations verified for "
                  f"{report.total_blocks} blocks, "
                  f"dedup {report.dedup_ratio:.1f}x)")
        else:
            print(f"WHOLE-MODEL VERDICT: {report.verdict} — failing "
                  f"blocks {report.failing_blocks}")
    # exit codes: 0 clean certificate; 1 expected failure (an injected bug
    # detected AND localized to its block — report.ok encodes that); 2 a
    # harness problem (clean run not ok, or a bug run failing in the wrong
    # block), so CI gates that assert rc==1 catch mis-localization.
    if args.inject_bug is not None:
        if not report.ok:
            print(f"[modelcheck] injected bug NOT correctly localized "
                  f"(expected block {1 + (report.bug_layer or 0)}, failing "
                  f"blocks {report.failing_blocks})", file=sys.stderr)
            return 2
        return 1
    return 0 if report.ok else 1


def _run_train(args, cache) -> int:
    from ..gradcheck import check_train
    from ..gradcheck.schedule import DEFAULT_TIMEOUT_S
    try:
        report = check_train(args.train, degree=args.degree,
                             bug=args.inject_bug, workers=args.workers,
                             engine_opts=_cli_engine_opts(args),
                             timeout_s=args.timeout or DEFAULT_TIMEOUT_S,
                             cache=cache)
    except (KeyError, ValueError) as e:
        print(f"[gradcheck] {e}", file=sys.stderr)
        return 2
    if args.json:
        print(_json_envelope("train", report.to_json(), report.timing(),
                             metrics=_metrics_snapshot(args),
                             explain=args.explain))
    else:
        print(report.to_markdown())
        if args.explain:
            _print_narrative(report.explanation)
        if report.verdict == "certificate":
            print(f"TRAIN-STEP REFINEMENT HOLDS ({len(report.params)} "
                  f"parameter gradients verified, relations transposed "
                  f"from the forward specs)")
        else:
            print(f"TRAIN-STEP VERDICT: {report.verdict} — failing "
                  f"parameters {report.failing_params}")
    # exit codes mirror the model path: 0 clean certificate; 1 expected
    # failure (injected gradient bug detected AND localized to its
    # parameter — report.ok encodes that); 2 a harness problem, so CI
    # gates that assert rc==1 catch mis-localization.
    if args.inject_bug is not None:
        if not report.ok:
            print(f"[gradcheck] injected bug NOT correctly localized "
                  f"(expected parameter {report.bug_param!r}, failing "
                  f"parameters {report.failing_params})", file=sys.stderr)
            return 2
        return 1
    return 0 if report.ok else 1


def _run_serve(args, cache) -> int:
    from ..servecheck import check_serve
    from ..servecheck.schedule import DEFAULT_TIMEOUT_S
    try:
        report = check_serve(args.serve, degree=args.degree,
                             bug=args.inject_bug, workers=args.workers,
                             engine_opts=_cli_engine_opts(args),
                             timeout_s=args.timeout or DEFAULT_TIMEOUT_S,
                             cache=cache)
    except (KeyError, ValueError) as e:
        print(f"[servecheck] {e}", file=sys.stderr)
        return 2
    if args.json:
        print(_json_envelope("serve", report.to_json(), report.timing(),
                             metrics=_metrics_snapshot(args),
                             explain=args.explain))
    else:
        print(report.to_markdown())
        if args.explain:
            _print_narrative(report.explanation)
        if report.verdict == "certificate":
            print(f"SERVING-PATH REFINEMENT HOLDS ({report.total_steps} "
                  f"serving blocks proved by {report.unique_obligations} "
                  f"obligations, dedup {report.dedup_ratio:.1f}x — decode "
                  f"chain refines full-sequence prefill)")
        else:
            print(f"SERVING-PATH VERDICT: {report.verdict} — failing "
                  f"steps {report.failing_steps}")
    # exit codes mirror the model/train paths: 0 clean certificate; 1
    # expected failure (injected serving bug detected AND localized to
    # its decode step — report.ok encodes that); 2 a harness problem, so
    # CI gates that assert rc==1 catch mis-localization.
    if args.inject_bug is not None:
        if not report.ok:
            print(f"[servecheck] injected bug NOT correctly localized "
                  f"(expected step{report.bug_step}, failing steps "
                  f"{report.failing_steps})", file=sys.stderr)
            return 2
        return 1
    return 0 if report.ok else 1


def _load_fn_task(target: str):
    """Resolve a ``--fn module:callable`` target and call it.

    The module part is either an importable dotted name or a path to a
    ``.py`` file; the callable takes no arguments and returns the task
    description (dict / ``StrategySpec`` / legacy 6-tuple).
    """
    mod_part, sep, attr = target.partition(":")
    if not sep or not mod_part or not attr:
        raise ValueError(f"--fn takes MODULE:CALLABLE, got `{target}`")
    if mod_part.endswith(".py") or "/" in mod_part:
        import importlib.util
        spec = importlib.util.spec_from_file_location("_verify_fn_target",
                                                      mod_part)
        if spec is None or spec.loader is None:
            raise ValueError(f"cannot load module file `{mod_part}`")
        module = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(module)
    else:
        import importlib
        module = importlib.import_module(mod_part)
    fn = getattr(module, attr, None)
    if fn is None or not callable(fn):
        raise ValueError(f"`{mod_part}` has no callable `{attr}`")
    return fn()


def _fn_task_kwargs(task) -> dict:
    """Normalize a ``--fn`` task into ``verify_functions`` keywords."""
    from ..api import StrategySpec
    if isinstance(task, StrategySpec):
        return dict(fn_seq=task.seq_fn, fn_dist=task.dist_fn,
                    mesh=task.mesh_axes, in_specs=task.in_specs,
                    avals=task.avals, input_names=task.input_names,
                    name=task.name or None)
    if isinstance(task, dict):
        d = dict(task)
        for old, new in (("seq_fn", "fn_seq"), ("dist_fn", "fn_dist"),
                         ("mesh_axes", "mesh"), ("names", "input_names")):
            if old in d and new not in d:
                d[new] = d.pop(old)
        allowed = {"fn_seq", "fn_dist", "mesh", "in_specs", "avals",
                   "input_names", "example_args", "name", "strict"}
        unknown = sorted(set(d) - allowed)
        if unknown:
            raise ValueError(f"unknown task keys {unknown} "
                             f"(allowed: {sorted(allowed)})")
        missing = sorted({"fn_seq", "fn_dist", "mesh", "in_specs"} - set(d))
        if missing:
            raise ValueError(f"task is missing required keys {missing}")
        return d
    if isinstance(task, (tuple, list)) and len(task) == 6:
        fn_seq, fn_dist, mesh, in_specs, avals, names = task
        return dict(fn_seq=fn_seq, fn_dist=fn_dist, mesh=mesh,
                    in_specs=in_specs, avals=avals, input_names=names)
    raise ValueError(
        f"--fn callable must return a dict, StrategySpec, or 6-tuple, got "
        f"{type(task).__name__}")


def _run_fn(args) -> int:
    """Run the ``--fn`` path: generic jaxpr capture -> standard Report.

    Exit codes follow the case path: 0 clean certificate, 1 refinement
    failure (the implementation does not refine the sequential function),
    2 a harness problem (bad --fn target, or capture/engine error —
    including ``UnsupportedPrimitive`` for code the term language cannot
    model).
    """
    from ..api import verify_functions
    try:
        task = _load_fn_task(args.fn)
        kw = _fn_task_kwargs(task)
    except (ValueError, TypeError, KeyError, ImportError, OSError,
            AttributeError) as e:
        print(f"[fn] {e}", file=sys.stderr)
        return 2
    engine_opts = {"max_nodes": 400_000}
    engine_opts.update(_cli_engine_opts(args) or {})
    report = verify_functions(engine_opts=engine_opts, **kw)
    if args.json:
        print(_json_envelope("fn", report.to_json(), _case_timing(report),
                             metrics=_metrics_snapshot(args),
                             explain=args.explain))
    elif report.verdict == "certificate":
        for k, v in (report.r_o or {}).items():
            print(f"  {k} = {v}")
        print(f"REFINEMENT HOLDS — `{report.case}` refines its sequential "
              f"spec (certificate above)")
        if args.explain:
            _print_narrative(report.explanation)
    elif report.verdict == "refinement_error":
        print(f"REFINEMENT FAILED — `{report.case}` bug localized:")
        print(json.dumps(report.localization, indent=2, sort_keys=True))
        if args.explain:
            _print_narrative(report.explanation)
    else:
        print(f"VERDICT: {report.verdict} — {report.error}")
    if report.verdict == "certificate":
        return 0
    return 1 if report.verdict == "refinement_error" else 2


def _case_report(args, cache) -> dict:
    """Run the single case through the shared runtime so ``--timeout`` and
    ``--cache`` behave exactly as they do for suite/model/train runs."""
    from ..api import Report
    from ..api.suite import _run_task
    from ..runtime import (RuntimeTask, SupervisedPool, execute_inline,
                           strategy_cache_key)
    eo = _cli_engine_opts(args)
    key = task_id(args.case, args.degree, args.bug)
    cache_key = None if cache is None else strategy_cache_key(
        build_spec(args.case, degree=args.degree, bug=args.bug), eo)
    rt = RuntimeTask(key=key, fn=_run_task,
                     args=((args.case, args.degree, args.bug), eo),
                     budget_s=args.timeout or 120.0, cache_key=cache_key)
    if args.timeout is not None:
        # budget enforcement needs a supervisor outside the task — one
        # supervised worker, killed if it overruns
        with SupervisedPool(1) as pool:
            outcome = pool.execute([rt], cache=cache)[key]
    else:
        outcome = execute_inline([rt], cache=cache)[key]
    if outcome.ok:
        d = dict(outcome.value)
        info = outcome.runtime_info()
        if info:
            d["runtime"] = info
        return d
    entry = get_strategy(args.case)
    expected = entry.expected if args.bug is None \
        else entry.bug_spec(args.bug).expected
    return Report(
        case=args.case, degree=args.degree, bug=args.bug,
        verdict="timeout" if outcome.status == "timeout" else "error",
        expected=expected, ok=False, error=outcome.error,
        wall_s=round(outcome.wall_s, 6),
        runtime=outcome.runtime_info() or None).to_json()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--case", default=None, choices=list_strategies(),
                    help="single-layer strategy case (default: tp_layer "
                         "unless --model is given)")
    ap.add_argument("--bug", default=None, choices=sorted(list_bugs()),
                    help="inject a bug class (must be hosted by --case)")
    from ..gradcheck import list_train_bugs, list_train_strategies
    from ..modelcheck.decompose import BUGS as model_bugs
    from ..servecheck import list_serve_bugs, list_serve_strategies
    train_bugs = sorted(list_train_bugs())
    serve_bugs = sorted(list_serve_bugs())
    ap.add_argument("--degree", type=parse_degree, default=None,
                    help="int, or per-mesh-axis like `4x2` for 2D cases "
                         "(default: 2 for --case, the strategy's first "
                         "registered degree for --train)")
    ap.add_argument("--model", default=None,
                    help="whole-model verification: a model id like `gpt` "
                         "(see --list)")
    ap.add_argument("--plan", default="dp2xtp2",
                    help="mesh plan for --model, e.g. dp2 / tp2 / dp2xtp2")
    ap.add_argument("--train", default=None,
                    choices=list_train_strategies(),
                    help="training-step verification: a train strategy "
                         "like `dp_accum` (see --list)")
    ap.add_argument("--serve", default=None,
                    choices=list_serve_strategies(),
                    help="serving-path verification: a serve strategy "
                         "like `tp_decode` (see --list)")
    ap.add_argument("--fn", default=None, metavar="MODULE:CALLABLE",
                    help="verify an arbitrary user function pair via the "
                         "generic jaxpr frontend: CALLABLE() returns the "
                         "task (a dict with fn_seq/fn_dist/mesh/in_specs/"
                         "avals or example_args, a StrategySpec, or the "
                         "legacy 6-tuple) — see docs/CLI.md")
    ap.add_argument("--inject-bug", default=None,
                    choices=tuple(model_bugs) + tuple(train_bugs)
                    + tuple(serve_bugs),
                    help="inject a whole-model bug into one layer "
                         "(--model), a gradient bug into one parameter "
                         "(--train), or a serving bug into one decode "
                         "step (--serve)")
    ap.add_argument("--bug-layer", type=int, default=None,
                    help="layer index for --model --inject-bug "
                         "(default: middle)")
    ap.add_argument("--workers", type=int, default=None,
                    help="process-pool size for --model/--train "
                         "(default: auto)")
    ap.add_argument("--timeout", type=float, default=None,
                    help="per-task budget in seconds, unified across "
                         "--case/--model/--train and enforced by the "
                         "supervised runtime from the moment a task "
                         "starts on a worker (default: unbudgeted for "
                         "--case, 600s per obligation for "
                         "--model/--train)")
    from ..api.suite import add_cache_flags
    add_cache_flags(ap)
    ap.add_argument("--list", action="store_true",
                    help="print registered case/model/train tasks and "
                         "bugs (kind-tagged) and exit")
    ap.add_argument("--json", action="store_true",
                    help="emit the structured report as JSON (with "
                         "schema_version + per-phase timing)")
    ap.add_argument("--explain", action="store_true",
                    help="record proof provenance and emit the lemma-chain "
                         "explanation: the equality chain proving each "
                         "certificate (replayable outside the e-graph), or "
                         "the failure frontier around the stuck op for "
                         "refinement errors; adds an `explanation` key to "
                         "the --json envelope (see docs/EXPLANATIONS.md)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record engine/pool/cache spans into a Chrome/"
                         "Perfetto trace JSON at PATH (plus PATH.jsonl; "
                         "a .json.gz PATH gzips both); inspect with "
                         "`python -m repro.obs report PATH`")
    ap.add_argument("--metrics", action="store_true",
                    help="print the metrics registry to stderr after the "
                         "run (and add a `metrics` key to the --json "
                         "envelope)")
    args = ap.parse_args(argv)
    if args.list:
        _print_registry()
        return
    import os
    prev_explain = os.environ.get("GRAPHGUARD_EXPLAIN")
    if args.explain:
        # ambient default so spawn-pool workers (which rebuild engines
        # from registry names) inherit provenance recording
        os.environ["GRAPHGUARD_EXPLAIN"] = "1"
    try:
        if args.trace is None and not args.metrics:
            return _dispatch(ap, args)
        from ..obs import trace as obs_trace
        from ..obs.metrics import REGISTRY
        if args.metrics:
            REGISTRY.reset()             # per-run numbers, not per-process
        tracer = obs_trace.start("main")
        try:
            return _dispatch(ap, args)
        finally:
            # runs on sys.exit too — bug-detection exit codes (1) still
            # get their trace/metrics
            obs_trace.stop()
            _finish_obs(args, tracer)
    finally:
        # in-process callers (tests) must not inherit the ambient flag
        if args.explain:
            if prev_explain is None:
                os.environ.pop("GRAPHGUARD_EXPLAIN", None)
            else:
                os.environ["GRAPHGUARD_EXPLAIN"] = prev_explain


def _finish_obs(args, tracer) -> None:
    """Export the trace and/or render the metrics registry (stderr only —
    stdout stays report/envelope material)."""
    if args.trace is not None:
        tracer.write_chrome(args.trace)
        # a gzipped trace gets a gzipped jsonl sibling
        jsonl = args.trace[:-len(".json.gz")] + ".jsonl.gz" \
            if args.trace.endswith(".json.gz") else args.trace + ".jsonl"
        tracer.write_jsonl(jsonl)
        print(f"[obs] wrote {args.trace} (+ {jsonl}) — inspect "
              f"with `python -m repro.obs report {args.trace}`",
              file=sys.stderr)
    if args.metrics:
        from ..obs.metrics import render
        print(render(), file=sys.stderr)


def _dispatch(ap, args):
    """Route the parsed args to the case/model/train/serve/fn path."""
    from ..api.suite import cache_from_args
    from ..gradcheck import list_train_bugs
    from ..modelcheck.decompose import BUGS as model_bugs
    from ..runtime import resolve_cache
    from ..servecheck import list_serve_bugs
    train_bugs = sorted(list_train_bugs())
    serve_bugs = sorted(list_serve_bugs())
    cache = resolve_cache(cache_from_args(args))
    if sum(x is not None
           for x in (args.model, args.train, args.serve, args.fn)) > 1:
        ap.error("--model, --train, --serve and --fn are separate paths")
    if args.fn is not None:
        if args.case is not None or args.bug is not None \
                or args.inject_bug is not None or args.bug_layer is not None:
            ap.error("--fn and --case/--bug/--inject-bug are separate paths")
        rc = _run_fn(args)
        if rc:
            sys.exit(rc)
        return
    if args.model is not None:
        if args.case is not None or args.bug is not None:
            ap.error("--model/--plan and --case/--bug are separate paths")
        if args.inject_bug in train_bugs:
            ap.error(f"--inject-bug {args.inject_bug} is a gradient bug — "
                     f"it requires --train")
        if args.inject_bug in serve_bugs:
            ap.error(f"--inject-bug {args.inject_bug} is a serving bug — "
                     f"it requires --serve")
        rc = _run_model(args, cache)
        if rc:
            sys.exit(rc)
        return
    if args.train is not None:
        if args.case is not None or args.bug is not None:
            ap.error("--train and --case/--bug are separate paths")
        if args.inject_bug in model_bugs:
            ap.error(f"--inject-bug {args.inject_bug} is a whole-model "
                     f"bug — it requires --model")
        if args.inject_bug in serve_bugs:
            ap.error(f"--inject-bug {args.inject_bug} is a serving bug — "
                     f"it requires --serve")
        if args.bug_layer is not None:
            ap.error("--bug-layer applies to --model (gradient bugs "
                     "localize to a parameter, not a layer)")
        rc = _run_train(args, cache)
        if rc:
            sys.exit(rc)
        return
    if args.serve is not None:
        if args.case is not None or args.bug is not None:
            ap.error("--serve and --case/--bug are separate paths")
        if args.inject_bug in model_bugs:
            ap.error(f"--inject-bug {args.inject_bug} is a whole-model "
                     f"bug — it requires --model")
        if args.inject_bug in train_bugs:
            ap.error(f"--inject-bug {args.inject_bug} is a gradient bug — "
                     f"it requires --train")
        if args.bug_layer is not None:
            ap.error("--bug-layer applies to --model (serving bugs "
                     "localize to a decode step, not a layer)")
        rc = _run_serve(args, cache)
        if rc:
            sys.exit(rc)
        return
    if args.inject_bug is not None or args.bug_layer is not None \
            or args.workers is not None:
        ap.error("--inject-bug/--bug-layer/--workers require --model, "
                 "--train or --serve (the case path takes --bug)")
    if args.case is None:
        args.case = "tp_layer"
    if args.degree is None:
        args.degree = 2
    if args.json or args.explain or args.timeout is not None \
            or cache is not None:
        from ..api import Report
        d = _case_report(args, cache)
        report = Report.from_json(d)
        if args.json:
            print(_json_envelope("case", d, _case_timing(report),
                                 metrics=_metrics_snapshot(args),
                                 explain=args.explain))
        elif report.verdict == "certificate":
            for k, v in (report.r_o or {}).items():
                print(f"  {k} = {v}")
            print("REFINEMENT HOLDS (certificate above)")
            if args.explain:
                _print_narrative(report.explanation)
        elif report.verdict == "refinement_error":
            print("REFINEMENT FAILED — bug localized:")
            print(json.dumps(report.localization, indent=2, sort_keys=True))
            if args.explain:
                _print_narrative(report.explanation)
        else:
            print(f"VERDICT: {report.verdict} — {report.error}")
        if report.verdict != "certificate":
            sys.exit(1)
        return
    try:
        run_case(args.case, args.bug, args.degree)
        print("REFINEMENT HOLDS (certificate above)")
    except RefinementError as e:
        print("REFINEMENT FAILED — bug localized:")
        print(e)
        sys.exit(1)


if __name__ == "__main__":
    main()

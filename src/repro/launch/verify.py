"""GraphGuard pre-launch verification CLI.

    python -m repro.launch.verify --case tp_layer [--bug rope_offset] \
        [--degree 2]

Captures the sequential layer and its shard_map distributed implementation,
derives R_i from the PartitionSpecs, runs iterative relation inference, and
prints the certificate R_o (or the localized bug report).
"""
from __future__ import annotations

import argparse
import sys

from ..core import (capture, capture_spmd, check_refinement, expand_spmd,
                    RefinementError)
from ..dist import strategies as S

CASES = {
    "tp_layer": S.tp_transformer_layer,
    "sp_rope": S.sp_rope_layer,
    "sp_pad": S.sp_pad_slice,
    "ep_moe": S.ep_moe_layer,
    "aux_loss": S.aux_loss_scale,
    "sp_moe": S.sp_moe_layer,
    "grad_accum": S.grad_accum_step,
    "ln_grad": S.ln_weight_grad,
}


def run_case(case: str, bug=None, degree: int = 2, max_nodes=400_000,
             quiet=False):
    builder = CASES[case]
    if bug is not None:
        host = S.BUG_CASES[bug][0]
        if host is not builder:
            hosts = [k for k, b in CASES.items() if b is host]
            raise ValueError(
                f"bug `{bug}` belongs to case {hosts or '?'} — running it "
                f"under `{case}` would silently verify the clean graph")
    seq_fn, dist_fn, mesh_axes, in_specs, avals, names = builder(
        degree=degree, bug=bug)
    gs = capture(seq_fn, avals, names)
    cap = capture_spmd(dist_fn, mesh_axes, in_specs, avals, names)
    gd, r_i = expand_spmd(cap)
    cert = check_refinement(gs, gd, r_i, max_nodes=max_nodes)
    if not quiet:
        print(f"[verify] {case} degree={degree} bug={bug}: "
              f"G_s ops={gs.n_ops} G_d ops={gd.n_ops}")
        print("R_o certificate:")
        for k, v in cert.r_o.items():
            print(f"  {k} = {v}")
        print(f"  ({cert.stats['time_s']*1e3:.1f} ms, "
              f"{cert.stats['egraph_nodes']} e-nodes)")
    return cert


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--case", default="tp_layer", choices=list(CASES))
    ap.add_argument("--bug", default=None, choices=[None] + list(S.BUG_CASES))
    ap.add_argument("--degree", type=int, default=2)
    args = ap.parse_args(argv)
    try:
        run_case(args.case, args.bug, args.degree)
        print("REFINEMENT HOLDS (certificate above)")
    except RefinementError as e:
        print("REFINEMENT FAILED — bug localized:")
        print(e)
        sys.exit(1)


if __name__ == "__main__":
    main()

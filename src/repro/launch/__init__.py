"""Launchers: mesh construction, multi-pod dry-run, training, serving,
and the GraphGuard pre-launch verification CLI."""

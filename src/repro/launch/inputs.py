"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) combo.

Decode shapes lower ``serve_step`` (one token + KV cache of seq_len);
train/prefill lower full-sequence compute. Modality frontends are stubbed:
``frames`` / ``patch_embeds`` arrive as precomputed embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import registry
from ..models.config import INPUT_SHAPES, InputShape, ModelConfig


def sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def skip_reason(cfg: ModelConfig, shape: InputShape):
    """Return a reason string if this (arch, shape) combination is skipped
    (documented in DESIGN.md), else None."""
    if shape.name == "long_500k":
        subq = (cfg.family in ("ssm", "hybrid") or cfg.window > 0)
        if not subq:
            return ("full-attention architecture: long_500k requires "
                    "sub-quadratic attention (DESIGN.md skip table)")
    return None


def batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Model-input specs for train/prefill modes."""
    B, S = shape.global_batch, shape.seq_len
    batch = {}
    if cfg.family == "vlm":
        vt = cfg.vision_tokens
        batch["tokens"] = sds((B, S - vt))
        batch["patch_embeds"] = sds((B, vt, cfg.d_model), jnp.bfloat16)
    elif cfg.family == "audio":
        batch["tokens"] = sds((B, S))
        batch["frames"] = sds((B, cfg.encoder_frames, cfg.d_model),
                              jnp.bfloat16)
    else:
        batch["tokens"] = sds((B, S))
    if shape.mode == "train":
        batch["labels"] = sds((B, S))
    return batch


def decode_specs(cfg: ModelConfig, shape: InputShape):
    """(cache_specs, token_spec, pos_spec) for decode shapes."""
    B, S = shape.global_batch, shape.seq_len
    cache = registry.init_cache(cfg, B, S, abstract=True)
    return cache, sds((B, 1)), sds((), jnp.int32)


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    if shape.mode in ("train", "prefill"):
        return batch_specs(cfg, shape)
    cache, tok, pos = decode_specs(cfg, shape)
    return {"cache": cache, "token": tok, "pos": pos}


# ---------------------------------------------------------------------------
# Logical sharding axes for inputs/caches (mirrors the spec trees)
# ---------------------------------------------------------------------------

def batch_logical(cfg: ModelConfig, shape: InputShape) -> dict:
    out = {"tokens": ("batch", None)}
    if cfg.family == "vlm":
        out["patch_embeds"] = ("batch", None, "embed")
    if cfg.family == "audio":
        out["frames"] = ("batch", None, "embed")
    if shape.mode == "train":
        out["labels"] = ("batch", None)
    return out


def cache_logical(cfg: ModelConfig) -> dict:
    """Logical axes matching registry.init_cache structure."""
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        kv = ("layers", "batch", "kv_seq", "kv_heads", None)
        kv_tail = ("batch", "kv_seq", "kv_heads", None)
        out = {}
        P = len(cfg.pattern)
        for i in range(P):
            out[f"p{i}"] = (kv, kv)
        for i in range(cfg.n_layers % P):
            out[f"tail{i}"] = (kv_tail, kv_tail)
        if fam == "moe":
            out = {k: v for k, v in out.items() if k.startswith("p")}
        return out
    if fam == "ssm":
        return {
            "ssm_state": ("layers", "batch", "heads", "state", None),
            "conv_state": ("layers", "batch", "conv", "ff"),
        }
    if fam == "hybrid":
        P = len(cfg.pattern)
        reps, tail = cfg.n_layers // P, cfg.n_layers % P
        out = {}
        for i, role in enumerate(cfg.pattern):
            if role == "recurrent":
                out[f"p{i}"] = {"state": ("layers", "batch", "ff"),
                                "conv": ("layers", "batch", "conv", "ff")}
            else:
                out[f"p{i}"] = {
                    "k": ("layers", "batch", "kv_seq", "kv_heads", None),
                    "v": ("layers", "batch", "kv_seq", "kv_heads", None)}
        for i in range(tail):
            role = cfg.pattern[i]
            if role == "recurrent":
                out[f"tail{i}"] = {"state": ("batch", "ff"),
                                   "conv": ("batch", "conv", "ff")}
            else:
                out[f"tail{i}"] = {
                    "k": ("batch", "kv_seq", "kv_heads", None),
                    "v": ("batch", "kv_seq", "kv_heads", None)}
        return out
    if fam == "audio":
        ax = ("layers", "batch", "kv_seq", "kv_heads", None)
        return {"self_k": ax, "self_v": ax, "cross_k": ax, "cross_v": ax}
    raise ValueError(fam)

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination on placeholder devices, extract memory/cost/collective analysis,
and derive the three-term roofline.

MUST be the first import in the process: the XLA_FLAGS below forces 512 host
devices and jax locks the device count at first init. (Do not import this
module from tests/benchmarks — they should see 1 device.)

Scan-correction methodology (EXPERIMENTS.md §Dry-run): XLA's cost_analysis
counts a `while` (scan) body once, so per-layer costs are reconstructed by
compiling small *unrolled* probe configs (1 and 2 pattern groups + tail) and
differencing — all numbers still come from compiled artifacts:

    group  = f(2P) - f(P)          base = f(P) - group
    total  = base + reps*group (+ tail from a third probe)

Collective bytes are parsed from the compiled HLO (operand bytes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute)
and extrapolated identically.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse        # noqa: E402
import dataclasses     # noqa: E402
import json            # noqa: E402
import re              # noqa: E402
import time            # noqa: E402
from dataclasses import replace  # noqa: E402

import numpy as np     # noqa: E402
import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..models import registry  # noqa: E402
from ..models.config import INPUT_SHAPES, InputShape, ModelConfig  # noqa: E402
from ..sharding.specs import tree_shardings, use_sharding  # noqa: E402
from ..train.loop import TrainConfig, make_train_step  # noqa: E402
from ..optim import adamw  # noqa: E402
from . import inputs as I  # noqa: E402
from .mesh import (make_production_mesh, mesh_axis_size,  # noqa: E402
                   rules_for_config)

# TPU v5e hardware model (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link
HBM_CAP = 16 * 2**30

COLLECTIVE_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:bf16|f16|f32|f64|s8|u8|s16|s32|u32|s64|i32|pred)"
    r"\[[\d,]*\][^ ]*|\([^)]*\))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")

DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s8": 1, "u8": 1,
               "s16": 2, "s32": 4, "u32": 4, "s64": 8, "i32": 4, "pred": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes per collective kind from HLO text."""
    out = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        shape_s, kind = m.group(2), m.group(3)
        total = 0
        for dt, dims in re.findall(r"(bf16|f16|f32|f64|s8|u8|s16|s32|u32|s64|i32|pred)\[([\d,]*)\]",
                                   shape_s):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + total
    return out


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def build_train(cfg: ModelConfig, shape: InputShape, mesh, rules):
    cfg = replace(cfg, remat=True)   # layer-granularity activation ckpt
    # sequence-parallel residual storage (Korthikanti et al. '22): the
    # between-block activations shard their seq dim over the model axis so
    # per-layer checkpoints are not replicated across TP ranks.
    if os.environ.get("REPRO_SP_RESIDUAL", "1") == "1" and shape.seq_len % 16 == 0:
        rules = rules.with_(seq="model")
    step = make_train_step(cfg, TrainConfig())
    batch_specs = I.batch_specs(cfg, shape)
    params = registry.abstract_params(cfg)
    opt = {"mu": params, "nu": params, "step": jax.ShapeDtypeStruct((), jnp.int32)}
    logical = registry.logical_axes(cfg)
    p_sh = tree_shardings(mesh, rules, logical)
    # ZeRO-1: moments shard their embed dim over data even when params
    # stay replicated across the data axis.
    opt_rules = rules.with_(embed_fsdp="data") \
        if cfg.d_model % mesh_axis_size(mesh, "data") == 0 else rules
    m_sh = tree_shardings(mesh, opt_rules, logical)
    o_sh = {"mu": m_sh, "nu": m_sh,
            "step": NamedSharding(mesh, P())}
    b_logical = I.batch_logical(cfg, shape)
    b_sh = {k: NamedSharding(mesh, rules.spec_for(v))
            for k, v in b_logical.items()}

    def fn(params, opt_state, batch):
        with use_sharding(mesh, rules):
            return step(params, opt_state, batch)

    return fn, (params, opt, batch_specs), (p_sh, o_sh, b_sh), (0, 1)


def build_prefill(cfg: ModelConfig, shape: InputShape, mesh, rules):
    batch_specs = I.batch_specs(cfg, shape)
    params = registry.abstract_params(cfg)
    logical = registry.logical_axes(cfg)
    p_sh = tree_shardings(mesh, rules, logical)
    b_logical = I.batch_logical(cfg, shape)
    b_sh = {k: NamedSharding(mesh, rules.spec_for(v))
            for k, v in b_logical.items()}

    def fn(params, batch):
        with use_sharding(mesh, rules):
            logits, _ = registry.forward(params, cfg, batch)
            return logits

    return fn, (params, batch_specs), (p_sh, b_sh), ()


def build_decode(cfg: ModelConfig, shape: InputShape, mesh, rules):
    # tiny global batches (long_500k B=1) cannot shard over data
    data_total = mesh_axis_size(mesh, "data") * mesh_axis_size(mesh, "pod")
    if shape.global_batch % data_total:
        rules = rules.with_(batch=None)
    # SPerf iteration (hillclimb): when KV heads cannot shard over the model
    # axis, shard the cache *sequence* dim instead (ring-context parallel) —
    # otherwise the KV cache replicates across all 16 TP ranks.
    if os.environ.get("REPRO_DECODE_SEQ_SHARD", "0") == "1":
        rules = rules.with_(kv_seq="model")
    cache, tok, pos = I.decode_specs(cfg, shape)
    params = registry.abstract_params(cfg)
    logical = registry.logical_axes(cfg)
    p_sh = tree_shardings(mesh, rules, logical)
    c_logical = I.cache_logical(cfg)
    c_sh = tree_shardings(mesh, rules, c_logical)
    t_sh = NamedSharding(mesh, rules.spec_for(("batch", None)))
    s_sh = NamedSharding(mesh, P())

    def fn(params, cache, token, pos):
        with use_sharding(mesh, rules):
            return registry.decode_step(params, cfg, cache, token, pos)

    return fn, (params, cache, tok, pos), (p_sh, c_sh, t_sh, s_sh), (1,)


BUILDERS = {"train": build_train, "prefill": build_prefill,
            "decode": build_decode}


# ---------------------------------------------------------------------------
# Compile + analyze
# ---------------------------------------------------------------------------

def compile_and_analyze(cfg, shape, mesh, rules, want_hlo=True):
    fn, args, shardings, donate = BUILDERS[shape.mode](cfg, shape, mesh, rules)
    t0 = time.perf_counter()
    jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
    lowered = jitted.lower(*args)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text()) if want_hlo else {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "mem_args": int(ma.argument_size_in_bytes),
        "mem_out": int(ma.output_size_in_bytes),
        "mem_temp": int(ma.temp_size_in_bytes),
        "mem_alias": int(ma.alias_size_in_bytes),
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
    }


def probe_cfg(cfg: ModelConfig, n_layers: int, enc_scale: float = None):
    upd = dict(n_layers=n_layers, scan_layers=False)
    if cfg.encoder_layers:
        upd["encoder_layers"] = n_layers
    return replace(cfg, **upd)


def extrapolated_costs(cfg, shape, mesh, rules):
    """Per-layer reconstruction via unrolled probe compiles (see module doc)."""
    Pn = len(cfg.pattern)
    reps, tail = cfg.n_layers // Pn, cfg.n_layers % Pn
    f1 = compile_and_analyze(probe_cfg(cfg, Pn), shape, mesh, rules)
    f2 = compile_and_analyze(probe_cfg(cfg, 2 * Pn), shape, mesh, rules)

    def combine(key, is_dict=False):
        if is_dict:
            keys = set(f1[key]) | set(f2[key])
            group = {k: f2[key].get(k, 0) - f1[key].get(k, 0) for k in keys}
            base = {k: f1[key].get(k, 0) - group.get(k, 0) for k in keys}
            total = {k: base[k] + reps * group[k] for k in keys}
            return total, group
        group = f2[key] - f1[key]
        base = f1[key] - group
        return base + reps * group, group

    flops, flops_group = combine("flops")
    byts, _ = combine("bytes_accessed")
    coll, coll_group = combine("collective_bytes", is_dict=True)
    if tail:
        f3 = compile_and_analyze(probe_cfg(cfg, 2 * Pn + tail), shape, mesh,
                                 rules)
        flops += f3["flops"] - f2["flops"]
        byts += f3["bytes_accessed"] - f2["bytes_accessed"]
        for k in coll:
            coll[k] = coll.get(k, 0) + f3["collective_bytes"].get(k, 0) \
                - f2["collective_bytes"].get(k, 0)
    return {"flops": max(flops, 0.0), "bytes_accessed": max(byts, 0.0),
            "collective_bytes": {k: max(v, 0) for k, v in coll.items()}}


def roofline(cfg: ModelConfig, shape: InputShape, est: dict, full: dict,
             n_chips: int) -> dict:
    """All quantities from the per-device SPMD module; terms in seconds."""
    t_comp = est["flops"] / PEAK_FLOPS
    t_mem = est["bytes_accessed"] / HBM_BW
    coll_total = sum(est["collective_bytes"].values())
    t_coll = coll_total / ICI_BW
    dom = max(("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
              key=lambda kv: kv[1])
    n_active = registry.n_active_params(cfg)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        model_flops = 2 * n_active * shape.global_batch
    hlo_total = est["flops"] * n_chips
    return {
        "compute_s": t_comp,
        "memory_s": t_mem,
        "collective_s": t_coll,
        "dominant": dom[0],
        "model_flops": model_flops,
        "hlo_flops_global": hlo_total,
        "useful_ratio": model_flops / hlo_total if hlo_total else 0.0,
        "mem_per_device_gib": (full["mem_args"] + full["mem_temp"]
                               + full["mem_out"] - full["mem_alias"])
        / 2**30,
        "fits_hbm": (full["mem_args"] + full["mem_temp"]) <= HBM_CAP,
    }


def run_combo(arch: str, shape_name: str, multi_pod: bool, outdir: str,
              rules_override=None, tag: str = "", skip_probes: bool = False):
    cfg = registry.load_config(arch)
    shape = INPUT_SHAPES[shape_name]
    reason = I.skip_reason(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    key = f"{arch}_{shape_name}_{mesh_name}{tag}"
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, key + ".json")
    if reason:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "skipped": reason}
        json.dump(rec, open(path, "w"), indent=1)
        print(f"[skip] {key}: {reason}")
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    # SPerf (mixtral iteration): factor the 16-way model axis into
    # (expert=8) x (model=2) so 8 experts shard instead of replicating.
    if os.environ.get("REPRO_MOE_FACTORED", "0") == "1" and cfg.n_experts \
            and cfg.n_experts < 16 and 16 % cfg.n_experts == 0:
        e = cfg.n_experts
        mshape = (2, 16, e, 16 // e) if multi_pod else (16, e, 16 // e)
        axes = ("pod", "data", "expert", "model") if multi_pod \
            else ("data", "expert", "model")
        mesh = jax.make_mesh(mshape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
        base = rules_for_config(cfg, mesh)
        rules_override = base.with_(experts="expert")
    rules = rules_override or rules_for_config(cfg, mesh)
    n_chips = int(np.prod(mesh.devices.shape))
    print(f"[dryrun] {key} ...", flush=True)
    full = compile_and_analyze(cfg, shape, mesh, rules)
    if skip_probes:
        est = {k: full[k] for k in
               ("flops", "bytes_accessed", "collective_bytes")}
    else:
        est = extrapolated_costs(cfg, shape, mesh, rules)
    roof = roofline(cfg, shape, est, full, n_chips)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "n_chips": n_chips, "full_compile": full, "extrapolated": est,
           "roofline": roof}
    json.dump(rec, open(path, "w"), indent=1)
    print(f"  flops/dev={est['flops']:.3e} bytes/dev={est['bytes_accessed']:.3e} "
          f"coll/dev={sum(est['collective_bytes'].values()):.3e} "
          f"dom={roof['dominant']} mem={roof['mem_per_device_gib']:.2f}GiB "
          f"(compile {full['t_compile_s']}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--skip-probes", action="store_true",
                    help="full compile only (multi-pod lowering proof)")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else registry.ARCH_IDS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_combo(arch, shape, mp, args.outdir,
                              skip_probes=args.skip_probes or mp)
                except Exception as e:  # noqa: BLE001 — report and continue
                    print(f"[FAIL] {arch} {shape} mp={mp}: {type(e).__name__}: {e}",
                          flush=True)


if __name__ == "__main__":
    main()

"""AdamW with fp32 moments and optional global-norm clipping."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100


def init(params):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.lr * warm


def update(grads, state, params, cfg: AdamWConfig):
    step = state["step"] + 1
    if cfg.clip_norm:
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    else:
        gnorm = jnp.zeros(())
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state["mu"], grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state["nu"], grads)
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t
    lr = schedule(cfg, step)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "step": step}, gnorm

from .pipeline import SyntheticTextDataset, make_batch_specs

"""Deterministic synthetic data pipeline.

Produces seeded token streams with Zipfian unigram statistics plus short
copy motifs (so a ~100M model shows a real, reproducible loss drop within a
few hundred steps). Shard-aware: each data-parallel host pulls its own slice
by (step, shard) without coordination.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp


@dataclass
class SyntheticTextDataset:
    vocab: int
    seq_len: int
    batch: int          # per-host batch
    seed: int = 0
    n_shards: int = 1
    shard: int = 0

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard)

    def batch_at(self, step: int) -> dict:
        rng = self._rng(step)
        V = self.vocab
        # Zipf-ish unigram distribution over the first 4k tokens
        support = min(V, 4096)
        ranks = np.arange(1, support + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(support, size=(self.batch, self.seq_len + 1),
                          p=probs).astype(np.int32)
        # motif: periodic copy pattern gives learnable structure
        period = 8
        toks[:, period::period] = toks[:, ::period][:, : toks[:, period::period].shape[1]]
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_specs(cfg, shape, abstract=True):
    """ShapeDtypeStruct batch for (cfg, InputShape) — see launch.inputs."""
    from repro.launch.inputs import input_specs
    return input_specs(cfg, shape)

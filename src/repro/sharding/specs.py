"""Logical-axis sharding: rules mapping logical tensor axes to mesh axes.

Model code annotates activations/params with *logical* axis names
("batch", "embed", "heads", ...). A ``ShardingRules`` table maps those to
mesh axes; ``logical_to_spec`` builds a PartitionSpec; ``constrain`` applies
``with_sharding_constraint`` when a mesh is active (no-op otherwise, so the
same model code runs in single-device smoke tests, GraphGuard capture, and
512-chip dry-runs).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field, replace
from typing import Optional, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


Axis = Union[None, str, tuple]


@dataclass(frozen=True)
class ShardingRules:
    """logical axis -> mesh axis (or tuple of mesh axes, or None)."""
    rules: dict

    def spec_for(self, logical_axes: tuple) -> P:
        entries = []
        for ax in logical_axes:
            if ax is None:
                entries.append(None)
            else:
                entries.append(self.rules.get(ax))
        return P(*entries)

    def with_(self, **updates) -> "ShardingRules":
        d = dict(self.rules)
        d.update(updates)
        return ShardingRules(d)


# The baseline production plan: data-parallel batch over (pod, data),
# tensor-parallel model dims over model; parameters ZeRO/FSDP-sharded over
# data on their non-tensor dim ("embed_fsdp" is used for *parameters only*).
def default_rules(multi_pod: bool = False, fsdp: bool = True) -> ShardingRules:
    data_axes = ("pod", "data") if multi_pod else ("data",)
    return ShardingRules({
        "batch": data_axes,
        "seq": None,
        "embed": None,
        "embed_fsdp": "data" if fsdp else None,   # parameter-only dim
        "heads": "model",
        "kv_heads": "model",
        "qheads": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "expert_ff": None,
        "expert_fsdp": "data" if fsdp else None,
        "act_ff": "model",       # activation hidden dim (TP)
        "act_heads": "model",    # activation heads dim (TP)
        "layers": None,
        "state": None,
        "kv_seq": None,
        "conv": None,
    })


# ---------------------------------------------------------------------------
# Mesh plans (modelcheck): a named mesh + logical-axis rules in one object
# ---------------------------------------------------------------------------

# Logical-axis rules for the whole-model verification plans: batch over the
# data axis, tensor dims (heads / ff / vocab / experts) over the model axis,
# parameters unsharded on their embed dim (pure Megatron TP — no ZeRO, so
# block programs need no weight gathers).  ``embed_tp`` is the embedding
# table's feature dim: sharding it (rather than vocab) keeps the gather
# local and assembles the activation with one all_gather, staying inside
# the lemma fragment (vocab-parallel embedding needs a value-dependent
# masked gather, which no symbolic engine can verify).
def plan_rules(axes: dict) -> ShardingRules:
    dp = "dp" if "dp" in axes else None
    tp = "tp" if "tp" in axes else None
    return ShardingRules({
        "batch": dp,
        "seq": None,
        "embed": None,
        "embed_fsdp": None,
        "embed_tp": tp,
        "vocab_rows": None,  # embedding-table rows (gather stays local)
        "heads": tp,
        "kv_heads": tp,
        "ff": tp,
        "vocab": tp,
        "experts": tp,
        "act_ff": tp,
        "act_heads": tp,
        "layers": None,
    })


@dataclass(frozen=True)
class MeshPlan:
    """A named sharding plan: ordered mesh axes + logical-axis rules.

    ``repro.modelcheck`` derives every obligation's ``in_specs`` (and thus
    R_i) from the plan: parameter/activation leaf specs carry *logical*
    axis names and ``spec_for`` maps them through the rules."""
    name: str
    axes: tuple                          # (("dp", 2), ("tp", 2)) — ordered
    rules: ShardingRules

    @property
    def mesh_axes(self) -> dict:
        return dict(self.axes)

    @property
    def degree(self) -> tuple:
        return tuple(s for _, s in self.axes)

    def axis(self, name: str) -> int:
        return self.mesh_axes.get(name, 1)

    def spec_for(self, logical_axes: tuple) -> P:
        return self.rules.spec_for(tuple(logical_axes))


PLAN_AXES = ("dp", "tp")


def parse_plan(token: str) -> MeshPlan:
    """Parse a plan token like ``dp2``, ``tp4`` or ``dp2xtp2`` into a
    :class:`MeshPlan` (axis order is as written; sizes must be >= 2 — an
    absent axis is simply not in the mesh)."""
    import re
    axes = []
    for part in str(token).split("x"):
        m = re.fullmatch(r"([a-z]+)(\d+)", part)
        if not m or m.group(1) not in PLAN_AXES:
            raise ValueError(
                f"bad plan {token!r} — expected parts like `dp2`/`tp4` "
                f"joined by `x` (axes: {PLAN_AXES})")
        name, size = m.group(1), int(m.group(2))
        if size < 2:
            raise ValueError(f"bad plan {token!r}: axis {name} needs "
                             f"size >= 2 (drop the axis instead of size 1)")
        if any(a == name for a, _ in axes):
            raise ValueError(f"bad plan {token!r}: duplicate axis {name}")
        axes.append((name, size))
    if not axes:
        raise ValueError(f"bad plan {token!r}: no mesh axes")
    axes = tuple(axes)
    return MeshPlan(token, axes, plan_rules(dict(axes)))


# The named plans the modelcheck CLI/benchmarks sweep by default.  tp4 parses
# but is a documented scale limit (the 4-wide psum chains hit the same
# assoc/comm blowup as tp_dp_2d@(4,4) — see EXPERIMENTS.md §Gaps).
DEFAULT_PLANS = ("dp2", "tp2", "dp2xtp2", "dp4")


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[ShardingRules] = None


_ctx = _Ctx()


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[ShardingRules]):
    prev = (_ctx.mesh, _ctx.rules)
    _ctx.mesh, _ctx.rules = mesh, rules
    try:
        yield
    finally:
        _ctx.mesh, _ctx.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _ctx.mesh


def constrain(x, logical_axes: tuple):
    """Apply a sharding constraint if a mesh is active; identity otherwise."""
    if _ctx.mesh is None or _ctx.rules is None:
        return x
    spec = _ctx.rules.spec_for(logical_axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ctx.mesh, spec))


def _is_axes_leaf(x):
    """A logical-axes leaf is a tuple of axis names / None — NOT a tuple of
    tuples (e.g. a (k, v) cache pair), which is tree structure."""
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def tree_shardings(mesh: Mesh, rules: ShardingRules, logical_tree):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, rules.spec_for(axes)),
        logical_tree, is_leaf=_is_axes_leaf)

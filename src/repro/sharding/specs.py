"""Logical-axis sharding: rules mapping logical tensor axes to mesh axes.

Model code annotates activations/params with *logical* axis names
("batch", "embed", "heads", ...). A ``ShardingRules`` table maps those to
mesh axes; ``logical_to_spec`` builds a PartitionSpec; ``constrain`` applies
``with_sharding_constraint`` when a mesh is active (no-op otherwise, so the
same model code runs in single-device smoke tests, GraphGuard capture, and
512-chip dry-runs).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field, replace
from typing import Optional, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


Axis = Union[None, str, tuple]


@dataclass(frozen=True)
class ShardingRules:
    """logical axis -> mesh axis (or tuple of mesh axes, or None)."""
    rules: dict

    def spec_for(self, logical_axes: tuple) -> P:
        entries = []
        for ax in logical_axes:
            if ax is None:
                entries.append(None)
            else:
                entries.append(self.rules.get(ax))
        return P(*entries)

    def with_(self, **updates) -> "ShardingRules":
        d = dict(self.rules)
        d.update(updates)
        return ShardingRules(d)


# The baseline production plan: data-parallel batch over (pod, data),
# tensor-parallel model dims over model; parameters ZeRO/FSDP-sharded over
# data on their non-tensor dim ("embed_fsdp" is used for *parameters only*).
def default_rules(multi_pod: bool = False, fsdp: bool = True) -> ShardingRules:
    data_axes = ("pod", "data") if multi_pod else ("data",)
    return ShardingRules({
        "batch": data_axes,
        "seq": None,
        "embed": None,
        "embed_fsdp": "data" if fsdp else None,   # parameter-only dim
        "heads": "model",
        "kv_heads": "model",
        "qheads": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "expert_ff": None,
        "expert_fsdp": "data" if fsdp else None,
        "act_ff": "model",       # activation hidden dim (TP)
        "act_heads": "model",    # activation heads dim (TP)
        "layers": None,
        "state": None,
        "kv_seq": None,
        "conv": None,
    })


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[ShardingRules] = None


_ctx = _Ctx()


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[ShardingRules]):
    prev = (_ctx.mesh, _ctx.rules)
    _ctx.mesh, _ctx.rules = mesh, rules
    try:
        yield
    finally:
        _ctx.mesh, _ctx.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _ctx.mesh


def constrain(x, logical_axes: tuple):
    """Apply a sharding constraint if a mesh is active; identity otherwise."""
    if _ctx.mesh is None or _ctx.rules is None:
        return x
    spec = _ctx.rules.spec_for(logical_axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ctx.mesh, spec))


def _is_axes_leaf(x):
    """A logical-axes leaf is a tuple of axis names / None — NOT a tuple of
    tuples (e.g. a (k, v) cache pair), which is tree structure."""
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def tree_shardings(mesh: Mesh, rules: ShardingRules, logical_tree):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, rules.spec_for(axes)),
        logical_tree, is_leaf=_is_axes_leaf)

from .specs import (ShardingRules, default_rules, use_sharding, constrain,
                    tree_shardings, active_mesh)

"""Training step: CE loss (+ MoE aux), grad accumulation, AdamW."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..models import registry
from ..models.config import ModelConfig
from ..optim import adamw
from ..optim.adamw import AdamWConfig


@dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1          # gradient accumulation steps
    z_loss: float = 0.0


CE_CHUNKS = 8   # sequence-chunked vocab-parallel CE (bounds logits memory)


def _ce_piece(cfg, tcfg, w, xc, lc):
    """CE over one sequence chunk; logits never materialize for full S."""
    logits = (xc @ w.astype(xc.dtype)).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / 30.0) * 30.0
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(
        logits, jnp.clip(lc, 0)[..., None], axis=-1)[..., 0]
    mask = (lc >= 0).astype(jnp.float32)
    nll = -((tgt - lse) * mask).sum()
    z = jnp.square(lse * mask).sum() if tcfg.z_loss else jnp.zeros(())
    return nll, mask.sum(), z


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    def loss_fn(params, batch):
        hidden, extras = registry.forward(params, cfg, batch,
                                          return_hidden=True)
        labels = batch["labels"]
        # VLM: hidden covers [vision tokens ; text tokens]; labels are padded
        # with ignore (-1) on the vision prefix by the pipeline/input spec.
        B, S, D = hidden.shape
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        piece = jax.checkpoint(partial(_ce_piece, cfg, tcfg, w))
        c = S // CE_CHUNKS if S % CE_CHUNKS == 0 and S >= CE_CHUNKS else S
        nll = cnt = zacc = 0.0
        for i in range(0, S, c):
            n_, c_, z_ = piece(hidden[:, i:i + c], labels[:, i:i + c])
            nll, cnt, zacc = nll + n_, cnt + c_, zacc + z_
        loss = nll / jnp.maximum(cnt, 1.0)
        if tcfg.z_loss:
            loss = loss + tcfg.z_loss * zacc / jnp.maximum(cnt, 1.0)
        metrics = {"ce_loss": loss}
        if extras and "aux_loss" in extras:
            loss = loss + extras["aux_loss"]
            metrics["aux_loss"] = extras["aux_loss"]
        metrics["loss"] = loss
        return loss, metrics
    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With tcfg.microbatches > 1, the batch's leading dim is split and
    gradients are accumulated (the strategy verified in paper bug #6 — the
    accumulated loss must be scaled by 1/n_microbatches)."""
    loss_fn = make_loss_fn(cfg, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    def accumulate(params, batch):
        n = tcfg.microbatches
        micro = jax.tree.map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)

        def body(acc, mb):
            grads, metrics = single(params, mb)
            # paper bug #6: this 1/n scaling is what buggy impls forget
            acc = jax.tree.map(lambda a, g: a + g / n, acc, grads)
            return acc, metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        grads, metrics = jax.lax.scan(body, zeros, micro)
        metrics = jax.tree.map(lambda m: m.mean(), metrics)
        return grads, metrics

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            grads, metrics = accumulate(params, batch)
        else:
            grads, metrics = single(params, batch)
        params, opt_state, gnorm = adamw.update(grads, opt_state, params,
                                                tcfg.optimizer)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step


def init_state(cfg: ModelConfig, rng):
    params = registry.init_params(cfg, rng)
    opt_state = adamw.init(params)
    return params, opt_state

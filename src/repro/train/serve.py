"""Serving: prefill + batched single-token decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import registry
from ..models.config import ModelConfig


def prefill_logits(params, cfg: ModelConfig, batch: dict):
    """Parallel prefill compute (the cost profile of the prefill_32k shape)."""
    logits, _ = registry.forward(params, cfg, batch)
    return logits


def sequential_prefill(params, cfg: ModelConfig, tokens, max_seq: int,
                       frames=None):
    """Build a KV cache by scanning decode_step over the prompt (universal
    across families; used by the serving example at small scale).

    ``frames`` (encoder-decoder only): encoder input; the per-layer cross
    K/V is precomputed into the cache, as decode_step expects.
    """
    B, S = tokens.shape
    cache = registry.init_cache(cfg, B, max_seq)
    if frames is not None:
        from ..models import encdec
        ck, cv = encdec.build_cross_cache(
            params, cfg, encdec.encode(params, cfg, frames))
        cache = dict(cache, cross_k=ck, cross_v=cv)

    def body(carry, i):
        cache = carry
        logits, cache = registry.decode_step(
            params, cfg, cache, jax.lax.dynamic_slice(tokens, (0, i), (B, 1)),
            i)
        return cache, logits[:, 0]

    cache, logits = jax.lax.scan(body, cache, jnp.arange(S))
    return cache, jnp.swapaxes(logits, 0, 1)   # (B, S, V)


def decode_tokens(params, cfg: ModelConfig, cache, last_token, start_pos,
                  n_steps: int, temperature: float = 0.0, rng=None):
    """Greedy (or sampled) generation of n_steps tokens."""
    B = last_token.shape[0]

    def body(carry, i):
        cache, tok, rng_ = carry
        logits, cache = registry.decode_step(params, cfg, cache, tok,
                                             start_pos + i)
        logits = logits[:, 0]
        if temperature > 0.0:
            rng_, sub = jax.random.split(rng_)
            nxt = jax.random.categorical(sub, logits / temperature)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = nxt.astype(jnp.int32)[:, None]
        return (cache, nxt, rng_), nxt[:, 0]

    rng = rng if rng is not None else jax.random.PRNGKey(0)
    (cache, _, _), toks = jax.lax.scan(
        body, (cache, last_token, rng), jnp.arange(n_steps))
    return cache, jnp.swapaxes(toks, 0, 1)     # (B, n_steps)

from .loop import make_train_step, make_loss_fn, TrainConfig
from . import serve

"""Gradient relations by *transposing* forward relations.

The forward input relation R_i is derived from each input's
``PartitionSpec`` (``derive_input_relation``).  The gradient side needs no
new derivation machinery — gradient relations are the forward relations
*transposed*, in the AD sense (the backward map is the linear transpose of
the forward map):

  * a dim sharded over mesh axis ``a`` (forward: global = concat of
    shards) transposes to a gradient sharded the same way — the
    post-collective gradient relation is the *same* nested concat;
  * an axis the parameter is replicated over while the loss data is
    sharded over it (forward: broadcast onto the ranks) transposes to a
    cross-rank *sum* — the implementation owes a ``psum`` over that axis
    before its gradient equals the sequential one;
  * an axis the parameter is sharded over while the backward partials are
    computed rank-locally (ZeRO) transposes to ``reduce_scatter``: sum
    over the group, keep your shard.

``grad_collective`` names the collective a strategy owes per parameter;
``expected_grad_relation`` builds the clean Term the inferred R_o must
equal once that collective ran (the gradcheck seam check, mirroring
``modelcheck.stitch.expected_output_relation``).
"""
from __future__ import annotations

import itertools
from typing import Tuple

from ..core.capture import Graph, derive_input_relation


def _spec_axes(spec) -> Tuple[str, ...]:
    """Mesh axes a PartitionSpec shards over (flattened, ordered)."""
    out = []
    for entry in tuple(spec) if spec is not None else ():
        if entry is None:
            continue
        for a in (entry if isinstance(entry, tuple) else (entry,)):
            out.append(a)
    return tuple(out)


def grad_collective(param_spec, data_spec, mesh_axes: dict
                    ) -> Tuple[str, Tuple[str, ...]]:
    """The collective the parameter gradient owes, by transposition.

    Returns ``(kind, axes)`` with ``kind`` one of:

      ``"identity"``        nothing owed — every reduction axis of the loss
                            is already local (fully-sharded parameter whose
                            partials are rank-exact)
      ``"psum"``            all-reduce over ``axes`` (replicated parameter,
                            data sharded over those axes)
      ``"reduce_scatter"``  sum over ``axes`` then keep the local shard
                            (ZeRO: the parameter itself is sharded over the
                            same axes the backward partial-sums over)
    """
    p_axes = set(_spec_axes(param_spec))
    d_axes = set(_spec_axes(data_spec))
    # axes the backward partial-sums over: every axis the loss data is
    # sharded over (each rank sees a batch shard, so its local gradient is
    # a partial sum), plus replicated-compute axes contribute nothing.
    reduce_axes = tuple(a for a in mesh_axes if a in d_axes)
    if not reduce_axes:
        return "identity", ()
    if p_axes & set(reduce_axes):
        return "reduce_scatter", reduce_axes
    return "psum", reduce_axes


def expected_grad_relation(base_name: str, local_shape, dtype: str,
                           param_spec, mesh_axes: dict):
    """The clean Term the parameter's inferred gradient R_o must equal.

    By transposition the *post-collective* gradient is sharded exactly
    like the parameter, so the expected relation is the same nested
    concat the forward spec induces (replica coordinate 0 on unsharded
    axes — the engine's deterministic extraction makes the same choice).
    """
    axis_names = tuple(mesh_axes)
    sizes = tuple(mesh_axes[a] for a in axis_names)
    coords = list(itertools.product(*[range(s) for s in sizes]))
    g = Graph([base_name], [], [], {base_name: tuple(local_shape)},
              {base_name: dtype})
    r = derive_input_relation(g, [param_spec], axis_names, sizes, coords)
    return r[base_name][0]

"""Scheduler: fan per-parameter gradient obligations across the runtime.

``check_train`` is the subsystem entry point.  Parameter obligations are
verified in-process or on a supervised spawn pool (:mod:`repro.runtime`)
— workers receive only picklable ``(strategy, degree, bug, param)``
tuples and rebuild the obligation from the deterministic registry, so
nothing unpicklable crosses the boundary and certificates stay
byte-identical for any worker count.  ``timeout_s`` budgets each
parameter obligation individually from the moment it starts on a worker;
``cache=`` attaches the persistent certificate cache keyed per
(strategy spec, parameter).
"""
from __future__ import annotations

import time
from functools import partial
from typing import Dict, List, Optional, Tuple

from ..api.report import Report
from ..api.runner import _engine_opts
from ..api.spec import Degree, StrategySpec, task_id
from ..core import RefinementError, check_refinement, expand_spmd
from ..core.capture import capture
from ..core.explain import aggregate_explanations
from ..core.terms import pretty
from ..runtime import (RuntimeTask, pool_stats, resolve_cache, run_tasks,
                       strategy_cache_key)
from .capture_grad import capture_grad_spmd
from .obligations import get_train_strategy
from .report import ParamResult, TrainReport
from .transpose import expected_grad_relation, grad_collective

DEFAULT_TIMEOUT_S = 600.0


def _verify_param(spec: StrategySpec, param: str,
                  engine_opts: Optional[dict] = None) -> dict:
    """Verify one parameter's gradient obligation; returns a JSON-ready
    nested Report dict with the transposition seam (inferred R_o vs the
    relation the parameter's PartitionSpec transposes to) attached."""
    # by convention the loss-data (batch) input is the obligation's first
    # input — see register_train_strategy; its sharding determines which
    # axes the local backward partial-sums over.  A custom strategy whose
    # parameter is not an input degrades to an unknown collective rather
    # than crashing the scheduler.
    try:
        i = spec.input_names.index(param)
        collective, axes = grad_collective(spec.in_specs[i],
                                           spec.in_specs[0], spec.mesh_axes)
        coll = collective if not axes else f"{collective}({','.join(axes)})"
        param_spec = spec.in_specs[i]
    except ValueError:
        coll, param_spec = "?", None
    t0 = time.perf_counter()
    try:
        with _engine_opts(engine_opts) as eo:
            # seq_fn is already grad_of(loss, param) — the sequential
            # backward graph; the dist side traces the per-rank backward
            # + collectives under shard_map
            gs = capture(spec.seq_fn, list(spec.avals),
                         list(spec.input_names))
            cap = capture_grad_spmd(spec.dist_fn, spec.mesh_axes,
                                    spec.in_specs, spec.avals,
                                    spec.input_names)
            gd, r_i = expand_spmd(cap)
            cert = check_refinement(gs, gd, r_i, max_nodes=eo.max_nodes,
                                    explain=eo.explain)
    except RefinementError as e:
        d = Report(
            case=spec.name, degree=spec.degree, bug=spec.bug,
            verdict="refinement_error", expected=spec.expected,
            ok=spec.expected == "refinement_error", localization=e.payload(),
            explanation=getattr(e, "explanation", None),
            wall_s=round(time.perf_counter() - t0, 6)).to_json()
        d["collective"] = coll
        return d
    except Exception as e:  # noqa: BLE001 — capture/engine failure -> verdict
        d = Report(
            case=spec.name, degree=spec.degree, bug=spec.bug,
            verdict="error", expected=spec.expected, ok=False,
            error=f"{type(e).__name__}: {e}",
            wall_s=round(time.perf_counter() - t0, 6)).to_json()
        d["collective"] = coll
        return d

    # transposition seam: the inferred gradient relation must equal the
    # one the parameter's PartitionSpec transposes to (skipped when the
    # parameter is not an input — no spec to transpose)
    if param_spec is not None:
        gd_out = gd.outputs[0]
        expect = expected_grad_relation(
            gd_out.split("@")[0], gd.shapes[gd_out], gd.dtypes[gd_out],
            param_spec, spec.mesh_axes)
        got = next(iter(cert.r_o.values()), None)
        relation_ok = got is expect      # Terms are hash-consed: identity
    else:
        expect, got, relation_ok = None, None, True
    cert_json = cert.to_json()
    d = Report(
        case=spec.name, degree=spec.degree, bug=spec.bug,
        verdict="certificate", expected=spec.expected,
        ok=spec.expected == "certificate" and relation_ok,
        r_o=cert_json["r_o"], stats=cert_json["stats"],
        explanation=cert.explanation,
        wall_s=round(time.perf_counter() - t0, 6)).to_json()
    d["collective"] = coll
    d["relation"] = {
        "ok": relation_ok,
        "expected": None if expect is None else pretty(expect, 999),
        "got": None if got is None else pretty(got, 999)}
    return d


def _pool_task(strategy: str, degree: Degree, bug: Optional[str],
               param: str, engine_opts: Optional[dict]) -> dict:
    """Pool worker: rebuild the obligation by name and verify it."""
    spec = get_train_strategy(strategy).build(degree=degree, bug=bug)[param]
    return _verify_param(spec, param, engine_opts)


def _outcome_report(spec: StrategySpec, outcome) -> dict:
    """Convert a runtime outcome into this parameter's report dict."""
    if outcome.ok:
        d = dict(outcome.value)
        info = outcome.runtime_info()
        if info:
            d["runtime"] = info
        return d
    verdict = "timeout" if outcome.status == "timeout" else "error"
    d = Report(
        case=spec.name, degree=spec.degree, bug=spec.bug,
        verdict=verdict, expected=spec.expected, ok=False,
        error=outcome.error, wall_s=round(outcome.wall_s, 6),
        runtime=outcome.runtime_info() or None).to_json()
    d["collective"] = "?"
    return d


def run_train_obligations(strategy: str, degree: Degree,
                          bug: Optional[str] = None,
                          workers: Optional[int] = None,
                          engine_opts: Optional[dict] = None,
                          timeout_s: float = DEFAULT_TIMEOUT_S,
                          cache=None
                          ) -> Tuple[Dict[str, dict], int, Optional[dict],
                                     dict]:
    """Verify every parameter obligation.

    Returns ``({param: report dict}, workers actually used, cache stats
    or None, runtime pool stats)``.  ``timeout_s`` budgets each parameter
    obligation individually; ``cache`` takes anything
    :func:`repro.runtime.resolve_cache` accepts.
    """
    entry = get_train_strategy(strategy)
    specs = entry.build(degree=degree, bug=bug)
    params = list(specs)
    if workers is None:
        # sub-second obligations, small count: in-process beats pool spin-up
        workers = min(4, len(params)) if len(params) > 4 else 1
    cache = resolve_cache(cache)
    base = f"train@{task_id(strategy, degree, bug)}"
    tasks = []
    for param in params:
        spec = specs[param]
        # the per-parameter specs share name/mesh/inputs (they differ in
        # the traced grad fn, which is not hashable) — the parameter name
        # must be part of the cache identity
        cache_key = None if cache is None else \
            f"{strategy_cache_key(spec, engine_opts)}:grad-{param}"
        tasks.append(RuntimeTask(
            key=f"{base}:{param}", fn=_pool_task,
            args=(strategy, degree, bug, param, engine_opts),
            budget_s=timeout_s, cache_key=cache_key,
            local_fn=partial(_verify_param, spec, param, engine_opts)))
    used = min(workers, len(params)) or 1
    # spawn, not fork: the parent has traced jax by now (see modelcheck)
    outcomes = run_tasks(tasks, used, mp_method="spawn", cache=cache)
    reports = {param: _outcome_report(specs[param],
                                      outcomes[f"{base}:{param}"])
               for param in params}
    cache_stats = None if cache is None else {
        "dir": cache.dir,
        "hits": sum(1 for o in outcomes.values() if o.cache == "hit"),
        "misses": sum(1 for o in outcomes.values() if o.cache == "miss"),
        "entries": len(cache),
        "recovered_corrupt": cache.recovered_corrupt}
    return reports, used, cache_stats, pool_stats(outcomes)


def check_train(strategy: str, *, degree: Optional[Degree] = None,
                bug: Optional[str] = None, workers: Optional[int] = None,
                engine_opts: Optional[dict] = None,
                timeout_s: float = DEFAULT_TIMEOUT_S,
                cache=None) -> TrainReport:
    """Train-step refinement check: one obligation per parameter, stitched.

    Returns a :class:`TrainReport`; never raises on verification failures
    (they become parameter verdicts) — only on caller mistakes (unknown
    strategy / bug / degree).  ``cache`` attaches the persistent
    certificate cache (see :func:`repro.runtime.resolve_cache`).
    """
    t0 = time.perf_counter()
    entry = get_train_strategy(strategy)
    if degree is None:
        degree = entry.degrees[0]
    degree = entry.validate_degree(degree)
    if bug is not None and bug not in entry.bug_names():
        raise ValueError(
            f"bug `{bug}` is not hosted by train strategy `{strategy}` "
            f"(hosted: {sorted(entry.bug_names()) or '-'})")
    reports, used, cache_stats, pstats = run_train_obligations(
        strategy, degree, bug=bug, workers=workers,
        engine_opts=engine_opts, timeout_s=timeout_s, cache=cache)

    params: List[ParamResult] = []
    failing: List[str] = []
    for param in entry.params:
        rep = reports[param]
        rel = rep.get("relation") or {}
        relation_ok = bool(rel.get("ok")) if rel else \
            rep["verdict"] == "certificate"
        loc = rep.get("localization") or {}
        params.append(ParamResult(
            param=param, verdict=rep["verdict"], relation_ok=relation_ok,
            collective=rep.get("collective", "?"),
            localized_op=loc.get("op_name")))
        if rep["verdict"] != "certificate" or not relation_ok:
            failing.append(param)

    verdicts = {p.verdict for p in params}
    if verdicts & {"error", "timeout"}:
        verdict = "error"
    elif "refinement_error" in verdicts:
        verdict = "refinement_error"
    elif any(not p.relation_ok for p in params):
        verdict = "unexpected_relation"
    else:
        verdict = "certificate"

    bug_param = entry.bug_params.get(bug) if bug else None
    if bug is None:
        ok = verdict == "certificate"
    else:
        # the injected gradient bug must surface the way its BugSpec
        # declares (refinement_error raise, or unexpected_relation via
        # the transposition seam) AND localize to exactly its parameter
        ok = (verdict == entry.bug_spec(bug).expected
              and failing == [bug_param])

    return TrainReport(
        strategy=strategy, degree=degree, verdict=verdict, ok=ok,
        params=params, reports=dict(reports), failing_params=failing,
        bug=bug, bug_param=bug_param,
        wall_s=round(time.perf_counter() - t0, 6), workers=used,
        cache=cache_stats, pool=pstats,
        explanation=aggregate_explanations(reports))

"""TrainReport: per-parameter gradient verdicts stitched into one verdict.

Mirrors :class:`repro.modelcheck.ModelReport` one level down: where the
model report nests per-*block* obligations, the train report nests one
:class:`repro.api.Report` per *parameter* of the training step, plus the
transposition seam — the inferred gradient R_o must equal the relation
``expected_grad_relation`` derives from the parameter's PartitionSpec.
A bug run is ``ok`` only when the failure localizes to exactly the
injected parameter (every other parameter must stay clean).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, fields
from typing import Dict, List, Optional

from ..api.spec import Degree, degree_token, normalize_degree

TRAIN_REPORT_SCHEMA = 1

VERDICTS = ("certificate", "refinement_error", "unexpected_relation",
            "error")


@dataclass
class ParamResult:
    """One parameter's gradient-obligation outcome."""
    param: str                   # "w1" | "w2" | ...
    verdict: str                 # nested report's verdict
    relation_ok: bool            # inferred R_o == transposed expectation
    collective: str              # owed collective: psum/reduce_scatter/...
    localized_op: Optional[str] = None   # failing G_s operator, if any

    def to_json(self) -> dict:
        return {f.name: getattr(self, f.name) for f in fields(self)}


@dataclass
class TrainReport:
    """Train-step refinement verdict for (strategy, degree[, bug])."""
    strategy: str
    degree: Degree
    verdict: str                         # one of VERDICTS
    ok: bool                             # matches the run's expectation
    params: List[ParamResult]
    reports: Dict[str, dict]             # param -> nested Report JSON
                                         # (+ "relation" detail)
    failing_params: List[str] = field(default_factory=list)
    bug: Optional[str] = None
    bug_param: Optional[str] = None      # the parameter the bug targets
    wall_s: float = 0.0
    workers: int = 0
    cache: Optional[dict] = None         # persistent-cache stats (hits,
                                         # misses, entries) — timing-class
                                         # data, never in stable_summary
    pool: Optional[dict] = None          # runtime pool_stats() aggregate
                                         # (queue-wait vs on-worker wall)
                                         # — timing-class data, never in
                                         # stable_summary
    explanation: Optional[dict] = None   # proof-provenance roll-up
                                         # (``--explain`` only); omitted
                                         # from to_json when absent, never
                                         # in stable_summary
    schema_version: int = TRAIN_REPORT_SCHEMA

    def __post_init__(self):
        self.degree = normalize_degree(self.degree)
        if self.verdict not in VERDICTS:
            raise ValueError(f"verdict must be one of {VERDICTS}, "
                             f"got {self.verdict!r}")

    def task_id(self) -> str:
        base = f"train@{self.strategy}@deg{degree_token(self.degree)}"
        return f"{base}+{self.bug}" if self.bug else base

    # -- serialization ------------------------------------------------------
    def to_json(self) -> dict:
        out = {f.name: getattr(self, f.name) for f in fields(self)
               if f.name != "params"}
        if out.get("explanation") is None:
            out.pop("explanation")
        out["params"] = [p.to_json() for p in self.params]
        out["timing"] = self.timing()
        return out

    @classmethod
    def from_json(cls, d: dict) -> "TrainReport":
        allowed = {f.name for f in fields(cls)}
        kw = {k: v for k, v in d.items() if k in allowed}
        kw["params"] = [ParamResult(**p) for p in d.get("params", ())]
        return cls(**kw)

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    # -- views --------------------------------------------------------------
    def timing(self) -> dict:
        """Per-phase wall time aggregated over the parameter obligations."""
        phases: Dict[str, float] = {}
        infer_s = 0.0
        for rep in self.reports.values():
            stats = rep.get("stats") or {}
            infer_s += float(stats.get("time_s", 0.0))
            for k, v in (stats.get("phase_s") or {}).items():
                phases[k] = phases.get(k, 0.0) + float(v)
        return {
            "wall_s": round(self.wall_s, 6),
            "infer_s_sum": round(infer_s, 6),
            "phase_s_sum": {k: round(v, 6)
                            for k, v in sorted(phases.items())},
        }

    def stable_summary(self) -> dict:
        """Deterministic fields only — golden-diff material."""
        return {
            "verdict": self.verdict,
            "ok": self.ok,
            "failing_params": list(self.failing_params),
            "params": [{"param": p.param, "verdict": p.verdict,
                        "relation_ok": p.relation_ok,
                        "collective": p.collective}
                       for p in self.params],
        }

    def to_markdown(self) -> str:
        lines = [
            f"### train@{self.strategy} @ deg{degree_token(self.degree)}"
            + (f" (bug={self.bug}@{self.bug_param})" if self.bug else ""),
            "",
            "| param | collective | verdict | relation | localized op |",
            "|-------|------------|---------|----------|--------------|",
        ]
        for p in self.params:
            lines.append(
                f"| {p.param} | {p.collective} | {p.verdict} "
                f"| {'ok' if p.relation_ok else '**MISMATCH**'} "
                f"| {p.localized_op or '-'} |")
        lines.append("")
        lines.append(
            f"**{self.verdict}** — {len(self.params)} parameter "
            f"gradient(s) checked in {self.wall_s:.2f}s.")
        if self.failing_params:
            lines.append(f"Failing parameters: {self.failing_params}.")
        return "\n".join(lines)

"""Train-step strategies and their per-parameter gradient obligations.

Each strategy models one real distributed-training recipe for the shared
two-matmul step (``loss = sum(tanh(x @ w1) @ w2)``, the Megatron MLP
fragment every family in this repo builds on):

  ``dp``        DDP: batch sharded, parameters replicated, local backward
                + gradient ``psum`` (the transposition of the replicated
                forward broadcast).
  ``dp_accum``  DDP with microbatch gradient accumulation into a
                ``dynamic_update_slice`` scatter buffer — the HF-regression
                pattern; certifies through the ``dus_concat`` lemma.
  ``fsdp``      ZeRO-3: parameters sharded dim 0, forward ``all_gather``,
                gradient ``reduce_scatter`` (transpose of the gather).
  ``tp_dp_2d``  Megatron TP x DP on a 2D mesh: col/row-sharded weights,
                batch sharded over dp; each weight gradient owes a ``psum``
                over *dp only* (the tp shard is exact by transposition).

A strategy yields one obligation per parameter — a plain
:class:`repro.api.StrategySpec` whose seq side is ``jax.grad`` of the
sequential loss and whose dist side is the per-rank local backward wrapped
in the strategy's collectives — so the unchanged engine verifies it and a
failure localizes to *that parameter*.

The three injected bug classes are the gradient analogues of the
bug-study literature (TTrace; the LLM-framework bug study — PAPERS.md):

  ``accum_no_rescale``     (dp_accum/w2) the accumulated gradient is
                           normalized by the microbatch size instead of
                           the global batch — grads come out n_steps x
                           too large.
  ``stale_grad_shard``     (fsdp/w2) the ``reduce_scatter`` is skipped and
                           the rank keeps its *local partial*'s shard —
                           the stale-shard ZeRO class.
  ``grad_psum_wrong_axis`` (tp_dp_2d/w2) the gradient all-reduce runs
                           over tp instead of dp — partial batch sums are
                           never combined, tp shards are wrongly summed.

All bugs target ``w2`` (and only ``w2``), so detection must localize to
exactly that parameter — ``w1`` staying clean is part of the check.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..api.spec import BugSpec, Degree, StrategySpec, axis_degrees, \
    normalize_degree
from .capture_grad import grad_of

# shared train-step fragment sizes (symbolic engine: cost is op count x
# degree, not extents — keep them divisibility-friendly)
BATCH, D_MODEL, D_FF = 8, 4, 4
N_MICRO = 2
PARAMS = ("w1", "w2")
_ARGNUM = {"w1": 1, "w2": 2}


def _loss(x, w1, w2):
    return jnp.sum(jnp.tanh(x @ w1) @ w2)


def _aval(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


_AVALS = (_aval((BATCH, D_MODEL)), _aval((D_MODEL, D_FF)),
          _aval((D_FF, D_MODEL)))
_NAMES = ("x", "w1", "w2")


@dataclass(frozen=True)
class TrainStrategy:
    """One distributed-training recipe: per-parameter obligations + bugs."""
    name: str
    params: Tuple[str, ...]
    degrees: Tuple[Degree, ...]
    bugs: Tuple[BugSpec, ...]
    bug_params: Mapping[str, str]        # bug name -> offending parameter
    description: str
    builder: Callable                    # (degree, bug) -> {param: spec...}

    def bug_names(self) -> Tuple[str, ...]:
        return tuple(b.name for b in self.bugs)

    def bug_spec(self, bug: str) -> BugSpec:
        for b in self.bugs:
            if b.name == bug:
                return b
        raise KeyError(bug)

    def validate_degree(self, degree: Degree) -> Degree:
        degree = normalize_degree(degree)
        arities = {len(d) for d in self.degrees if isinstance(d, tuple)}
        if isinstance(degree, tuple):
            if not arities:
                raise ValueError(
                    f"train strategy `{self.name}` is single-axis — it "
                    f"takes an int degree, not {degree}")
            if len(degree) not in arities:
                raise ValueError(
                    f"train strategy `{self.name}` takes "
                    f"{sorted(arities)}-axis degrees, got {degree}")
        return degree

    def build(self, degree: Optional[Degree] = None,
              bug: Optional[str] = None) -> Dict[str, StrategySpec]:
        """Materialize the per-parameter obligations (ordered by PARAMS)."""
        if degree is None:
            degree = self.degrees[0]
        degree = self.validate_degree(degree)
        if bug is not None and bug not in self.bug_names():
            hosts = [s.name for s in TRAIN_STRATEGIES.values()
                     if bug in s.bug_names()]
            raise ValueError(
                f"bug `{bug}` belongs to train strategy {hosts or '?'} — "
                f"running it under `{self.name}` would silently verify "
                f"the clean step")
        specs = self.builder(degree=degree, bug=bug)
        out = {}
        for param in self.params:
            expected = "certificate"
            if bug is not None and self.bug_params.get(bug) == param:
                expected = self.bug_spec(bug).expected
            out[param] = specs[param].with_identity(
                name=f"{self.name}:{param}", degree=degree,
                bug=bug if expected != "certificate" else None,
                expected=expected)
        return out


TRAIN_STRATEGIES: Dict[str, TrainStrategy] = {}


def register_train_strategy(name: str, *, params=PARAMS, degrees=(2, 4),
                            bugs=(), bug_params=None, description=""):
    """Register a train-step strategy (the gradcheck registry — mirrors
    ``repro.api.register_strategy`` for ``train@strategy`` task ids).

    The decorated builder returns ``{param: StrategySpec}`` with the
    loss-data (batch) input as each obligation's *first* input — the
    scheduler transposes its sharding into the owed gradient collective.
    Reject unsupported degrees with ``ValueError`` (never ``assert``:
    the CLI maps ValueError to exit code 2, and a bare assert would exit
    1 — the code CI gates read as "bug localized")."""
    bug_specs = tuple(b if isinstance(b, BugSpec) else BugSpec(str(b))
                      for b in bugs)

    def deco(fn):
        if name in TRAIN_STRATEGIES:
            raise ValueError(f"train strategy `{name}` already registered")
        for s in TRAIN_STRATEGIES.values():
            taken = set(s.bug_names()) & {b.name for b in bug_specs}
            if taken:
                raise ValueError(f"train bug name(s) {sorted(taken)} "
                                 f"already registered under `{s.name}`")
        TRAIN_STRATEGIES[name] = TrainStrategy(
            name=name, params=tuple(params),
            degrees=tuple(normalize_degree(d) for d in degrees),
            bugs=bug_specs, bug_params=dict(bug_params or {}),
            description=description or (fn.__doc__ or "").strip().split("\n")[0],
            builder=fn)
        return fn

    return deco


def list_train_strategies() -> Tuple[str, ...]:
    return tuple(TRAIN_STRATEGIES)


def get_train_strategy(name: str) -> TrainStrategy:
    try:
        return TRAIN_STRATEGIES[name]
    except KeyError:
        raise KeyError(f"unknown train strategy `{name}` — registered: "
                       f"{sorted(TRAIN_STRATEGIES)}") from None


def list_train_bugs() -> Dict[str, Tuple[str, BugSpec]]:
    """train bug name -> (host strategy, BugSpec)."""
    out: Dict[str, Tuple[str, BugSpec]] = {}
    for s in TRAIN_STRATEGIES.values():
        for b in s.bugs:
            out[b.name] = (s.name, b)
    return out


# ---------------------------------------------------------------------------
# dp — DDP: local backward + gradient psum
# ---------------------------------------------------------------------------

@register_train_strategy(
    "dp", degrees=(2, 4),
    description="DDP train step: batch-sharded local backward + grad psum")
def dp_train(degree: int = 2, bug=None) -> Dict[str, StrategySpec]:
    """Replicated parameters transpose to a gradient all-reduce: each rank
    runs the local backward on its batch shard and psums the result."""
    if degree < 1 or BATCH % degree:
        raise ValueError(f"train strategy `dp` needs the degree to divide "
                         f"the batch of {BATCH}, got degree {degree}")
    specs = (P("dp", None), P(), P())
    out = {}
    for param, a in _ARGNUM.items():
        seq_fn = grad_of(_loss, a)

        def dist_fn(x, w1, w2, a=a):
            g = grad_of(_loss, a)(x, w1, w2)
            return jax.lax.psum(g, "dp")

        out[param] = StrategySpec(seq_fn, dist_fn, {"dp": degree}, specs,
                                  _AVALS, _NAMES)
    return out


# ---------------------------------------------------------------------------
# dp_accum — DDP + microbatch accumulation into a dus scatter buffer
# ---------------------------------------------------------------------------

@register_train_strategy(
    "dp_accum", degrees=(2, 4),
    bugs=[BugSpec("accum_no_rescale", "refinement_error",
                  "the accumulated gradient is normalized by the "
                  "microbatch size instead of the global batch — grads "
                  "n_steps x too large (the HF-regression class)")],
    bug_params={"accum_no_rescale": "w2"},
    description="DDP + microbatch grad accumulation (dus scatter buffer)")
def dp_accum_train(degree: int = 2, bug=None) -> Dict[str, StrategySpec]:
    """Per-microbatch local backwards are written into a zeros scatter
    buffer (``dynamic_update_slice``), summed, psummed, and normalized by
    the *global* batch — verifiable end-to-end thanks to the constrained
    ``dus_concat`` lemma.  Bug ``accum_no_rescale`` (w2 only): the final
    normalization divides by the microbatch size."""
    local = BATCH // degree
    mb = local // N_MICRO
    if degree < 1 or BATCH % degree or mb < 1:
        raise ValueError(
            f"train strategy `dp_accum` needs degree * {N_MICRO} "
            f"microbatches to divide the batch of {BATCH}, got degree "
            f"{degree}")
    specs = (P("dp", None), P(), P())
    out = {}
    for param, a in _ARGNUM.items():
        def seq_fn(x, w1, w2, a=a):
            return grad_of(_loss, a)(x, w1, w2) / BATCH

        def dist_fn(x, w1, w2, a=a, param=param):
            gshape = _AVALS[a].shape
            buf = jnp.zeros((N_MICRO,) + gshape, jnp.float32)
            for m in range(N_MICRO):
                xm = jax.lax.dynamic_slice(x, (m * mb, 0), (mb, D_MODEL))
                g = grad_of(_loss, a)(xm, w1, w2)
                buf = jax.lax.dynamic_update_slice(buf, g[None], (m, 0, 0))
            acc = jnp.sum(buf, axis=0)
            tot = jax.lax.psum(acc, "dp")
            denom = mb if (bug == "accum_no_rescale" and param == "w2") \
                else BATCH               # BUG: microbatch-size normalization
            return tot / denom

        out[param] = StrategySpec(seq_fn, dist_fn, {"dp": degree}, specs,
                                  _AVALS, _NAMES)
    return out


# ---------------------------------------------------------------------------
# fsdp — ZeRO-3: gather weights forward, reduce_scatter gradients back
# ---------------------------------------------------------------------------

@register_train_strategy(
    "fsdp", degrees=(2, 4),
    bugs=[BugSpec("stale_grad_shard", "refinement_error",
                  "the gradient reduce_scatter is skipped — the rank keeps "
                  "its local partial's shard (stale ZeRO-3 shard class)")],
    bug_params={"stale_grad_shard": "w2"},
    description="ZeRO-3 train step: all_gather weights, reduce_scatter grads")
def fsdp_train(degree: int = 2, bug=None) -> Dict[str, StrategySpec]:
    """The all_gather of the forward transposes to a reduce_scatter of the
    backward: sum the per-rank partials over the group, keep your shard.
    Bug ``stale_grad_shard`` (w2 only): the scatter is skipped and the
    rank slices its own *unreduced* partial."""
    if degree < 1 or D_MODEL % degree or D_FF % degree \
            or BATCH % degree:
        raise ValueError(
            f"train strategy `fsdp` needs the degree to divide the "
            f"batch ({BATCH}) and both weight dims ({D_MODEL}, {D_FF}), "
            f"got degree {degree}")
    specs = (P("dp", None), P("dp", None), P("dp", None))
    out = {}
    for param, a in _ARGNUM.items():
        seq_fn = grad_of(_loss, a)

        def dist_fn(x, w1s, w2s, a=a, param=param):
            w1 = jax.lax.all_gather(w1s, "dp", axis=0, tiled=True)
            w2 = jax.lax.all_gather(w2s, "dp", axis=0, tiled=True)
            g = grad_of(_loss, a)(x, w1, w2)
            if bug == "stale_grad_shard" and param == "w2":
                blk = g.shape[0] // degree   # BUG: local partial, no reduce
                idx = jax.lax.axis_index("dp")
                return jax.lax.dynamic_slice(
                    g, (idx * blk, 0), (blk, g.shape[1]))
            return jax.lax.psum_scatter(g, "dp", scatter_dimension=0,
                                        tiled=True)

        out[param] = StrategySpec(seq_fn, dist_fn, {"dp": degree}, specs,
                                  _AVALS, _NAMES)
    return out


# ---------------------------------------------------------------------------
# tp_dp_2d — Megatron TP x DP: sharded-weight grads, dp-only psum
# ---------------------------------------------------------------------------

@register_train_strategy(
    "tp_dp_2d", degrees=((2, 2), (4, 4)),
    bugs=[BugSpec("grad_psum_wrong_axis", "refinement_error",
                  "the gradient all-reduce runs over tp instead of dp — "
                  "batch partials never combine and tp shards are wrongly "
                  "summed (the composed-mesh wrong-axis class)")],
    bug_params={"grad_psum_wrong_axis": "w2"},
    description="Megatron TP x DP train step: sharded-weight grads, dp psum")
def tp_dp_2d_train(degree=(2, 2), bug=None) -> Dict[str, StrategySpec]:
    """On the 2D mesh the weight shard is exact under transposition (the
    tp split of the forward concat transposes to the same split of the
    gradient), so each weight gradient owes a psum over *dp only*.  The
    16-rank ``(4, 4)`` mesh is exactly the add-chain width that needed the
    n-ary add normal form.  Bug ``grad_psum_wrong_axis`` (w2 only): the
    all-reduce runs over tp."""
    d_dp, d_tp = axis_degrees(degree, 2)
    if d_dp < 1 or d_tp < 1 or BATCH % d_dp or D_FF % d_tp:
        raise ValueError(
            f"train strategy `tp_dp_2d` needs dp to divide the batch "
            f"({BATCH}) and tp to divide d_ff ({D_FF}), got degree "
            f"({d_dp}, {d_tp})")
    specs = (P("dp", None), P(None, "tp"), P("tp", None))
    mesh = {"dp": d_dp, "tp": d_tp}
    out = {}
    for param, a in _ARGNUM.items():
        seq_fn = grad_of(_loss, a)

        def dist_fn(x, w1, w2, a=a, param=param):
            g = grad_of(_loss, a)(x, w1, w2)
            axis = "tp" if (bug == "grad_psum_wrong_axis"
                            and param == "w2") else "dp"   # BUG: wrong axis
            return jax.lax.psum(g, axis)

        out[param] = StrategySpec(seq_fn, dist_fn, mesh, specs, _AVALS,
                                  _NAMES)
    return out

"""Backward-graph capture: ``jax.grad`` over the existing capture layer.

The forward families in ``repro.dist.strategies`` verify what a rank
*computes*; the training step is about what a rank *differentiates*.  This
module turns a loss function into gradient functions whose jaxprs the
existing ``repro.core.capture`` machinery traces like any other program —
the backward pass is just more operators (transposed matmuls, activation
derivatives, broadcast cotangents), so the lemma engine needs no new
concepts, only the n-ary add normal form to keep the (much wider) gradient
add chains tractable.

    seq_grad  = grad_of(loss, argnums=2)          # d loss / d w2
    gs        = capture_grad(loss, avals, names, wrt=2)   # backward Graph

``capture_grad_spmd`` is the distributed flavour: the per-rank gradient
function (local backward + whatever collectives the strategy wraps around
it) is traced under ``shard_map`` exactly like a forward ``dist_fn``.
"""
from __future__ import annotations

from typing import Callable, Sequence, Union

import jax

from ..core.capture import Graph, SpmdCapture, capture, capture_spmd


def grad_of(loss_fn: Callable, argnums: Union[int, Sequence[int]]
            ) -> Callable:
    """The gradient function of a scalar loss w.r.t. ``argnums``.

    A thin, named wrapper over ``jax.grad`` so obligations read as what
    they verify (``grad_of(loss, 2)`` = the w2 gradient of the step).
    """
    return jax.grad(loss_fn, argnums=argnums)


def capture_grad(loss_fn: Callable, avals: Sequence, names: Sequence[str],
                 wrt: Union[int, Sequence[int]]) -> Graph:
    """Capture the backward graph of ``loss_fn`` w.r.t. ``wrt`` as a
    sequential :class:`Graph` (the G_s of a train-step obligation)."""
    return capture(grad_of(loss_fn, wrt), list(avals), list(names))


def capture_grad_spmd(dist_grad_fn: Callable, mesh_axes: dict,
                      in_specs: Sequence, avals: Sequence,
                      names: Sequence[str]) -> SpmdCapture:
    """Capture a per-rank gradient implementation (local backward +
    explicit collectives) under ``shard_map`` — the G_d of a train-step
    obligation."""
    return capture_spmd(dist_grad_fn, mesh_axes, list(in_specs),
                        list(avals), list(names))

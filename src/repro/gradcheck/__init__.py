"""repro.gradcheck — distributed training-step verification.

The forward families verify what a rank computes; most real distribution
bugs bite in the *backward* pass — wrong-axis gradient psums, stale ZeRO
shards, mis-normalized accumulation (the TTrace / LLM-framework bug-study
classes in PAPERS.md).  This subsystem verifies the training step itself:

    from repro.gradcheck import check_train
    report = check_train("dp_accum")              # -> TrainReport
    report = check_train("fsdp", bug="stale_grad_shard", degree=2)
    report.failing_params                         # ["w2"] — localized

Pipeline:

  * ``capture_grad``   captures backward graphs via ``jax.grad`` over the
                       existing ``repro.core.capture`` machinery — the
                       backward pass is just more operators.
  * ``transpose``      derives gradient relations by *transposing* the
                       forward relations: a sharded forward input owes a
                       psum/reduce_scatter gradient collective, a
                       replicated one transposes to identity; the inferred
                       R_o must equal the transposed relation (seam).
  * ``obligations``    the ``train@strategy`` registry — per-parameter
                       gradient obligations for dp, dp_accum (microbatch
                       accumulation), fsdp (ZeRO-3), and tp_dp_2d
                       strategies, plus the three injected gradient bug
                       classes.
  * ``schedule``       fans obligations across the Suite-style worker
                       pool and stitches per-parameter reports into one
                       :class:`TrainReport`.
  * ``report``         the nested, JSON-ready verdict (schema-versioned,
                       per-parameter localization).
"""
from .capture_grad import capture_grad, capture_grad_spmd, grad_of
from .obligations import (TRAIN_STRATEGIES, TrainStrategy,
                          get_train_strategy, list_train_bugs,
                          list_train_strategies, register_train_strategy)
from .report import TRAIN_REPORT_SCHEMA, ParamResult, TrainReport
from .schedule import check_train, run_train_obligations
from .transpose import expected_grad_relation, grad_collective

__all__ = [
    "capture_grad", "capture_grad_spmd", "grad_of",
    "TRAIN_STRATEGIES", "TrainStrategy", "get_train_strategy",
    "list_train_bugs", "list_train_strategies", "register_train_strategy",
    "TRAIN_REPORT_SCHEMA", "ParamResult", "TrainReport",
    "check_train", "run_train_obligations",
    "expected_grad_relation", "grad_collective",
]

"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per
expert) vocab=163840, 384 experts top-8 — trillion-param MoE
[arXiv:2501.kimi2, paper-table spec]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab=163840, head_dim=112,
    pattern=("global",), window=0,
    n_experts=384, top_k=8, moe_d_ff=2048,
    citation="arXiv:2501.kimi2 (paper-table)",
)

"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE, dynamic resolution (vision frontend stubbed to 1024
patch embeddings) [arXiv:2409.12191]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab=151936, head_dim=128,
    pattern=("global",), window=0,
    vision_tokens=1024, mrope=True, mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0, tie_embeddings=True,
    citation="arXiv:2409.12191",
)

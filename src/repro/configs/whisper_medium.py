"""whisper-medium [audio]: 24L (decoder) + 24L encoder, d_model=1024 16H
d_ff=4096 vocab=51865 — enc-dec, conv frontend stubbed to precomputed frame
embeddings [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=51865, head_dim=64, use_bias=True,
    pattern=("global",), window=0,
    encoder_layers=24, encoder_frames=1500, tie_embeddings=True,
    citation="arXiv:2212.04356",
)

"""Assigned architecture configs (+ the paper's GPT). One module per arch;
``repro.models.registry.load_config`` resolves ids to CONFIG objects."""

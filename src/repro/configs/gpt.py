"""The paper's own evaluation model: GPT (Megatron-LM example scale) —
used by the verification examples and the 100M end-to-end training driver."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gpt", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=50257, head_dim=64,
    pattern=("global",), window=0, rope_theta=10_000.0,
    citation="Megatron-LM run_simple_mcore_train_loop (paper table 2)",
)

"""mamba2-1.3b [ssm]: 48L d_model=2048 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=50280,
    pattern=("recurrent",),
    ssm_state=128, ssm_head_dim=64, ssm_chunk=256, ssm_conv=4, ssm_expand=2,
    citation="arXiv:2405.21060",
)

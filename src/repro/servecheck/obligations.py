"""Serving strategies: decode-step and prefill-read obligations.

Each strategy models one real sharded-KV-cache serving recipe for the
shared single-layer attention fragment (project keys/values into a
``(seq, feat)`` cache, attend with the last position's query).  The
refinement claim is the serving-path soundness argument: *N incremental
decode steps chained over the sharded cache refine full-sequence
prefill*.  It decomposes exactly like modelcheck's block argument:

  step t   the sequential single-position cache write
           (``dynamic_update_slice`` at row ``t``) is refined by the
           rank-local/rank-conditional distributed write — one
           obligation per decode step, deduped by *position class*;
  read     the full decode chain from a zeros cache, re-captured
           end-to-end, plus the attention read through the gathered
           cache — one obligation proving the chained steps compose
           (this is where the ``dus_concat``/``dus_unfold`` lemmas
           flatten the N-link update chain into the prefill concat).

Strategies::

  ``tp_decode``      tensor-parallel serving — cache feature-sharded
                     (layout ``heads``); writes are local, the read
                     gathers on the feature dim.
  ``sp_cache``       sequence-parallel cache — cache row-sharded
                     (layout ``seq``); writes are rank-conditional
                     (``where(axis_index == owner, upd, cache)``, folded
                     per-rank by the engine's select fold), reads gather
                     on the position dim.
  ``batched_decode`` continuous batching on a dp x tp mesh — two
                     requests at *different* positions decode together:
                     dp gathers the 2-token batch, tp shards the cache
                     features.  Positions rotate per step, so every step
                     is its own position class (dedup ratio 1 — the
                     documented contrast case to tp/sp).

Position classes (the dedup identity, carried as a ``structure`` fact in
place of the step index): ``tp_decode`` steps differ only in where the
written row sits relative to the cache ends (``first``/``mid``/``last``
— 8 steps collapse to 3 obligations); ``sp_cache`` steps differ in the
*local* offset on the owner's shard (``lfirst``/``lmid``/``llast`` —
the owner rank itself is symmetric under the mesh, so steps landing on
different ranks at the same local offset share one obligation).

The three injected bug classes are the serving analogues of the bug
study (PAPERS.md):

  ``stale_cache_shard``       (tp_decode, step 3) rank 0's feature shard
                              keeps the pre-write cache — the
                              skipped-write/stale-page KV class.
  ``pos_off_by_one``          (sp_cache, step 4) the owner writes local
                              row ``loc + 1`` — the global-vs-local
                              position-arithmetic class.
  ``cache_gather_wrong_axis`` (batched_decode, step 1) the token batch
                              is gathered over tp instead of dp.  Each
                              request's cache is still *reconstructible*
                              from the ranks that computed it correctly,
                              so refinement holds — but the inferred R_o
                              shifts off the spec-promised relation and
                              the seam check flags it
                              (``unexpected_relation``, the paper's
                              silent-misplacement detection mode).

A bug changes its step's structure fingerprint, splitting the step out
of its position class — which is exactly how :class:`ServeReport`
localizes it to the failing step while the class siblings stay clean.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..api.spec import BugSpec, Degree, axis_degrees, normalize_degree
from ..modelcheck.obligations import Obligation, ObligationSet
from ..sharding.specs import parse_plan
from .relations import cache_spec, seq_parallel_plan

# serving fragment sizes (symbolic engine: cost is op count x degree, not
# extents).  S is the decode horizon for the single-request strategies;
# the batched strategy halves it — its read chain carries 4 interleaved
# dus chains, and 4 steps already exercise a full position rotation.
S, SB, D_MODEL, HD = 8, 4, 4, 4


def _aval(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _obligation(kind, seq_fn, dist_fn, plan, in_specs, out_specs, avals,
                names, *, strategy, role, pos_class, bug=None,
                description=""):
    return Obligation(
        kind=kind, seq_fn=seq_fn, dist_fn=dist_fn,
        mesh_axes=tuple(plan.axes), in_specs=tuple(in_specs),
        out_specs=tuple(out_specs), avals=tuple(avals),
        input_names=tuple(names),
        structure=tuple(sorted((
            ("strategy", strategy), ("role", role),
            ("pos_class", pos_class), ("bug", bug or "-")))),
        description=description)


@dataclass(frozen=True)
class ServeStrategy:
    """One serving recipe: per-step + read obligations, and its bugs."""
    name: str
    n_steps: int
    degrees: Tuple[Degree, ...]
    bugs: Tuple[BugSpec, ...]
    bug_steps: Mapping[str, int]         # bug name -> decode step it lands on
    description: str
    builder: Callable                    # (degree, bug) -> ObligationSet

    def bug_names(self) -> Tuple[str, ...]:
        return tuple(b.name for b in self.bugs)

    def bug_spec(self, bug: str) -> BugSpec:
        for b in self.bugs:
            if b.name == bug:
                return b
        raise KeyError(bug)

    def validate_degree(self, degree: Degree) -> Degree:
        degree = normalize_degree(degree)
        arities = {len(d) for d in self.degrees if isinstance(d, tuple)}
        if isinstance(degree, tuple):
            if not arities:
                raise ValueError(
                    f"serve strategy `{self.name}` is single-axis — it "
                    f"takes an int degree, not {degree}")
            if len(degree) not in arities:
                raise ValueError(
                    f"serve strategy `{self.name}` takes "
                    f"{sorted(arities)}-axis degrees, got {degree}")
        return degree

    def build(self, degree: Optional[Degree] = None,
              bug: Optional[str] = None) -> ObligationSet:
        """Materialize the obligation set: blocks ``step0..stepN-1, read``."""
        if degree is None:
            degree = self.degrees[0]
        degree = self.validate_degree(degree)
        if bug is not None and bug not in self.bug_names():
            hosts = [s.name for s in SERVE_STRATEGIES.values()
                     if bug in s.bug_names()]
            raise ValueError(
                f"bug `{bug}` belongs to serve strategy {hosts or '?'} — "
                f"running it under `{self.name}` would silently certify "
                f"the clean serving path")
        return self.builder(degree=degree, bug=bug)


SERVE_STRATEGIES: Dict[str, ServeStrategy] = {}


def register_serve_strategy(name: str, *, n_steps, degrees=(2, 4), bugs=(),
                            bug_steps=None, description=""):
    """Register a serving strategy (the servecheck registry — mirrors
    ``register_train_strategy`` for ``serve@strategy`` task ids).

    The decorated builder returns an :class:`ObligationSet` whose blocks
    are ``step0..step{n_steps-1}`` followed by ``read``.  Reject
    unsupported degrees with ``ValueError`` (never ``assert``: the CLI
    maps ValueError to exit code 2, and a bare assert would exit 1 — the
    code CI gates read as "bug localized")."""
    bug_specs = tuple(b if isinstance(b, BugSpec) else BugSpec(str(b))
                      for b in bugs)

    def deco(fn):
        if name in SERVE_STRATEGIES:
            raise ValueError(f"serve strategy `{name}` already registered")
        for s in SERVE_STRATEGIES.values():
            taken = set(s.bug_names()) & {b.name for b in bug_specs}
            if taken:
                raise ValueError(f"serve bug name(s) {sorted(taken)} "
                                 f"already registered under `{s.name}`")
        SERVE_STRATEGIES[name] = ServeStrategy(
            name=name, n_steps=int(n_steps),
            degrees=tuple(normalize_degree(d) for d in degrees),
            bugs=bug_specs, bug_steps=dict(bug_steps or {}),
            description=description or (fn.__doc__ or "").strip().split("\n")[0],
            builder=fn)
        return fn

    return deco


def list_serve_strategies() -> Tuple[str, ...]:
    return tuple(SERVE_STRATEGIES)


def get_serve_strategy(name: str) -> ServeStrategy:
    try:
        return SERVE_STRATEGIES[name]
    except KeyError:
        raise KeyError(f"unknown serve strategy `{name}` — registered: "
                       f"{sorted(SERVE_STRATEGIES)}") from None


def list_serve_bugs() -> Dict[str, Tuple[str, BugSpec]]:
    """serve bug name -> (host strategy, BugSpec)."""
    out: Dict[str, Tuple[str, BugSpec]] = {}
    for s in SERVE_STRATEGIES.values():
        for b in s.bugs:
            out[b.name] = (s.name, b)
    return out


# ---------------------------------------------------------------------------
# tp_decode — tensor-parallel serving: feature-sharded cache, local writes
# ---------------------------------------------------------------------------

@register_serve_strategy(
    "tp_decode", n_steps=S, degrees=(2, 4),
    bugs=[BugSpec("stale_cache_shard", "refinement_error",
                  "rank 0's feature shard keeps the pre-write cache — "
                  "the skipped-write / stale-KV-page class")],
    bug_steps={"stale_cache_shard": 3},
    description="TP serving: feature-sharded KV cache, local decode writes")
def tp_decode(degree: int = 2, bug=None) -> ObligationSet:
    """Every rank holds all S positions of its head slice, so a decode
    write is purely local (the dus row spans the rank's full feature
    shard) and only the read pays an all_gather on the feature dim.
    Position classes: the written row's relation to the cache ends —
    ``first`` (empty prefix), ``mid``, ``last`` (empty suffix) — so the
    S-step decode owes 3 step obligations, not S."""
    degree = normalize_degree(degree)
    if not isinstance(degree, int) or degree < 2 or HD % degree:
        raise ValueError(
            f"serve strategy `tp_decode` needs an int degree >= 2 dividing "
            f"the feature dim of {HD}, got {degree}")
    plan = parse_plan(f"tp{degree}")
    w_spec = plan.spec_for(("embed", "heads"))       # P(None, "tp")
    ck_spec = cache_spec(plan, "heads")              # P(None, "tp")
    x_aval, w_aval, c_aval = _aval((S, D_MODEL)), _aval((D_MODEL, HD)), \
        _aval((S, HD))
    obs = ObligationSet()

    for t in range(S):
        stale = bug == "stale_cache_shard" and t == 3

        def seq_step(x, wk, wv, ck, cv, t=t):
            xt = jax.lax.slice(x, (t, 0), (t + 1, D_MODEL))
            ck = jax.lax.dynamic_update_slice(ck, xt @ wk, (t, 0))
            cv = jax.lax.dynamic_update_slice(cv, xt @ wv, (t, 0))
            return ck, cv

        def dist_step(x, wk, wv, ck, cv, t=t, stale=stale):
            xt = jax.lax.slice(x, (t, 0), (t + 1, D_MODEL))
            upd_k = jax.lax.dynamic_update_slice(ck, xt @ wk, (t, 0))
            if stale:
                # BUG: rank 0's feature shard never lands the k write
                upd_k = jnp.where(jax.lax.axis_index("tp") == 0, ck, upd_k)
            cv = jax.lax.dynamic_update_slice(cv, xt @ wv, (t, 0))
            return upd_k, cv

        klass = "first" if t == 0 else ("last" if t == S - 1 else "mid")
        obs.add(f"step{t}", _obligation(
            "serve_step", seq_step, dist_step, plan,
            in_specs=(P(), w_spec, w_spec, ck_spec, ck_spec),
            out_specs=(ck_spec, ck_spec),
            avals=(x_aval, w_aval, w_aval, c_aval, c_aval),
            names=("x", "wk", "wv", "ck", "cv"),
            strategy="tp_decode", role="step", pos_class=klass,
            bug=bug if stale else None,
            description=f"tp decode write, position class {klass}"))

    def seq_read(x, wk, wv, wq):
        ck = jnp.zeros((S, HD), jnp.float32)
        cv = jnp.zeros((S, HD), jnp.float32)
        for t in range(S):
            xt = jax.lax.slice(x, (t, 0), (t + 1, D_MODEL))
            ck = jax.lax.dynamic_update_slice(ck, xt @ wk, (t, 0))
            cv = jax.lax.dynamic_update_slice(cv, xt @ wv, (t, 0))
        q = jax.lax.slice(x, (S - 1, 0), (S, D_MODEL)) @ wq
        return (q @ ck.T) @ cv

    def dist_read(x, wk, wv, wq, degree=degree):
        ck = jnp.zeros((S, HD // degree), jnp.float32)
        cv = jnp.zeros((S, HD // degree), jnp.float32)
        for t in range(S):
            xt = jax.lax.slice(x, (t, 0), (t + 1, D_MODEL))
            ck = jax.lax.dynamic_update_slice(ck, xt @ wk, (t, 0))
            cv = jax.lax.dynamic_update_slice(cv, xt @ wv, (t, 0))
        full_k = jax.lax.all_gather(ck, "tp", axis=1, tiled=True)
        full_v = jax.lax.all_gather(cv, "tp", axis=1, tiled=True)
        q = jax.lax.slice(x, (S - 1, 0), (S, D_MODEL)) @ wq
        return (q @ full_k.T) @ full_v

    obs.add("read", _obligation(
        "serve_read", seq_read, dist_read, plan,
        in_specs=(P(), w_spec, w_spec, P()), out_specs=(P(),),
        avals=(x_aval, w_aval, w_aval, w_aval),
        names=("x", "wk", "wv", "wq"),
        strategy="tp_decode", role="read", pos_class="full",
        description=f"tp prefill read: {S}-step chain + gathered attention"))
    return obs


# ---------------------------------------------------------------------------
# sp_cache — sequence-parallel cache: row-sharded, rank-conditional writes
# ---------------------------------------------------------------------------

@register_serve_strategy(
    "sp_cache", n_steps=S, degrees=(2, 4),
    bugs=[BugSpec("pos_off_by_one", "refinement_error",
                  "the owner writes local row loc+1 — the global-vs-local "
                  "position-arithmetic class")],
    bug_steps={"pos_off_by_one": 4},
    description="Sequence-parallel KV cache: row-sharded, owner-only writes")
def sp_cache(degree: int = 2, bug=None) -> ObligationSet:
    """Each rank owns S/degree contiguous cache rows; step t lands only on
    rank ``t // L`` (``where(axis_index == owner, upd, cache)``, folded to
    a per-rank straight-line write by the engine's select fold) and the
    step output is the all_gather of the per-rank buffers — the gather is
    what groups the rank-split cache into one term the engine can relate
    to the sequential dus.  Position classes: the *local* offset on the
    owner's shard (``lfirst``/``lmid``/``llast``); the owner index itself
    is symmetric under the mesh, so steps landing on different ranks at
    the same local offset share one obligation."""
    degree = normalize_degree(degree)
    if not isinstance(degree, int) or degree < 2 or S % degree:
        raise ValueError(
            f"serve strategy `sp_cache` needs an int degree >= 2 dividing "
            f"the sequence length of {S}, got {degree}")
    plan = seq_parallel_plan(degree)
    local = S // degree
    w_spec = plan.spec_for(("embed", "heads"))       # replicated
    ck_spec = cache_spec(plan, "seq")                # P("sp", None)
    x_aval, w_aval, c_aval = _aval((S, D_MODEL)), _aval((D_MODEL, HD)), \
        _aval((S, HD))
    obs = ObligationSet()

    for t in range(S):
        owner, loc = t // local, t % local
        off = bug == "pos_off_by_one" and t == 4

        def seq_step(x, wk, wv, ck, cv, t=t):
            xt = jax.lax.slice(x, (t, 0), (t + 1, D_MODEL))
            ck = jax.lax.dynamic_update_slice(ck, xt @ wk, (t, 0))
            cv = jax.lax.dynamic_update_slice(cv, xt @ wv, (t, 0))
            return ck, cv

        def dist_step(x, wk, wv, ck, cv, t=t, owner=owner, loc=loc, off=off):
            xt = jax.lax.slice(x, (t, 0), (t + 1, D_MODEL))
            # BUG (pos_off_by_one): the k row lands one past its local slot
            kloc = loc + 1 if off else loc
            upd_k = jax.lax.dynamic_update_slice(ck, xt @ wk, (kloc, 0))
            upd_v = jax.lax.dynamic_update_slice(cv, xt @ wv, (loc, 0))
            mine = jax.lax.axis_index("sp") == owner
            out_k = jnp.where(mine, upd_k, ck)
            out_v = jnp.where(mine, upd_v, cv)
            return (jax.lax.all_gather(out_k, "sp", axis=0, tiled=True),
                    jax.lax.all_gather(out_v, "sp", axis=0, tiled=True))

        klass = "lfirst" if loc == 0 else \
            ("llast" if loc == local - 1 else "lmid")
        obs.add(f"step{t}", _obligation(
            "serve_step", seq_step, dist_step, plan,
            in_specs=(P(), w_spec, w_spec, ck_spec, ck_spec),
            out_specs=(P(), P()),            # gathered -> replicated
            avals=(x_aval, w_aval, w_aval, c_aval, c_aval),
            names=("x", "wk", "wv", "ck", "cv"),
            strategy="sp_cache", role="step", pos_class=klass,
            bug=bug if off else None,
            description=f"sp owner-conditional write, local class {klass}"))

    def seq_read(x, wk, wv, wq):
        ck = jnp.zeros((S, HD), jnp.float32)
        cv = jnp.zeros((S, HD), jnp.float32)
        for t in range(S):
            xt = jax.lax.slice(x, (t, 0), (t + 1, D_MODEL))
            ck = jax.lax.dynamic_update_slice(ck, xt @ wk, (t, 0))
            cv = jax.lax.dynamic_update_slice(cv, xt @ wv, (t, 0))
        q = jax.lax.slice(x, (S - 1, 0), (S, D_MODEL)) @ wq
        return (q @ ck.T) @ cv

    def dist_read(x, wk, wv, wq, local=local):
        ck = jnp.zeros((local, HD), jnp.float32)
        cv = jnp.zeros((local, HD), jnp.float32)
        me = jax.lax.axis_index("sp")
        for t in range(S):
            owner, loc = t // local, t % local
            xt = jax.lax.slice(x, (t, 0), (t + 1, D_MODEL))
            upd_k = jax.lax.dynamic_update_slice(ck, xt @ wk, (loc, 0))
            upd_v = jax.lax.dynamic_update_slice(cv, xt @ wv, (loc, 0))
            mine = me == owner
            ck = jnp.where(mine, upd_k, ck)
            cv = jnp.where(mine, upd_v, cv)
        full_k = jax.lax.all_gather(ck, "sp", axis=0, tiled=True)
        full_v = jax.lax.all_gather(cv, "sp", axis=0, tiled=True)
        q = jax.lax.slice(x, (S - 1, 0), (S, D_MODEL)) @ wq
        return (q @ full_k.T) @ full_v

    obs.add("read", _obligation(
        "serve_read", seq_read, dist_read, plan,
        in_specs=(P(), w_spec, w_spec, P()), out_specs=(P(),),
        avals=(x_aval, w_aval, w_aval, w_aval),
        names=("x", "wk", "wv", "wq"),
        strategy="sp_cache", role="read", pos_class="full",
        description=f"sp prefill read: {S}-step owner chain + row gather"))
    return obs


# ---------------------------------------------------------------------------
# batched_decode — continuous batching: dp gathers the token batch,
# tp shards cache features, positions rotate per step
# ---------------------------------------------------------------------------

def _batch_pos(t: int) -> Tuple[int, int]:
    """Request positions at step t: request a decodes in order, request b
    joined mid-stream (continuous batching) — its position is rotated by
    half the horizon, so no two steps share a position pair."""
    return t, (t + SB // 2) % SB


@register_serve_strategy(
    "batched_decode", n_steps=SB, degrees=((2, 2), (2, 4)),
    bugs=[BugSpec("cache_gather_wrong_axis", "unexpected_relation",
                  "the token batch is gathered over tp instead of dp — "
                  "refinement still holds (each request's cache is "
                  "reconstructible from the ranks that computed it), but "
                  "the inferred R_o shifts off the spec's relation and "
                  "the seam check flags it")],
    bug_steps={"cache_gather_wrong_axis": 1},
    description="Continuous batching on dp x tp: gathered 2-token batch, "
                "feature-sharded caches")
def batched_decode(degree=(2, 2), bug=None) -> ObligationSet:
    """Two requests decode together: each dp rank holds one request's
    current token, the step gathers the 2-token batch over dp, projects
    it through the tp-sharded weights once, and scatters the two rows
    into the two feature-sharded caches.  Request b joined mid-stream, so
    its write position is rotated — every step is its own position class
    and the dedup ratio is 1 (the documented contrast case: position
    classes, not step count, set the obligation count)."""
    d_dp, d_tp = axis_degrees(normalize_degree(degree), 2)
    if d_dp != 2:
        raise ValueError(
            f"serve strategy `batched_decode` serves exactly 2 concurrent "
            f"requests — dp must be 2, got ({d_dp}, {d_tp})")
    if d_tp < 2 or HD % d_tp:
        raise ValueError(
            f"serve strategy `batched_decode` needs tp >= 2 dividing the "
            f"feature dim of {HD}, got ({d_dp}, {d_tp})")
    if bug == "cache_gather_wrong_axis" and d_tp != d_dp:
        raise ValueError(
            f"bug `cache_gather_wrong_axis` swaps the dp gather for a tp "
            f"gather, which only type-checks on a square mesh — run it at "
            f"degree ({d_dp}, {d_dp}), not ({d_dp}, {d_tp})")
    plan = parse_plan(f"dp{d_dp}xtp{d_tp}")
    w_spec = plan.spec_for(("embed", "heads"))       # P(None, "tp")
    ck_spec = cache_spec(plan, "heads")              # P(None, "tp")
    x_aval, w_aval, c_aval = _aval((SB, D_MODEL)), _aval((D_MODEL, HD)), \
        _aval((SB, HD))
    local_hd = HD // d_tp
    obs = ObligationSet()

    for t in range(SB):
        pa, pb = _batch_pos(t)
        wrong = bug == "cache_gather_wrong_axis" and t == 1

        def seq_step(xa, xb, wk, wv, cka, cva, ckb, cvb, pa=pa, pb=pb):
            xta = jax.lax.slice(xa, (pa, 0), (pa + 1, D_MODEL))
            xtb = jax.lax.slice(xb, (pb, 0), (pb + 1, D_MODEL))
            cka = jax.lax.dynamic_update_slice(cka, xta @ wk, (pa, 0))
            cva = jax.lax.dynamic_update_slice(cva, xta @ wv, (pa, 0))
            ckb = jax.lax.dynamic_update_slice(ckb, xtb @ wk, (pb, 0))
            cvb = jax.lax.dynamic_update_slice(cvb, xtb @ wv, (pb, 0))
            return cka, cva, ckb, cvb

        def dist_step(xa, xb, wk, wv, cka, cva, ckb, cvb,
                      pa=pa, pb=pb, wrong=wrong):
            xta = jax.lax.slice(xa, (pa, 0), (pa + 1, D_MODEL))
            xtb = jax.lax.slice(xb, (pb, 0), (pb + 1, D_MODEL))
            mine = jax.lax.axis_index("dp") == 0
            xloc = jnp.where(mine, xta, xtb)         # my request's token
            # BUG (cache_gather_wrong_axis): gathering over tp hands every
            # dp rank its own token twice instead of the 2-request batch
            batch = jax.lax.all_gather(xloc, "tp" if wrong else "dp",
                                       axis=0, tiled=True)
            k2, v2 = batch @ wk, batch @ wv          # (2, HD/tp)
            cka = jax.lax.dynamic_update_slice(
                cka, jax.lax.slice(k2, (0, 0), (1, local_hd)), (pa, 0))
            cva = jax.lax.dynamic_update_slice(
                cva, jax.lax.slice(v2, (0, 0), (1, local_hd)), (pa, 0))
            ckb = jax.lax.dynamic_update_slice(
                ckb, jax.lax.slice(k2, (1, 0), (2, local_hd)), (pb, 0))
            cvb = jax.lax.dynamic_update_slice(
                cvb, jax.lax.slice(v2, (1, 0), (2, local_hd)), (pb, 0))
            return cka, cva, ckb, cvb

        obs.add(f"step{t}", _obligation(
            "serve_step", seq_step, dist_step, plan,
            in_specs=(P(), P(), w_spec, w_spec,
                      ck_spec, ck_spec, ck_spec, ck_spec),
            out_specs=(ck_spec, ck_spec, ck_spec, ck_spec),
            avals=(x_aval, x_aval, w_aval, w_aval,
                   c_aval, c_aval, c_aval, c_aval),
            names=("xa", "xb", "wk", "wv", "cka", "cva", "ckb", "cvb"),
            strategy="batched_decode", role="step",
            pos_class=f"pos{pa}-{pb}", bug=bug if wrong else None,
            description=f"batched write at positions ({pa}, {pb})"))

    def seq_read(xa, xb, wk, wv, wq):
        cka = jnp.zeros((SB, HD), jnp.float32)
        cva = jnp.zeros((SB, HD), jnp.float32)
        ckb = jnp.zeros((SB, HD), jnp.float32)
        cvb = jnp.zeros((SB, HD), jnp.float32)
        for t in range(SB):
            pa, pb = _batch_pos(t)
            xta = jax.lax.slice(xa, (pa, 0), (pa + 1, D_MODEL))
            xtb = jax.lax.slice(xb, (pb, 0), (pb + 1, D_MODEL))
            cka = jax.lax.dynamic_update_slice(cka, xta @ wk, (pa, 0))
            cva = jax.lax.dynamic_update_slice(cva, xta @ wv, (pa, 0))
            ckb = jax.lax.dynamic_update_slice(ckb, xtb @ wk, (pb, 0))
            cvb = jax.lax.dynamic_update_slice(cvb, xtb @ wv, (pb, 0))
        qa = jax.lax.slice(xa, (SB - 1, 0), (SB, D_MODEL)) @ wq
        qb = jax.lax.slice(xb, (SB - 1, 0), (SB, D_MODEL)) @ wq
        return (qa @ cka.T) @ cva, (qb @ ckb.T) @ cvb

    def dist_read(xa, xb, wk, wv, wq, local_hd=local_hd):
        cka = jnp.zeros((SB, local_hd), jnp.float32)
        cva = jnp.zeros((SB, local_hd), jnp.float32)
        ckb = jnp.zeros((SB, local_hd), jnp.float32)
        cvb = jnp.zeros((SB, local_hd), jnp.float32)
        for t in range(SB):
            pa, pb = _batch_pos(t)
            xta = jax.lax.slice(xa, (pa, 0), (pa + 1, D_MODEL))
            xtb = jax.lax.slice(xb, (pb, 0), (pb + 1, D_MODEL))
            mine = jax.lax.axis_index("dp") == 0
            xloc = jnp.where(mine, xta, xtb)
            batch = jax.lax.all_gather(xloc, "dp", axis=0, tiled=True)
            k2, v2 = batch @ wk, batch @ wv
            cka = jax.lax.dynamic_update_slice(
                cka, jax.lax.slice(k2, (0, 0), (1, local_hd)), (pa, 0))
            cva = jax.lax.dynamic_update_slice(
                cva, jax.lax.slice(v2, (0, 0), (1, local_hd)), (pa, 0))
            ckb = jax.lax.dynamic_update_slice(
                ckb, jax.lax.slice(k2, (1, 0), (2, local_hd)), (pb, 0))
            cvb = jax.lax.dynamic_update_slice(
                cvb, jax.lax.slice(v2, (1, 0), (2, local_hd)), (pb, 0))
        full = [jax.lax.all_gather(c, "tp", axis=1, tiled=True)
                for c in (cka, cva, ckb, cvb)]
        qa = jax.lax.slice(xa, (SB - 1, 0), (SB, D_MODEL)) @ wq
        qb = jax.lax.slice(xb, (SB - 1, 0), (SB, D_MODEL)) @ wq
        return ((qa @ full[0].T) @ full[1], (qb @ full[2].T) @ full[3])

    obs.add("read", _obligation(
        "serve_read", seq_read, dist_read, plan,
        in_specs=(P(), P(), w_spec, w_spec, P()), out_specs=(P(), P()),
        avals=(x_aval, x_aval, w_aval, w_aval, w_aval),
        names=("xa", "xb", "wk", "wv", "wq"),
        strategy="batched_decode", role="read", pos_class="full",
        description=f"batched prefill read: {SB} rotated steps, 2 requests"))
    return obs

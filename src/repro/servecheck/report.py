"""ServeReport: per-step decode verdicts stitched into one serving verdict.

Mirrors :class:`repro.gradcheck.TrainReport` for the serving path: one
:class:`StepResult` per decode step (plus the prefill ``read``), each
backed by a nested :class:`repro.api.Report` keyed by its obligation's
canonical key.  Steps in the same position class share an obligation, so
most step rows are ``cached`` — the dedup stats (``total_steps`` vs
``unique_obligations``) quantify the N-steps -> O(1)-obligations claim.
A bug run is ``ok`` only when the failure localizes to exactly the
injected step (its position-class siblings must stay clean).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, fields
from typing import Dict, List, Optional

from ..api.spec import Degree, degree_token, normalize_degree

SERVE_REPORT_SCHEMA = 1

VERDICTS = ("certificate", "refinement_error", "unexpected_relation",
            "error")


@dataclass
class StepResult:
    """One decode step's (or the read's) obligation outcome."""
    step: str                    # "step0".."stepN-1" | "read"
    pos_class: str               # position class (the dedup identity)
    obligation: str              # canonical obligation key
    verdict: str                 # nested report's verdict
    relation_ok: bool            # inferred R_o == cache-spec relation
    cached: bool                 # an earlier step paid for this obligation
    localized_op: Optional[str] = None   # failing G_s operator, if any

    def to_json(self) -> dict:
        return {f.name: getattr(self, f.name) for f in fields(self)}


@dataclass
class ServeReport:
    """Serving-path refinement verdict for (strategy, degree[, bug])."""
    strategy: str
    degree: Degree
    verdict: str                         # one of VERDICTS
    ok: bool                             # matches the run's expectation
    steps: List[StepResult]
    reports: Dict[str, dict]             # obligation key -> nested Report
                                         # JSON (+ "seams" detail)
    total_steps: int = 0                 # decode steps + the read
    unique_obligations: int = 0
    dedup_ratio: float = 0.0
    failing_steps: List[str] = field(default_factory=list)
    bug: Optional[str] = None
    bug_step: Optional[int] = None       # the decode step the bug targets
    wall_s: float = 0.0
    workers: int = 0
    cache: Optional[dict] = None         # persistent-cache stats (hits,
                                         # misses, entries) — timing-class
                                         # data, never in stable_summary
    pool: Optional[dict] = None          # runtime pool_stats() aggregate
                                         # (queue-wait vs on-worker wall)
                                         # — timing-class data, never in
                                         # stable_summary
    explanation: Optional[dict] = None   # proof-provenance roll-up
                                         # (``--explain`` only); omitted
                                         # from to_json when absent, never
                                         # in stable_summary
    schema_version: int = SERVE_REPORT_SCHEMA

    def __post_init__(self):
        self.degree = normalize_degree(self.degree)
        if self.verdict not in VERDICTS:
            raise ValueError(f"verdict must be one of {VERDICTS}, "
                             f"got {self.verdict!r}")

    def task_id(self) -> str:
        base = f"serve@{self.strategy}@deg{degree_token(self.degree)}"
        return f"{base}+{self.bug}" if self.bug else base

    # -- serialization ------------------------------------------------------
    def to_json(self) -> dict:
        out = {f.name: getattr(self, f.name) for f in fields(self)
               if f.name != "steps"}
        if out.get("explanation") is None:
            out.pop("explanation")
        out["steps"] = [s.to_json() for s in self.steps]
        out["timing"] = self.timing()
        return out

    @classmethod
    def from_json(cls, d: dict) -> "ServeReport":
        allowed = {f.name for f in fields(cls)}
        kw = {k: v for k, v in d.items() if k in allowed}
        kw["steps"] = [StepResult(**s) for s in d.get("steps", ())]
        return cls(**kw)

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    # -- views --------------------------------------------------------------
    def timing(self) -> dict:
        """Per-phase wall time aggregated over the unique obligations."""
        phases: Dict[str, float] = {}
        infer_s = 0.0
        for rep in self.reports.values():
            stats = rep.get("stats") or {}
            infer_s += float(stats.get("time_s", 0.0))
            for k, v in (stats.get("phase_s") or {}).items():
                phases[k] = phases.get(k, 0.0) + float(v)
        return {
            "wall_s": round(self.wall_s, 6),
            "infer_s_sum": round(infer_s, 6),
            "phase_s_sum": {k: round(v, 6)
                            for k, v in sorted(phases.items())},
        }

    def stable_summary(self) -> dict:
        """Deterministic fields only — golden-diff material."""
        return {
            "verdict": self.verdict,
            "ok": self.ok,
            "failing_steps": list(self.failing_steps),
            "total_steps": self.total_steps,
            "unique_obligations": self.unique_obligations,
            "dedup_ratio": self.dedup_ratio,
            "steps": [{"step": s.step, "pos_class": s.pos_class,
                       "obligation": s.obligation, "verdict": s.verdict,
                       "relation_ok": s.relation_ok, "cached": s.cached}
                      for s in self.steps],
        }

    def to_markdown(self) -> str:
        lines = [
            f"### serve@{self.strategy} @ deg{degree_token(self.degree)}"
            + (f" (bug={self.bug}@step{self.bug_step})" if self.bug else ""),
            "",
            "| step | class | verdict | relation | cached | localized op |",
            "|------|-------|---------|----------|--------|--------------|",
        ]
        for s in self.steps:
            lines.append(
                f"| {s.step} | {s.pos_class} | {s.verdict} "
                f"| {'ok' if s.relation_ok else '**MISMATCH**'} "
                f"| {'yes' if s.cached else '-'} "
                f"| {s.localized_op or '-'} |")
        lines.append("")
        lines.append(
            f"**{self.verdict}** — {self.total_steps} serving block(s) "
            f"proved by {self.unique_obligations} obligation(s) "
            f"(dedup {self.dedup_ratio}x) in {self.wall_s:.2f}s.")
        if self.failing_steps:
            lines.append(f"Failing steps: {self.failing_steps}.")
        return "\n".join(lines)

"""KV-cache sharding relations derived from a :class:`MeshPlan`.

A serving KV cache is a ``(seq, feat)`` buffer per layer: rows are token
positions, columns are (flattened) head features.  The two production
layouts shard exactly one of those dims:

  ``heads``  tensor-parallel serving — every rank holds every position but
             only its head slice (``cache_feat`` -> ``tp``).  Reads gather
             on the feature dim; writes are purely local.
  ``seq``    sequence-parallel cache — every rank owns a contiguous block
             of positions (``cache_seq`` -> the sequence axis).  Writes are
             rank-conditional (only the owner's ``dynamic_update_slice``
             lands); reads gather on the position dim.

``cache_rules`` extends the plan's logical-axis table with the two cache
axes, so obligations derive the cache ``PartitionSpec`` (and hence R_i /
the expected R_o) from the *same* ``MeshPlan`` vocabulary modelcheck uses
for weights and activations, rather than hand-writing specs per strategy.
``cache_relation`` turns the spec into the concrete clean Term the
scheduler's seam check compares against (identical machinery to
modelcheck's block seams).
"""
from __future__ import annotations

from jax.sharding import PartitionSpec as P

from ..modelcheck.stitch import expected_output_relation
from ..sharding.specs import MeshPlan, ShardingRules, plan_rules

# logical axes of a (seq, feat) KV-cache buffer
CACHE_AXES = ("cache_seq", "cache_feat")

CACHE_LAYOUTS = ("heads", "seq")


def seq_parallel_plan(degree: int) -> MeshPlan:
    """A one-axis ``sp`` mesh plan for sequence-parallel caches.

    ``parse_plan`` deliberately restricts CLI tokens to dp/tp; the cache
    sequence axis is a serving-only concept, so servecheck constructs the
    plan directly — weights stay replicated (every rule maps to None) and
    only the cache axes (added by :func:`cache_rules`) touch the mesh.
    """
    if degree < 2:
        raise ValueError(f"sp plan needs degree >= 2, got {degree}")
    return MeshPlan(f"sp{degree}", (("sp", degree),), plan_rules({}))


def cache_rules(plan: MeshPlan, layout: str) -> ShardingRules:
    """The plan's logical-axis rules extended with the KV-cache axes."""
    if layout not in CACHE_LAYOUTS:
        raise ValueError(f"cache layout must be one of {CACHE_LAYOUTS}, "
                         f"got {layout!r}")
    axes = plan.mesh_axes
    tp = "tp" if "tp" in axes else None
    sp = "sp" if "sp" in axes else None
    if layout == "heads":
        return plan.rules.with_(cache_seq=None, cache_feat=tp)
    return plan.rules.with_(cache_seq=sp or tp, cache_feat=None)


def cache_spec(plan: MeshPlan, layout: str) -> P:
    """PartitionSpec of a (seq, feat) cache buffer under the plan."""
    return cache_rules(plan, layout).spec_for(CACHE_AXES)


def cache_relation(base_name: str, local_shape, dtype: str, plan: MeshPlan,
                   layout: str):
    """The clean Term a cache's spec promises: the nested per-rank concat
    (sharded dims) at replica coordinate 0 (unsharded dims) — what the
    scheduler's seam check compares the inferred R_o against."""
    return expected_output_relation(base_name, local_shape, dtype,
                                    cache_spec(plan, layout),
                                    plan.mesh_axes)

"""repro.servecheck — serving-path (sharded KV-cache decode) verification.

modelcheck proves the *training-shaped* forward, gradcheck the backward;
production inference runs a third program: incremental decode over a
sharded KV cache.  Its correctness argument — *N decode steps chained
over the cache refine full-sequence prefill* — is exactly a refinement
claim, and this subsystem verifies it:

    from repro.servecheck import check_serve
    report = check_serve("tp_decode")             # -> ServeReport
    report = check_serve("sp_cache", bug="pos_off_by_one", degree=2)
    report.failing_steps                          # ["step4"] — localized

Pipeline:

  * ``relations``      derives the KV-cache PartitionSpec (and the clean
                       relation the seam check expects) from the same
                       :class:`MeshPlan` vocabulary modelcheck uses —
                       ``heads`` (feature-sharded, TP serving) and
                       ``seq`` (row-sharded, sequence-parallel cache)
                       layouts.
  * ``obligations``    the ``serve@strategy`` registry — per-decode-step
                       write obligations deduped by *position class*
                       (N steps -> O(1) obligations) plus one prefill
                       ``read`` obligation proving the chained steps
                       compose (the ``dus_concat``/``dus_unfold`` lemmas
                       flatten the update chain into the prefill concat),
                       for tp_decode, sp_cache and batched_decode, with
                       the three injected serving bug classes.
  * ``schedule``       fans unique obligations across the supervised
                       runtime pool (persistent-cache keys
                       ``serve:{strategy}-{digest}``) and stitches
                       per-step reports into one :class:`ServeReport`.
  * ``report``         the nested, JSON-ready verdict (schema-versioned,
                       per-step localization + dedup stats).
"""
from .obligations import (SERVE_STRATEGIES, ServeStrategy,
                          get_serve_strategy, list_serve_bugs,
                          list_serve_strategies, register_serve_strategy)
from .relations import (CACHE_AXES, CACHE_LAYOUTS, cache_relation,
                        cache_rules, cache_spec, seq_parallel_plan)
from .report import SERVE_REPORT_SCHEMA, ServeReport, StepResult
from .schedule import check_serve, run_serve_obligations

__all__ = [
    "SERVE_STRATEGIES", "ServeStrategy", "get_serve_strategy",
    "list_serve_bugs", "list_serve_strategies", "register_serve_strategy",
    "CACHE_AXES", "CACHE_LAYOUTS", "cache_relation", "cache_rules",
    "cache_spec", "seq_parallel_plan",
    "SERVE_REPORT_SCHEMA", "ServeReport", "StepResult",
    "check_serve", "run_serve_obligations",
]

"""Scheduler: fan unique serving obligations across the shared runtime.

``check_serve`` is the subsystem entry point.  Unique obligations (after
position-class dedup) are verified in-process or on a supervised spawn
pool (:mod:`repro.runtime`) — workers receive only picklable
``(strategy, degree, bug, key)`` tuples and rebuild the obligation from
the deterministic registry, so nothing unpicklable crosses the boundary
and reports stay byte-identical for any worker count.  ``timeout_s``
budgets each obligation individually from the moment it starts on a
worker; ``cache=`` attaches the persistent certificate cache keyed by
:func:`repro.runtime.serve_cache_key` (strategy + obligation content
digest), so a warm re-run replays every serve verdict from disk.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Dict, List, Optional, Tuple

from ..api.report import Report
from ..api.runner import _engine_opts
from ..api.spec import Degree, task_id
from ..core import (RefinementError, capture, capture_spmd, check_refinement,
                    expand_spmd)
from ..core.explain import aggregate_explanations
from ..core.terms import pretty
from ..modelcheck.obligations import Obligation
from ..modelcheck.stitch import expected_output_relation
from ..obs import trace as obs_trace
from ..runtime import (RuntimeTask, pool_stats, resolve_cache, run_tasks,
                       serve_cache_key)
from .obligations import ServeStrategy, get_serve_strategy
from .report import ServeReport, StepResult

DEFAULT_TIMEOUT_S = 600.0


def _expected_for(ob: Obligation, entry: ServeStrategy) -> str:
    bug = dict(ob.structure).get("bug", "-")
    return "certificate" if bug == "-" else entry.bug_spec(bug).expected


def _verify_obligation(ob: Obligation, name: str, expected: str,
                       engine_opts: Optional[dict] = None) -> dict:
    """Verify one serving obligation; returns a JSON-ready nested Report
    dict with the cache seam check (inferred R_o vs the relation the
    cache's PartitionSpec promises) attached — the seam is what catches
    the paper's silent-misplacement mode, where a wrong-axis collective
    still *refines* but assembles the cache off-spec."""
    bug = dict(ob.structure).get("bug", "-")
    bug = None if bug == "-" else bug
    degree = tuple(s for _, s in ob.mesh_axes)
    t0 = time.perf_counter()
    try:
        with _engine_opts(engine_opts) as eo:
            gs = capture(ob.seq_fn, list(ob.avals), list(ob.input_names))
            cap = capture_spmd(ob.dist_fn, dict(ob.mesh_axes),
                               list(ob.in_specs), list(ob.avals),
                               list(ob.input_names))
            gd, r_i = expand_spmd(cap)
            cert = check_refinement(gs, gd, r_i, max_nodes=eo.max_nodes,
                                    explain=eo.explain)
    except RefinementError as e:
        return Report(
            case=name, degree=degree, bug=bug,
            verdict="refinement_error", expected=expected,
            ok=expected == "refinement_error", localization=e.payload(),
            explanation=getattr(e, "explanation", None),
            wall_s=round(time.perf_counter() - t0, 6)).to_json()
    except Exception as e:  # noqa: BLE001 — capture/engine failure -> verdict
        return Report(
            case=name, degree=degree, bug=bug,
            verdict="error", expected=expected, ok=False,
            error=f"{type(e).__name__}: {e}",
            wall_s=round(time.perf_counter() - t0, 6)).to_json()

    # seam check: each distributed cache/read output must assemble exactly
    # as its PartitionSpec promises the next decode step's input relation
    n_ranks = 1
    for _, s in ob.mesh_axes:
        n_ranks *= s
    seams, seams_ok = [], True
    for j, (out_name, ospec) in enumerate(zip(gs.outputs, ob.out_specs)):
        gd_out = gd.outputs[j * n_ranks]
        base = gd_out.split("@")[0]
        expect = expected_output_relation(
            base, gd.shapes[gd_out], gd.dtypes[gd_out], ospec,
            dict(ob.mesh_axes))
        got = cert.r_o.get(out_name)
        ok = got is expect               # Terms are hash-consed: identity
        seams_ok &= ok
        seams.append({"output": out_name, "ok": ok,
                      "expected": pretty(expect, 999),
                      "got": None if got is None else pretty(got, 999)})
    cert_json = cert.to_json()
    ok = seams_ok if expected == "certificate" else \
        (expected == "unexpected_relation" and not seams_ok)
    d = Report(
        case=name, degree=degree, bug=bug,
        verdict="certificate", expected=expected, ok=ok,
        r_o=cert_json["r_o"], stats=cert_json["stats"],
        explanation=cert.explanation,
        wall_s=round(time.perf_counter() - t0, 6)).to_json()
    d["seams"] = seams
    return d


def _pool_task(strategy: str, degree: Degree, bug: Optional[str],
               key: str, engine_opts: Optional[dict]) -> dict:
    """Pool worker: rebuild the (deterministic) obligation set and verify
    the obligation addressed by ``key``."""
    entry = get_serve_strategy(strategy)
    ob = entry.build(degree=degree, bug=bug).unique[key]
    base = f"serve@{task_id(strategy, degree, bug)}"
    return _verify_obligation(ob, f"{base}:{key}",
                              _expected_for(ob, entry), engine_opts)


def _outcome_report(ob: Obligation, entry: ServeStrategy, name: str,
                    outcome) -> dict:
    """Convert a runtime outcome into this obligation's report dict."""
    if outcome.ok:
        d = dict(outcome.value)
        if outcome.cache == "hit":
            # cache entries are content-addressed — re-label for this run
            d["case"] = name
        info = outcome.runtime_info()
        if info:
            d["runtime"] = info
        return d
    verdict = "timeout" if outcome.status == "timeout" else "error"
    return Report(
        case=name, degree=tuple(s for _, s in ob.mesh_axes), bug=None,
        verdict=verdict, expected=_expected_for(ob, entry), ok=False,
        error=outcome.error, wall_s=round(outcome.wall_s, 6),
        runtime=outcome.runtime_info() or None).to_json()


def run_serve_obligations(strategy: str, degree: Degree,
                          bug: Optional[str] = None,
                          workers: Optional[int] = None,
                          engine_opts: Optional[dict] = None,
                          timeout_s: float = DEFAULT_TIMEOUT_S,
                          cache=None
                          ) -> Tuple[Dict[str, dict], int, Optional[dict],
                                     dict]:
    """Verify the strategy's unique serving obligations.

    Returns ``({obligation key: report dict}, workers actually used,
    cache stats or None, runtime pool stats)``.  ``timeout_s`` budgets
    each obligation individually; ``cache`` takes anything
    :func:`repro.runtime.resolve_cache` accepts.
    """
    entry = get_serve_strategy(strategy)
    obset = entry.build(degree=degree, bug=bug)
    keys = obset.keys_in_order()
    if workers is None:
        # dedup leaves a handful of obligations, most sub-second; fan out
        # only when there is genuinely parallel work
        workers = min(4, len(keys)) if len(keys) > 4 else 1
    cache = resolve_cache(cache)
    base = f"serve@{task_id(strategy, degree, bug)}"
    tasks = []
    for key in keys:
        ob = obset.unique[key]
        tasks.append(RuntimeTask(
            key=key, fn=_pool_task,
            args=(strategy, degree, bug, key, engine_opts),
            budget_s=timeout_s,
            cache_key=None if cache is None
            else serve_cache_key(strategy, key, engine_opts),
            local_fn=partial(_verify_obligation, ob, f"{base}:{key}",
                             _expected_for(ob, entry), engine_opts)))
    used = min(workers, len(keys)) or 1
    # spawn, not fork: the parent has traced jax by now (see modelcheck)
    outcomes = run_tasks(tasks, used, mp_method="spawn", cache=cache)
    reports = {key: _outcome_report(obset.unique[key], entry,
                                    f"{base}:{key}", outcomes[key])
               for key in keys}
    cache_stats = None if cache is None else {
        "dir": cache.dir,
        "hits": sum(1 for o in outcomes.values() if o.cache == "hit"),
        "misses": sum(1 for o in outcomes.values() if o.cache == "miss"),
        "entries": len(cache),
        "recovered_corrupt": cache.recovered_corrupt}
    return reports, used, cache_stats, pool_stats(outcomes)


def check_serve(strategy: str, *, degree: Optional[Degree] = None,
                bug: Optional[str] = None, workers: Optional[int] = None,
                engine_opts: Optional[dict] = None,
                timeout_s: float = DEFAULT_TIMEOUT_S,
                cache=None) -> ServeReport:
    """Serving-path refinement check: decode steps + prefill read, deduped
    by position class, verified, stitched.

    Returns a :class:`ServeReport`; never raises on verification failures
    (they become step verdicts) — only on caller mistakes (unknown
    strategy / bug / degree).  ``cache`` attaches the persistent
    certificate cache (see :func:`repro.runtime.resolve_cache`).
    """
    t0 = time.perf_counter()
    entry = get_serve_strategy(strategy)
    if degree is None:
        degree = entry.degrees[0]
    degree = entry.validate_degree(degree)
    if bug is not None and bug not in entry.bug_names():
        raise ValueError(
            f"bug `{bug}` is not hosted by serve strategy `{strategy}` "
            f"(hosted: {sorted(entry.bug_names()) or '-'})")
    obset = entry.build(degree=degree, bug=bug)
    obs_trace.event("dedup", cat="engine", subsystem="servecheck",
                    total=obset.total_blocks, unique=obset.n_unique)
    reports, used, cache_stats, pstats = run_serve_obligations(
        strategy, degree, bug=bug, workers=workers,
        engine_opts=engine_opts, timeout_s=timeout_s, cache=cache)

    steps: List[StepResult] = []
    failing: List[str] = []
    seen: set = set()
    for name, key in obset.blocks:
        rep = reports[key]
        ob = obset.unique[key]
        seams = rep.get("seams") or []
        relation_ok = all(s["ok"] for s in seams) if seams else \
            rep["verdict"] == "certificate"
        loc = rep.get("localization") or {}
        steps.append(StepResult(
            step=name, pos_class=dict(ob.structure)["pos_class"],
            obligation=key, verdict=rep["verdict"],
            relation_ok=relation_ok, cached=key in seen,
            localized_op=loc.get("op_name")))
        seen.add(key)
        if rep["verdict"] != "certificate" or not relation_ok:
            failing.append(name)

    verdicts = {s.verdict for s in steps}
    if verdicts & {"error", "timeout"}:
        verdict = "error"
    elif "refinement_error" in verdicts:
        verdict = "refinement_error"
    elif any(not s.relation_ok for s in steps):
        verdict = "unexpected_relation"
    else:
        verdict = "certificate"

    bug_step = entry.bug_steps.get(bug) if bug else None
    if bug is None:
        ok = verdict == "certificate"
    else:
        # the injected serving bug must surface the way its BugSpec
        # declares (refinement_error raise, or unexpected_relation via
        # the cache seam) AND localize to exactly its decode step — the
        # position-class siblings of the bugged step must stay clean
        ok = (verdict == entry.bug_spec(bug).expected
              and failing == [f"step{bug_step}"])

    return ServeReport(
        strategy=strategy, degree=degree, verdict=verdict, ok=ok,
        steps=steps, reports=dict(reports),
        total_steps=obset.total_blocks,
        unique_obligations=obset.n_unique,
        dedup_ratio=round(obset.dedup_ratio, 3),
        failing_steps=failing, bug=bug, bug_step=bug_step,
        wall_s=round(time.perf_counter() - t0, 6), workers=used,
        cache=cache_stats, pool=pstats,
        explanation=aggregate_explanations(reports))

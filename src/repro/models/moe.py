"""Mixture-of-Experts family (mixtral-8x7b, kimi-k2-1t).

Routing uses sort-based dispatch with a static per-expert capacity
(dropless-style up to the capacity factor): tokens are replicated top_k
times, sorted by expert id, packed into an (E, C, D) buffer, processed with
a batched expert GEMM (expert dim sharded over the `model` mesh axis =
expert parallelism), then combined with router gates. Compute is
proportional to *active* experts (6·N_active·D roofline), unlike dense
all-expert dispatch.

The auxiliary load-balance loss (Switch/GShard style) is returned alongside
the output — its TP scaling is the subject of paper bug #2.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..sharding.specs import constrain
from .config import ModelConfig
from . import layers as L
from . import dense


def moe_mlp_spec(cfg: ModelConfig) -> dict:
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    return {
        "router": L.Leaf((d, e), ("embed", "experts")),
        "wg": L.Leaf((e, d, fe), ("experts", "embed_fsdp", "expert_ff")),
        "wu": L.Leaf((e, d, fe), ("experts", "embed_fsdp", "expert_ff")),
        "wd": L.Leaf((e, fe, d), ("experts", "expert_ff", "embed_fsdp")),
    }


def block_spec(cfg: ModelConfig) -> dict:
    return {
        "pre_attn": L.norm_spec(cfg.d_model),
        "attn": L.attn_spec(cfg),
        "pre_mlp": L.norm_spec(cfg.d_model),
        "moe": moe_mlp_spec(cfg),
    }


def model_spec(cfg: ModelConfig) -> dict:
    P = len(cfg.pattern)
    reps = cfg.n_layers // P
    spec = dict(L.embed_spec(cfg))
    spec["blocks"] = {f"p{i}": L.stack_spec(block_spec(cfg), reps)
                      for i in range(P)}
    spec["final_norm"] = L.norm_spec(cfg.d_model)
    return spec


def moe_mlp(p, cfg: ModelConfig, x, capacity_factor: float = 1.25):
    """x: (B, S, D) -> (y, aux_loss)."""
    B, S, D = x.shape
    E, K, Fe = cfg.n_experts, cfg.top_k, cfg.moe_d_ff
    T = B * S
    xt = x.reshape(T, D)
    logits = (xt @ p["router"].astype(jnp.float32))      # (T, E) fp32 routing
    probs = jax.nn.softmax(logits, axis=-1)
    top_logits, top_idx = jax.lax.top_k(logits, K)       # (T, K)
    gates = jax.nn.softmax(top_logits, axis=-1).astype(x.dtype)

    # ---- sort-based dispatch with static capacity -----------------------
    flat_e = top_idx.reshape(T * K)                       # expert id per row
    flat_t = jnp.repeat(jnp.arange(T), K)                 # source token
    flat_g = gates.reshape(T * K)
    order = jnp.argsort(flat_e)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    offsets = jnp.cumsum(counts) - counts                 # start of each group
    pos_in_e = jnp.arange(T * K) - offsets[se]
    C = int(math.ceil(T * K / E * capacity_factor))
    C = max(C, 1)
    keep = pos_in_e < C
    buf_idx = jnp.where(keep, se * C + pos_in_e, E * C)   # overflow slot
    buf = jnp.zeros((E * C + 1, D), x.dtype).at[buf_idx].set(xt[st])
    buf = buf[:-1].reshape(E, C, D)
    buf = constrain(buf, ("experts", None, "embed"))

    # ---- expert computation (batched GEMM over expert dim) --------------
    h = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"].astype(x.dtype))
    h = jax.nn.silu(h) * u
    h = constrain(h, ("experts", None, "expert_ff"))
    out = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(x.dtype))
    out = constrain(out, ("experts", None, "embed"))

    # ---- combine ---------------------------------------------------------
    rows = out.reshape(E * C, D)
    gathered = jnp.where(keep[:, None],
                         rows[jnp.clip(buf_idx, 0, E * C - 1)], 0.0)
    y = jnp.zeros((T, D), x.dtype).at[st].add(gathered * sg[:, None])
    y = y.reshape(B, S, D)

    # ---- auxiliary load-balance loss (paper bug #2 family) --------------
    frac = counts.astype(jnp.float32) / (T * K)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * mean_prob) * cfg.aux_loss_coef
    return constrain(y, ("batch", "seq", "embed")), aux


def _apply_block(p, cfg, x, positions, angles, role):
    h, _ = L.attention(p["attn"], cfg,
                       L.rmsnorm(x, p["pre_attn"], cfg.norm_eps),
                       positions, causal=True,
                       window=cfg.window if role == "local" else 0,
                       angles=angles)
    x = x + h
    y, aux = moe_mlp(p["moe"], cfg, L.rmsnorm(x, p["pre_mlp"], cfg.norm_eps))
    return x + y, aux


def forward(params, cfg: ModelConfig, tokens, positions=None,
            return_hidden=False, **_):
    B, S = tokens.shape
    x = L.embed(params, cfg, tokens)
    if positions is None:
        positions = jnp.arange(S)
    angles = L.rope_angles(jnp.broadcast_to(positions[None], (B, S)),
                           cfg.hd, cfg.rope_theta)
    P = len(cfg.pattern)

    ab = jax.checkpoint(_apply_block, static_argnums=(1, 5)) \
        if cfg.remat else _apply_block

    def body(carry, blk):
        xc, aux_acc = carry
        for i in range(P):
            xc, aux = ab(blk[f"p{i}"], cfg, xc, positions, angles,
                         cfg.pattern[i])
            aux_acc = aux_acc + aux
        return (xc, aux_acc), None

    init = (x, jnp.zeros((), jnp.float32))
    wrapped = body  # per-block checkpoints
    if cfg.scan_layers:
        (x, aux_total), _ = jax.lax.scan(wrapped, init, params["blocks"])
    else:
        carry = init
        for g in range(cfg.n_layers // P):
            blk = jax.tree.map(lambda a, g=g: a[g], params["blocks"])
            carry, _ = wrapped(carry, blk)
        x, aux_total = carry
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, {"aux_loss": aux_total}
    logits = L.unembed(params, cfg, x)
    return logits, {"aux_loss": aux_total}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, abstract=False):
    P = len(cfg.pattern)
    reps = cfg.n_layers // P
    mk = (lambda s: jax.ShapeDtypeStruct(s, cfg.jdtype)) if abstract \
        else (lambda s: jnp.zeros(s, cfg.jdtype))
    cache = {}
    for i, role in enumerate(cfg.pattern):
        C = dense.cache_size(cfg, role, max_seq)
        shape = (reps, batch, C, cfg.n_kv_heads, cfg.hd)
        cache[f"p{i}"] = (mk(shape), mk(shape))
    return cache


def decode_step(params, cfg: ModelConfig, cache, token, pos):
    x = L.embed(params, cfg, token)

    def body(xc, blk_and_cache):
        blk, caches = blk_and_cache
        new_caches = {}
        for i, role in enumerate(cfg.pattern):
            p = blk[f"p{i}"]
            ck, cv = caches[f"p{i}"]
            h = L.rmsnorm(xc, p["pre_attn"], cfg.norm_eps)
            h, ck, cv = L.attention_decode(
                p["attn"], cfg, h, ck, cv, pos,
                window=cfg.window if role == "local" else 0)
            xc = xc + h
            y, _ = moe_mlp(p["moe"], cfg,
                           L.rmsnorm(xc, p["pre_mlp"], cfg.norm_eps))
            xc = xc + y
            new_caches[f"p{i}"] = (ck, cv)
        return xc, new_caches

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(params, cfg, x), new_cache

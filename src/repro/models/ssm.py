"""Mamba2 (state-space duality / SSD) family — attention-free.

Implements the chunked SSD algorithm (Dao & Gu, arXiv:2405.21060): the
sequence is split into chunks; within a chunk the semiseparable matrix is
applied quadratically (MXU-friendly), across chunks a linear recurrence on
the (H, N, P) state is scanned. Decode is O(1): a single state update.

TPU adaptation notes (DESIGN.md §2): the CUDA kernel's warp-level scan is
replaced by chunk-local einsums (MXU) + ``lax.scan`` over chunk states; the
depthwise causal conv1d is expressed as shifted adds (no im2col), which XLA
fuses on TPU.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..sharding.specs import constrain
from .config import ModelConfig
from . import layers as L


def block_spec(cfg: ModelConfig) -> dict:
    d, din = cfg.d_model, cfg.d_inner
    H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    conv_ch = din + 2 * N   # x plus single-group B and C
    return {
        "norm": L.norm_spec(d),
        "in_proj": L.Leaf((d, 2 * din + 2 * N + H), ("embed_fsdp", "heads")),
        "conv_w": L.Leaf((cfg.ssm_conv, conv_ch), ("conv", "heads")),
        "conv_b": L.Leaf((conv_ch,), ("heads",), scale=0.0),
        "A_log": L.Leaf((H,), ("heads",), scale=-1.0),
        "D": L.Leaf((H,), ("heads",), scale=-1.0),
        "dt_bias": L.Leaf((H,), ("heads",), scale=0.0),
        "out_norm": L.Leaf((din,), ("heads",), scale=0.0),
        "out_proj": L.Leaf((din, d), ("heads", "embed_fsdp")),
    }


def model_spec(cfg: ModelConfig) -> dict:
    spec = dict(L.embed_spec(cfg))
    spec["blocks"] = L.stack_spec(block_spec(cfg), cfg.n_layers)
    spec["final_norm"] = L.norm_spec(cfg.d_model)
    return spec


def _causal_conv(x, w, b):
    """Depthwise causal conv: x (B,S,C), w (K,C) — as K shifted adds."""
    K = w.shape[0]
    out = x * w[K - 1]
    for k in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, :-k]
        out = out + shifted * w[K - 1 - k]
    return jax.nn.silu(out + b)


def _split_proj(cfg, proj):
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :din]
    xBC = proj[..., din:2 * din + 2 * N]
    dt = proj[..., 2 * din + 2 * N:]
    return z, xBC, dt


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """SSD scan. x: (B,S,H,P), dt: (B,S,H) (post-softplus), A: (H,) < 0,
    Bm/Cm: (B,S,N). Returns (y (B,S,H,P), final_state (B,H,N,P))."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    nc = s // chunk
    assert nc * chunk == s, f"seq {s} not divisible by chunk {chunk}"
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = Bm.reshape(b, nc, chunk, n)
    Cc = Cm.reshape(b, nc, chunk, n)

    xdt = xc * dtc[..., None]                       # (b,c,q,h,p)
    dA = dtc * A[None, None, None, :]               # (b,c,q,h) negative
    cs = jnp.cumsum(dA, axis=2)                     # within-chunk cumsum

    # intra-chunk (quadratic within chunk, MXU-friendly)
    Lmat = jnp.exp(cs[:, :, :, None, :] - cs[:, :, None, :, :])  # (b,c,q,t,h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    Lmat = jnp.where(tri[None, None, :, :, None], Lmat, 0.0)
    CB = jnp.einsum("bcqn,bctn->bcqt", Cc, Bc)      # (b,c,q,t)
    y_diag = jnp.einsum("bcqt,bcqth,bcthp->bcqhp", CB, Lmat, xdt)

    # chunk states + inter-chunk recurrence
    decay_out = jnp.exp(cs[:, :, -1:, :] - cs)      # (b,c,q,h)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", Bc, decay_out, xdt)
    chunk_decay = jnp.exp(cs[:, :, -1, :])          # (b,c,h)

    def scan_fn(S, inp):
        st, dec = inp
        S_new = S * dec[:, :, None, None] + st
        return S_new, S                              # emit state *before*

    S0 = jnp.zeros((b, h, n, p), states.dtype) if init_state is None \
        else init_state.astype(states.dtype)
    final, S_prev = jax.lax.scan(
        scan_fn, S0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    S_prev = jnp.moveaxis(S_prev, 0, 1)              # (b,c,h,n,p)

    decay_in = jnp.exp(cs)                           # (b,c,q,h)
    y_off = jnp.einsum("bcqn,bchnp,bcqh->bcqhp", Cc, S_prev, decay_in)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def _apply_block(p, cfg, x):
    B, S, D = x.shape
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = L.rmsnorm(x, p["norm"], cfg.norm_eps)
    proj = h @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, proj)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs = xBC[..., :din].reshape(B, S, H, P)
    Bm = xBC[..., din:din + N]
    Cm = xBC[..., din + N:]
    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + xs * p["D"][None, None, :, None]
    y = y.reshape(B, S, din) * jax.nn.silu(z)
    y = L.rmsnorm(y, p["out_norm"], cfg.norm_eps)
    return x + (y @ p["out_proj"]).astype(x.dtype)


def forward(params, cfg: ModelConfig, tokens, positions=None,
            return_hidden=False, **_):
    x = L.embed(params, cfg, tokens)

    def body(xc, blk):
        return _apply_block(blk, cfg, xc), None

    wrapped = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(wrapped, x, params["blocks"])
    else:
        for l in range(cfg.n_layers):
            blk = jax.tree.map(lambda a, l=l: a[l], params["blocks"])
            x, _ = wrapped(x, blk)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, None
    return L.unembed(params, cfg, x), None


# ---------------------------------------------------------------------------
# Decode: O(1) state update per token
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, abstract=False):
    H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    conv_ch = cfg.d_inner + 2 * N
    mk = (lambda s, dt: jax.ShapeDtypeStruct(s, dt)) if abstract \
        else (lambda s, dt: jnp.zeros(s, dt))
    return {
        "ssm_state": mk((cfg.n_layers, batch, H, N, P), jnp.float32),
        "conv_state": mk((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_ch),
                         cfg.jdtype),
    }


def decode_step(params, cfg: ModelConfig, cache, token, pos):
    x = L.embed(params, cfg, token)     # (B, 1, D)
    B = x.shape[0]
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    def body(xc, blk_and_cache):
        p, (S_state, conv_state) = blk_and_cache
        h = L.rmsnorm(xc, p["norm"], cfg.norm_eps)
        proj = (h @ p["in_proj"])[:, 0]              # (B, ...)
        z, xBC, dt = _split_proj(cfg, proj)
        # conv: window = [conv_state ; xBC]
        win = jnp.concatenate([conv_state, xBC[:, None, :]], axis=1)
        conv_out = jnp.einsum("bkc,kc->bc", win, p["conv_w"]) + p["conv_b"]
        conv_out = jax.nn.silu(conv_out)
        new_conv = win[:, 1:]
        xs = conv_out[..., :din].reshape(B, H, P)
        Bm = conv_out[..., din:din + N]
        Cm = conv_out[..., din + N:]
        dtv = jax.nn.softplus(dt + p["dt_bias"])     # (B, H)
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        dA = jnp.exp(dtv * A[None, :])               # (B, H)
        upd = jnp.einsum("bn,bh,bhp->bhnp", Bm, dtv, xs)
        S_new = S_state * dA[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", Cm, S_new.astype(Cm.dtype))
        y = y + xs * p["D"][None, :, None]
        y = y.reshape(B, 1, din) * jax.nn.silu(z)[:, None]
        y = L.rmsnorm(y, p["out_norm"], cfg.norm_eps)
        xc = xc + y @ p["out_proj"]
        return xc, (S_new, new_conv)

    x, new_cache = jax.lax.scan(
        body, x, (params["blocks"],
                  (cache["ssm_state"], cache["conv_state"])))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params, cfg, x)
    return logits, {"ssm_state": new_cache[0], "conv_state": new_cache[1]}

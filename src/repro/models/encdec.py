"""Encoder-decoder (Whisper) family — transformer backbone only.

The mel-spectrogram + conv frontend is a STUB per the assignment: the model
consumes precomputed frame embeddings (B, frames, D) supplied by
``input_specs``. The encoder is bidirectional; the decoder has causal
self-attention plus cross-attention over encoder states. Sinusoidal
positional embeddings (no RoPE), biases on (whisper-style).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..sharding.specs import constrain
from .config import ModelConfig
from . import layers as L


def _enc_block_spec(cfg) -> dict:
    return {
        "pre_attn": L.norm_spec(cfg.d_model),
        "attn": L.attn_spec(cfg),
        "pre_mlp": L.norm_spec(cfg.d_model),
        "mlp": L.mlp_spec(cfg, geglu=False),
    }


def _dec_block_spec(cfg) -> dict:
    return {
        "pre_self": L.norm_spec(cfg.d_model),
        "self_attn": L.attn_spec(cfg),
        "pre_cross": L.norm_spec(cfg.d_model),
        "cross_attn": L.attn_spec(cfg),
        "pre_mlp": L.norm_spec(cfg.d_model),
        "mlp": L.mlp_spec(cfg, geglu=False),
    }


def model_spec(cfg: ModelConfig) -> dict:
    spec = dict(L.embed_spec(cfg))
    spec["enc_blocks"] = L.stack_spec(_enc_block_spec(cfg),
                                      cfg.encoder_layers)
    spec["dec_blocks"] = L.stack_spec(_dec_block_spec(cfg), cfg.n_layers)
    spec["enc_norm"] = L.norm_spec(cfg.d_model)
    spec["final_norm"] = L.norm_spec(cfg.d_model)
    return spec


def sinusoid(S: int, d: int, dtype):
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)],
                           axis=-1).astype(dtype)


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, F, D) precomputed frontend embeddings (stub)."""
    B, F, D = frames.shape
    x = frames.astype(cfg.jdtype) + sinusoid(F, D, cfg.jdtype)[None]
    positions = jnp.arange(F)

    def body(xc, blk):
        h, _ = L.attention(blk["attn"], cfg,
                           L.rmsnorm(xc, blk["pre_attn"], cfg.norm_eps),
                           positions, causal=False, window=0, angles=None)
        xc = xc + h
        xc = xc + L.mlp(blk["mlp"],
                        L.rmsnorm(xc, blk["pre_mlp"], cfg.norm_eps))
        return constrain(xc, ("batch", "seq", "embed")), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block(p, cfg, x, enc, positions):
    h, _ = L.attention(p["self_attn"], cfg,
                       L.rmsnorm(x, p["pre_self"], cfg.norm_eps),
                       positions, causal=True, window=0, angles=None)
    x = x + h
    h, _ = L.attention(p["cross_attn"], cfg,
                       L.rmsnorm(x, p["pre_cross"], cfg.norm_eps),
                       positions, causal=False, window=0,
                       kv_override=enc, angles=None)
    x = x + h
    x = x + L.mlp(p["mlp"], L.rmsnorm(x, p["pre_mlp"], cfg.norm_eps))
    return constrain(x, ("batch", "seq", "embed"))


def forward(params, cfg: ModelConfig, tokens, frames=None, positions=None,
            return_hidden=False, **_):
    """Teacher-forced training / prefill: returns (logits, None)."""
    B, S = tokens.shape
    enc = encode(params, cfg, frames)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.jdtype)
    x = x + sinusoid(S, cfg.d_model, cfg.jdtype)[None]
    if positions is None:
        positions = jnp.arange(S)

    def body(xc, blk):
        return _dec_block(blk, cfg, xc, enc, positions), None

    wrapped = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(wrapped, x, params["dec_blocks"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, None
    return L.unembed(params, cfg, x), None


# ---------------------------------------------------------------------------
# Decode: self-attn cache + per-layer cached cross K/V
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, abstract=False):
    kvh = cfg.n_kv_heads
    mk = (lambda s: jax.ShapeDtypeStruct(s, cfg.jdtype)) if abstract \
        else (lambda s: jnp.zeros(s, cfg.jdtype))
    self_shape = (cfg.n_layers, batch, max_seq, kvh, cfg.hd)
    cross_shape = (cfg.n_layers, batch, cfg.encoder_frames, kvh, cfg.hd)
    return {
        "self_k": mk(self_shape), "self_v": mk(self_shape),
        "cross_k": mk(cross_shape), "cross_v": mk(cross_shape),
    }


def build_cross_cache(params, cfg: ModelConfig, enc):
    """Precompute per-layer cross-attention K/V from encoder states."""
    B, F, D = enc.shape

    def body(_, blk):
        k = (enc @ blk["cross_attn"]["wk"]).reshape(B, F, cfg.n_kv_heads,
                                                    cfg.hd)
        v = (enc @ blk["cross_attn"]["wv"]).reshape(B, F, cfg.n_kv_heads,
                                                    cfg.hd)
        if cfg.use_bias:
            v = v + blk["cross_attn"]["bv"].reshape(1, 1, cfg.n_kv_heads,
                                                    cfg.hd)
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, params["dec_blocks"])
    return ks, vs


def decode_step(params, cfg: ModelConfig, cache, token, pos):
    B = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.jdtype)
    pe = sinusoid(cache["self_k"].shape[2], cfg.d_model, cfg.jdtype)
    x = x + jax.lax.dynamic_slice(pe, (pos, 0), (1, cfg.d_model))[None]

    def body(xc, blk_and_cache):
        blk, (sk, sv, ck_, cv_) = blk_and_cache
        h = L.rmsnorm(xc, blk["pre_self"], cfg.norm_eps)
        # sinusoid positions are added at the embedding; no RoPE anywhere
        # in this family's forward, so none in decode either
        h, sk, sv = L.attention_decode(blk["self_attn"], cfg, h, sk, sv, pos,
                                       rope=False)
        xc = xc + h
        # cross attention against cached encoder K/V (no mask)
        h = L.rmsnorm(xc, blk["pre_cross"], cfg.norm_eps)
        q = (h @ blk["cross_attn"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.hd)
        if cfg.use_bias:
            q = q + blk["cross_attn"]["bq"].reshape(1, 1, cfg.n_heads, cfg.hd)
        ones = jnp.ones((B, 1, 1, ck_.shape[1]), bool)
        y = L.gqa_attend(q, ck_, cv_, ones)
        y = y @ blk["cross_attn"]["wo"]
        if cfg.use_bias:
            y = y + blk["cross_attn"]["bo"]
        xc = xc + y
        xc = xc + L.mlp(blk["mlp"], L.rmsnorm(xc, blk["pre_mlp"],
                                              cfg.norm_eps))
        return xc, (sk, sv)

    x, (nsk, nsv) = jax.lax.scan(
        body, x, (params["dec_blocks"],
                  (cache["self_k"], cache["self_v"],
                   cache["cross_k"], cache["cross_v"])))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params, cfg, x)
    return logits, {"self_k": nsk, "self_v": nsv,
                    "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}

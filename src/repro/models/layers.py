"""Shared model building blocks (pure JAX, config-driven).

Parameter trees are built from *leaf specs* — one source of truth giving
shape, logical sharding axes, and init scale — so random init (smoke tests),
abstract init (dry-run), and shardings all derive from the same structure.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.specs import constrain
from .config import ModelConfig


# ---------------------------------------------------------------------------
# Leaf specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Leaf:
    shape: tuple
    logical: tuple
    scale: float = 1.0          # stddev multiplier (fan-in scaling applied)
    dtype: Optional[str] = None


def is_leaf(x):
    return isinstance(x, Leaf)


def init_tree(spec, rng, dtype):
    leaves, treedef = jax.tree.flatten(spec, is_leaf=is_leaf)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for k, lf in zip(keys, leaves):
        dt = lf.dtype or dtype
        fan_in = lf.shape[-2] if len(lf.shape) >= 2 else lf.shape[-1]
        if lf.scale == 0.0:
            out.append(jnp.zeros(lf.shape, dt))
        elif lf.scale == -1.0:   # ones (norm scales)
            out.append(jnp.ones(lf.shape, dt))
        else:
            std = lf.scale / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, lf.shape, jnp.float32)
                        * std).astype(dt))
    return jax.tree.unflatten(treedef, out)


def abstract_tree(spec, dtype):
    return jax.tree.map(
        lambda lf: jax.ShapeDtypeStruct(lf.shape, lf.dtype or dtype),
        spec, is_leaf=is_leaf)


def logical_tree(spec):
    return jax.tree.map(lambda lf: lf.logical, spec, is_leaf=is_leaf)


def stacked(leaf: Leaf, n: int) -> Leaf:
    """Stack a leaf along a leading scan axis."""
    return Leaf((n,) + leaf.shape, ("layers",) + leaf.logical, leaf.scale,
                leaf.dtype)


def stack_spec(spec, n: int):
    return jax.tree.map(lambda lf: stacked(lf, n), spec, is_leaf=is_leaf)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def norm_spec(d: int) -> Leaf:
    return Leaf((d,), ("embed",), scale=0.0)


# ---------------------------------------------------------------------------
# RoPE (incl. M-RoPE for the VLM backbone)
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd // 2, dtype=jnp.float32)
                            / (hd // 2)))


def rope_angles(positions, hd: int, theta: float, mrope_sections=None):
    """positions: (..., S) int or (..., S, 3) for M-RoPE -> (..., S, hd//2)."""
    freqs = rope_freqs(hd, theta)
    if mrope_sections is None:
        return positions[..., None].astype(jnp.float32) * freqs
    # M-RoPE (Qwen2-VL): frequency bands partitioned into (t, h, w) sections,
    # each rotated by its own position stream.
    sec = mrope_sections
    assert sum(sec) == hd // 2
    parts = []
    off = 0
    for i, s in enumerate(sec):
        p = positions[..., i].astype(jnp.float32)
        parts.append(p[..., None] * freqs[off:off + s])
        off += s
    return jnp.concatenate(parts, axis=-1)


def apply_rope(x, angles):
    """x: (B, S, H, hd); angles: (B, S, hd//2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    c = jnp.cos(angles)[:, :, None, :]
    s = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window / bidirectional / softcap)
# ---------------------------------------------------------------------------

def attn_spec(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    spec = {
        "wq": Leaf((d, h * hd), ("embed_fsdp", "heads")),
        "wk": Leaf((d, kv * hd), ("embed_fsdp", "kv_heads")),
        "wv": Leaf((d, kv * hd), ("embed_fsdp", "kv_heads")),
        "wo": Leaf((h * hd, d), ("heads", "embed_fsdp")),
    }
    if cfg.use_bias:
        spec["bq"] = Leaf((h * hd,), ("heads",), scale=0.0)
        spec["bv"] = Leaf((kv * hd,), ("kv_heads",), scale=0.0)
        spec["bo"] = Leaf((d,), ("embed",), scale=0.0)
    return spec


def _mask(q_pos, k_pos, causal: bool, window: int):
    """q_pos: (Sq,), k_pos: (Sk,) -> (Sq, Sk) bool."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def gqa_attend(q, k, v, mask, softcap: float = 0.0):
    """q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd), mask broadcastable (B,1,Sq,Sk).

    KV heads are (virtually) expanded to H so the score tensor keeps one
    fused head dim — XLA folds the repeat into the einsum, and the head
    dim stays expressible as a single sharded axis (TP over heads)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(hd)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(mask, scores, -1e30)
    scores = constrain(scores, ("batch", "act_heads", None, None))
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", w.astype(v.dtype), v)
    return out.reshape(B, Sq, H * hd)


ATTN_CHUNK = 1024       # q-block size for chunked attention
CHUNK_THRESHOLD = 2048  # use chunked path above this sequence length


def gqa_attend_chunked(q, k, v, q_pos, k_pos, *, causal, window,
                       softcap: float = 0.0):
    """Blockwise attention over q chunks with static per-chunk K/V slices.

    Local (sliding-window) layers only touch K/V inside the window of each
    q block, making prefill cost O(S*(window+chunk)) instead of O(S^2) —
    the TPU-side analogue of a flash-attention schedule, expressed in pure
    XLA ops (the Pallas kernel in repro.kernels is the fused variant).
    Chunks are unrolled in Python: the layer scan provides the loop.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    c = min(ATTN_CHUNK, Sq)
    outs = []
    for s0 in range(0, Sq, c):
        s1 = min(s0 + c, Sq)
        lo = 0
        hi = Sk
        if window:
            lo = max(0, s0 - window + 1)
        if causal and Sk == Sq:
            hi = s1
        qc = q[:, s0:s1]
        m = _mask(q_pos[s0:s1], k_pos[lo:hi], causal, window)[None, None]
        outs.append(gqa_attend(qc, k[:, lo:hi], v[:, lo:hi], m, softcap))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def attention(p, cfg: ModelConfig, x, positions, *, causal=True, window=0,
              kv_override=None, angles=None):
    """Full-sequence attention (train / prefill). Returns (y, (k, v))."""
    B, S, D = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, h, hd)
    if cfg.use_bias:
        q = q + p["bq"].reshape(1, 1, h, hd)
    if kv_override is None:
        ksrc = x
    else:
        ksrc = kv_override
    Sk = ksrc.shape[1]
    k = (ksrc @ p["wk"]).reshape(B, Sk, kv, hd)
    v = (ksrc @ p["wv"]).reshape(B, Sk, kv, hd)
    if cfg.use_bias:
        v = v + p["bv"].reshape(1, 1, kv, hd)
    if angles is not None:
        q = apply_rope(q, angles)
        if kv_override is None:
            k = apply_rope(k, angles)
    # inside the block, seq is gathered (SP boundary is the residual)
    q = constrain(q, ("batch", None, "act_heads", None))
    k = constrain(k, ("batch", None, None, None))
    if kv_override is None:
        kpos = positions
    else:
        kpos = jnp.arange(Sk)
    if S > CHUNK_THRESHOLD:
        y = gqa_attend_chunked(q, k, v, positions, kpos, causal=causal,
                               window=window, softcap=cfg.logit_softcap)
    else:
        m = _mask(positions, kpos, causal, window)[None, None]
        y = gqa_attend(q, k, v, m, cfg.logit_softcap)
    y = y @ p["wo"]
    if cfg.use_bias:
        y = y + p["bo"]
    return constrain(y, ("batch", "seq", "embed")), (k, v)


def attention_decode(p, cfg: ModelConfig, x, cache_k, cache_v, pos, *,
                     window=0, theta=None, rope=True):
    """Single-token decode. cache_{k,v}: (B, C, KV, hd). ``window`` selects
    ring-buffer semantics (C == window) vs linear cache (C == max seq).
    ``rope=False`` for families whose prefill attention runs unrotated
    (absolute/sinusoid embeddings, e.g. whisper's decoder self-attention) —
    decode must rotate exactly when prefill does, or the two paths diverge
    at every position past 0."""
    B, S1, D = x.shape
    assert S1 == 1
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    C = cache_k.shape[1]
    theta = theta or cfg.rope_theta
    q = (x @ p["wq"]).reshape(B, 1, h, hd)
    if cfg.use_bias:
        q = q + p["bq"].reshape(1, 1, h, hd)
    k_new = (x @ p["wk"]).reshape(B, 1, kv, hd)
    v_new = (x @ p["wv"]).reshape(B, 1, kv, hd)
    if cfg.use_bias:
        v_new = v_new + p["bv"].reshape(1, 1, kv, hd)
    if rope:
        posv = jnp.full((B, 1), pos)
        ang = rope_angles(posv, hd, theta)
        q = apply_rope(q, ang)
        k_new = apply_rope(k_new, ang)
    slot = pos % C if window > 0 else pos  # ring buffer vs linear cache
    cache_k = jax.lax.dynamic_update_slice(cache_k, k_new, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v_new, (0, slot, 0, 0))
    idx = jnp.arange(C)
    if window > 0:
        valid = idx < jnp.minimum(pos + 1, C)
    else:
        valid = idx <= pos
    m = jnp.broadcast_to(valid[None, None, :], (B, 1, C))[:, None]
    y = gqa_attend(q, cache_k, cache_v, m, cfg.logit_softcap)
    y = y @ p["wo"]
    if cfg.use_bias:
        y = y + p["bo"]
    return y, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP (geglu / gelu)
# ---------------------------------------------------------------------------

def mlp_spec(cfg: ModelConfig, geglu: bool = True) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if geglu:
        return {
            "wg": Leaf((d, f), ("embed_fsdp", "ff")),
            "wu": Leaf((d, f), ("embed_fsdp", "ff")),
            "wd": Leaf((f, d), ("ff", "embed_fsdp")),
        }
    spec = {
        "w1": Leaf((d, f), ("embed_fsdp", "ff")),
        "w2": Leaf((f, d), ("ff", "embed_fsdp")),
    }
    if cfg.use_bias:
        spec["b1"] = Leaf((f,), ("ff",), scale=0.0)
        spec["b2"] = Leaf((d,), ("embed",), scale=0.0)
    return spec


def mlp(p, x):
    if "wg" in p:
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
        h = constrain(h, ("batch", None, "act_ff"))
        return h @ p["wd"]
    h = x @ p["w1"]
    if "b1" in p:
        h = h + p["b1"]
    h = jax.nn.gelu(h)
    h = constrain(h, ("batch", None, "act_ff"))
    y = h @ p["w2"]
    if "b2" in p:
        y = y + p["b2"]
    return y


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_spec(cfg: ModelConfig) -> dict:
    spec = {"embed": Leaf((cfg.vocab, cfg.d_model), ("vocab", "embed_fsdp"))}
    if not cfg.tie_embeddings:
        spec["unembed"] = Leaf((cfg.d_model, cfg.vocab),
                               ("embed_fsdp", "vocab"))
    return spec


def embed(p, cfg: ModelConfig, tokens):
    x = jnp.take(p["embed"], tokens, axis=0).astype(cfg.jdtype)
    if cfg.family in ("dense", "moe", "vlm"):
        x = x * math.sqrt(cfg.d_model)  # gemma-style scaling
    return constrain(x, ("batch", "seq", "embed"))


def unembed(p, cfg: ModelConfig, x):
    w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
    logits = x @ w.astype(cfg.jdtype)
    # vocab-parallel logits; seq explicitly gathered (vocab CE does the
    # cross-shard logsumexp reduction)
    return constrain(logits, ("batch", None, "vocab"))

from .config import ModelConfig, InputShape, INPUT_SHAPES
from . import registry

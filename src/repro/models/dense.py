"""Dense decoder-only transformer family.

Covers: gemma3-27b / gemma3-12b (5:1 local:global attention pattern,
softcap-free RoPE), yi-9b (llama arch), command-r-35b (no-bias GQA),
qwen2-vl-2b (M-RoPE + stubbed vision frontend), and the paper's GPT.

Layers are grouped by the attention *pattern* (e.g. 5 local + 1 global) and
scanned over pattern groups; any remainder layers get their own unscanned
parameter stack. Per-role KV caches (ring-buffer for "local" layers, linear
for "global") keep decode memory at the architecture's true footprint.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from ..sharding.specs import constrain
from .config import ModelConfig
from . import layers as L


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def block_spec(cfg: ModelConfig) -> dict:
    return {
        "pre_attn": L.norm_spec(cfg.d_model),
        "attn": L.attn_spec(cfg),
        "pre_mlp": L.norm_spec(cfg.d_model),
        "mlp": L.mlp_spec(cfg, geglu=not cfg.use_bias),
    }


def model_spec(cfg: ModelConfig) -> dict:
    P = len(cfg.pattern)
    reps, tail = cfg.n_layers // P, cfg.n_layers % P
    spec = dict(L.embed_spec(cfg))
    spec["blocks"] = {f"p{i}": L.stack_spec(block_spec(cfg), reps)
                      for i in range(P)}
    if tail:
        spec["tail"] = {f"p{i}": block_spec(cfg) for i in range(tail)}
    spec["final_norm"] = L.norm_spec(cfg.d_model)
    if cfg.vision_tokens:
        spec["vision_proj"] = L.Leaf((cfg.d_model, cfg.d_model),
                                     ("embed", "embed_fsdp"))
    return spec


def _role_window(cfg, role):
    return cfg.window if role == "local" else 0


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _apply_block(p, cfg, x, positions, angles, role, collect_kv=False):
    h, kv_ = L.attention(p["attn"], cfg, L.rmsnorm(x, p["pre_attn"],
                                                   cfg.norm_eps),
                         positions, causal=True,
                         window=_role_window(cfg, role), angles=angles)
    x = x + h
    x = x + L.mlp(p["mlp"], L.rmsnorm(x, p["pre_mlp"], cfg.norm_eps))
    x = constrain(x, ("batch", "seq", "embed"))
    return (x, kv_) if collect_kv else (x, None)


def forward(params, cfg: ModelConfig, tokens, positions=None,
            patch_embeds=None, collect_kv=False, return_hidden=False):
    """tokens: (B, S_text); patch_embeds: (B, V_tok, D) for the VLM family.
    Returns (logits, kv_caches_or_None)."""
    B = tokens.shape[0]
    x = L.embed(params, cfg, tokens)
    if cfg.vision_tokens and patch_embeds is not None:
        pe = patch_embeds.astype(cfg.jdtype) @ params["vision_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    S = x.shape[1]
    if positions is None:
        positions = jnp.arange(S)
    sections = cfg.mrope_sections if cfg.mrope else None
    if cfg.mrope:
        pos3 = jnp.broadcast_to(positions[None, :, None], (B, S, 3))
        angles = L.rope_angles(pos3, cfg.hd, cfg.rope_theta, sections)
    else:
        angles = L.rope_angles(
            jnp.broadcast_to(positions[None], (B, S)), cfg.hd, cfg.rope_theta)

    P = len(cfg.pattern)
    reps = cfg.n_layers // P
    kvs = {}

    ab = jax.checkpoint(_apply_block, static_argnums=(1, 5, 6)) \
        if cfg.remat else _apply_block

    def body(xc, blk):
        kv_list = []
        for i, role in enumerate(cfg.pattern):
            xc, kv_ = ab(blk[f"p{i}"], cfg, xc, positions, angles,
                         role, collect_kv)
            kv_list.append(kv_)
        return xc, tuple(kv_list) if collect_kv else None

    wrapped = body  # per-block checkpoints; residuals SP-sharded
    if cfg.scan_layers and reps > 0:
        x, ys = jax.lax.scan(wrapped, x, params["blocks"])
        if collect_kv:
            kvs["scan"] = ys
    else:
        blocks_unstacked = [
            jax.tree.map(lambda a, g=g: a[g], params["blocks"])
            for g in range(reps)]
        ys = []
        for blk in blocks_unstacked:
            x, kv_ = wrapped(x, blk)
            ys.append(kv_)
        if collect_kv:
            kvs["scan"] = jax.tree.map(lambda *a: jnp.stack(a), *ys) \
                if ys else None
    if "tail" in params:
        tail_kv = []
        for i, role in enumerate(cfg.pattern[:cfg.n_layers % P]):
            x, kv_ = _apply_block(params["tail"][f"p{i}"], cfg, x, positions,
                                  angles, role, collect_kv)
            tail_kv.append(kv_)
        if collect_kv:
            kvs["tail"] = tuple(tail_kv)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, (kvs if collect_kv else None)
    logits = L.unembed(params, cfg, x)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / 30.0) * 30.0
    return logits, (kvs if collect_kv else None)


# ---------------------------------------------------------------------------
# Decode (single token against per-role caches)
# ---------------------------------------------------------------------------

def cache_size(cfg: ModelConfig, role: str, max_seq: int) -> int:
    return min(cfg.window, max_seq) if role == "local" else max_seq


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, abstract=False):
    """Per-pattern-position stacked KV caches.
    Layout: {"p{i}": (k, v)} with k: (reps, B, C_i, KV, hd)."""
    P = len(cfg.pattern)
    reps, tail = cfg.n_layers // P, cfg.n_layers % P
    mk = (lambda s: jax.ShapeDtypeStruct(s, cfg.jdtype)) if abstract \
        else (lambda s: jnp.zeros(s, cfg.jdtype))
    cache = {}
    for i, role in enumerate(cfg.pattern):
        C = cache_size(cfg, role, max_seq)
        shape = (reps, batch, C, cfg.n_kv_heads, cfg.hd)
        cache[f"p{i}"] = (mk(shape), mk(shape))
    for i, role in enumerate(cfg.pattern[:tail]):
        C = cache_size(cfg, role, max_seq)
        shape = (batch, C, cfg.n_kv_heads, cfg.hd)
        cache[f"tail{i}"] = (mk(shape), mk(shape))
    return cache


def _decode_block(p, cfg, x, ck, cv, pos, role):
    h = L.rmsnorm(x, p["pre_attn"], cfg.norm_eps)
    h, ck, cv = L.attention_decode(p["attn"], cfg, h, ck, cv, pos,
                                   window=_role_window(cfg, role))
    x = x + h
    x = x + L.mlp(p["mlp"], L.rmsnorm(x, p["pre_mlp"], cfg.norm_eps))
    return x, ck, cv


def decode_step(params, cfg: ModelConfig, cache, token, pos):
    """token: (B, 1) int32; pos: scalar int32. Returns (logits, new cache)."""
    x = L.embed(params, cfg, token)
    P = len(cfg.pattern)
    reps = cfg.n_layers // P

    def body(xc, blk_and_cache):
        blk = blk_and_cache[0]
        new_caches = {}
        for i, role in enumerate(cfg.pattern):
            ck, cv = blk_and_cache[1][f"p{i}"]
            xc, ck, cv = _decode_block(blk[f"p{i}"], cfg, xc, ck, cv, pos,
                                       role)
            new_caches[f"p{i}"] = (ck, cv)
        return xc, new_caches

    if cfg.scan_layers and reps > 0:
        scan_cache = {k: v for k, v in cache.items() if k.startswith("p")}
        x, new_scan = jax.lax.scan(body, x, (params["blocks"], scan_cache))
    else:
        new_list = []
        for g in range(reps):
            blk = jax.tree.map(lambda a, g=g: a[g], params["blocks"])
            sc = {k: jax.tree.map(lambda a, g=g: a[g], v)
                  for k, v in cache.items() if k.startswith("p")}
            x, nc = body(x, (blk, sc))
            new_list.append(nc)
        new_scan = jax.tree.map(lambda *a: jnp.stack(a), *new_list) \
            if new_list else {}
    new_cache = dict(new_scan)
    for i, role in enumerate(cfg.pattern[:cfg.n_layers % P]):
        ck, cv = cache[f"tail{i}"]
        x, ck, cv = _decode_block(params["tail"][f"p{i}"], cfg, x, ck, cv,
                                  pos, role)
        new_cache[f"tail{i}"] = (ck, cv)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params, cfg, x)
    return logits, new_cache

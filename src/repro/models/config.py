"""Model configuration shared by all architecture families."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None

    # attention pattern: cycled over layers, e.g. 5 local + 1 global (gemma3)
    # entries: "global" | "local" | "recurrent"
    pattern: tuple = ("global",)
    window: int = 0               # sliding-window size for "local" layers
    rope_theta: float = 10_000.0
    logit_softcap: float = 0.0
    use_bias: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    aux_loss_coef: float = 0.01

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2

    # hybrid (RG-LRU)
    lru_width: int = 0

    # encoder-decoder (whisper): encoder frames are a stubbed frontend
    encoder_layers: int = 0
    encoder_frames: int = 1500

    # VLM: stubbed vision frontend supplies patch embeddings
    vision_tokens: int = 0
    mrope: bool = False
    mrope_sections: tuple = (16, 24, 24)

    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = False
    scan_layers: bool = True
    citation: str = ""

    # ---------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern_reps(self) -> int:
        """Number of pattern-group repetitions (scan length)."""
        assert self.n_layers % len(self.pattern) == 0, \
            f"{self.n_layers} layers not divisible by pattern {self.pattern}"
        return self.n_layers // len(self.pattern)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_subquadratic(self) -> bool:
        """True if no layer attends over unbounded context (long_500k ok)."""
        return all(p != "global" for p in self.pattern) or self.family == "ssm"

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test variant of the same family (2 pattern groups,
        d_model<=256, <=4 experts) — per the assignment's smoke-test rule."""
        small = dict(
            n_layers=2 * len(self.pattern) if self.pattern else 2,
            d_model=min(self.d_model, 128),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=32,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            window=min(self.window, 16) if self.window else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=min(self.moe_d_ff, 64) if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            lru_width=min(self.lru_width, 128) if self.lru_width else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_frames=16 if self.encoder_layers else 1500,
            vision_tokens=8 if self.vision_tokens else 0,
            mrope_sections=(4, 6, 6) if self.mrope else self.mrope_sections,
            dtype="float32",
        )
        small.update(overrides)
        return replace(self, **small)


@dataclass(frozen=True)
class InputShape:
    """An assigned (seq_len, global_batch, mode) input shape."""
    name: str
    seq_len: int
    global_batch: int
    mode: str      # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

"""RecurrentGemma / Griffin hybrid family: RG-LRU recurrent blocks
interleaved with local sliding-window attention (arXiv:2402.19427).

Pattern ("recurrent", "recurrent", "local") repeats; remainder layers (26 %
3 == 2 for recurrentgemma-2b) get an unscanned tail — see DESIGN.md.

The RG-LRU linear recurrence h_t = a_t*h_{t-1} + sqrt(1-a_t^2)*(i_t*x_t) is
evaluated with ``jax.lax.associative_scan`` over the sequence (the TPU
adaptation of the paper's fused GPU scan kernel); decode is an O(1) update.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..sharding.specs import constrain
from .config import ModelConfig
from . import layers as L
from . import dense


C_COEF = 8.0  # Griffin's `c` constant


def rglru_spec(cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    return {
        "norm": L.norm_spec(d),
        "in_x": L.Leaf((d, w), ("embed_fsdp", "ff")),
        "in_gate": L.Leaf((d, w), ("embed_fsdp", "ff")),
        "conv_w": L.Leaf((4, w), ("conv", "ff")),
        "conv_b": L.Leaf((w,), ("ff",), scale=0.0),
        "w_input_gate": L.Leaf((w, w), (None, "ff")),
        "w_rec_gate": L.Leaf((w, w), (None, "ff")),
        "lambda_p": L.Leaf((w,), ("ff",), scale=-1.0),
        "out": L.Leaf((w, d), ("ff", "embed_fsdp")),
    }


def attn_block_spec(cfg: ModelConfig) -> dict:
    return {
        "pre_attn": L.norm_spec(cfg.d_model),
        "attn": L.attn_spec(cfg),
    }


def block_spec(cfg: ModelConfig, role: str) -> dict:
    base = {"pre_mlp": L.norm_spec(cfg.d_model),
            "mlp": L.mlp_spec(cfg, geglu=True)}
    if role == "recurrent":
        base["rglru"] = rglru_spec(cfg)
    else:
        base.update(attn_block_spec(cfg))
    return base


def model_spec(cfg: ModelConfig) -> dict:
    P = len(cfg.pattern)
    reps, tail = cfg.n_layers // P, cfg.n_layers % P
    spec = dict(L.embed_spec(cfg))
    spec["blocks"] = {f"p{i}": L.stack_spec(block_spec(cfg, role), reps)
                      for i, role in enumerate(cfg.pattern)}
    if tail:
        spec["tail"] = {f"p{i}": block_spec(cfg, cfg.pattern[i])
                        for i in range(tail)}
    spec["final_norm"] = L.norm_spec(cfg.d_model)
    return spec


def _rglru_scan(a, bx, h0=None):
    """h_t = a_t * h_{t-1} + bx_t via associative scan. a,bx: (B,S,W)."""
    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def rglru_block(p, cfg: ModelConfig, x, state=None, conv_state=None,
                decode=False):
    """Returns (y, new_state, new_conv_state)."""
    h = L.rmsnorm(x, p["norm"], cfg.norm_eps)
    gate = jax.nn.gelu(h @ p["in_gate"])
    u = h @ p["in_x"]
    # causal depthwise conv (window 4)
    if decode:
        win = jnp.concatenate([conv_state, u.astype(conv_state.dtype)], axis=1)
        u = jnp.einsum("bkc,kc->bc", win, p["conv_w"])[:, None] + p["conv_b"]
        new_conv = win[:, 1:]
    else:
        K = p["conv_w"].shape[0]
        acc = u * p["conv_w"][K - 1]
        for k in range(1, K):
            acc = acc + jnp.pad(u, ((0, 0), (k, 0), (0, 0)))[:, :-k] \
                * p["conv_w"][K - 1 - k]
        u = acc + p["conv_b"]
        new_conv = None
    # RG-LRU
    i_t = jax.nn.sigmoid(u @ p["w_input_gate"])
    r_t = jax.nn.sigmoid(u @ p["w_rec_gate"])
    log_a = -C_COEF * r_t * jax.nn.softplus(p["lambda_p"])
    a_t = jnp.exp(log_a)
    scaled = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    bx = scaled * (i_t * u)
    if decode:
        new_state = (a_t[:, 0] * state + bx[:, 0]).astype(jnp.float32)
        hidden = new_state[:, None]
    else:
        hidden = _rglru_scan(a_t, bx)
        new_state = hidden[:, -1].astype(jnp.float32)
    y = ((hidden * gate) @ p["out"]).astype(x.dtype)
    return y, new_state, new_conv


def _apply_block(p, cfg, x, role, positions, angles):
    if role == "recurrent":
        y, _, _ = rglru_block(p["rglru"], cfg, x)
        x = x + y
    else:
        h, _ = L.attention(p["attn"], cfg,
                           L.rmsnorm(x, p["pre_attn"], cfg.norm_eps),
                           positions, causal=True, window=cfg.window,
                           angles=angles)
        x = x + h
    x = x + L.mlp(p["mlp"], L.rmsnorm(x, p["pre_mlp"], cfg.norm_eps))
    return constrain(x, ("batch", "seq", "embed"))


def forward(params, cfg: ModelConfig, tokens, positions=None,
            return_hidden=False, **_):
    B, S = tokens.shape
    x = L.embed(params, cfg, tokens)
    if positions is None:
        positions = jnp.arange(S)
    angles = L.rope_angles(jnp.broadcast_to(positions[None], (B, S)),
                           cfg.hd, cfg.rope_theta)
    P = len(cfg.pattern)
    reps, tail = cfg.n_layers // P, cfg.n_layers % P

    ab = jax.checkpoint(_apply_block, static_argnums=(1, 3)) \
        if cfg.remat else _apply_block

    def body(xc, blk):
        for i, role in enumerate(cfg.pattern):
            xc = ab(blk[f"p{i}"], cfg, xc, role, positions, angles)
        return xc, None

    wrapped = body  # per-block checkpoints
    if cfg.scan_layers and reps:
        x, _ = jax.lax.scan(wrapped, x, params["blocks"])
    else:
        for g in range(reps):
            blk = jax.tree.map(lambda a, g=g: a[g], params["blocks"])
            x, _ = wrapped(x, blk)
    for i in range(tail):
        x = _apply_block(params["tail"][f"p{i}"], cfg, x, cfg.pattern[i],
                         positions, angles)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, None
    return L.unembed(params, cfg, x), None


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, abstract=False):
    P = len(cfg.pattern)
    reps, tail = cfg.n_layers // P, cfg.n_layers % P
    mk = (lambda s, dt: jax.ShapeDtypeStruct(s, dt)) if abstract \
        else (lambda s, dt: jnp.zeros(s, dt))
    cache = {}
    for i, role in enumerate(cfg.pattern):
        if role == "recurrent":
            cache[f"p{i}"] = {
                "state": mk((reps, batch, cfg.lru_width), jnp.float32),
                "conv": mk((reps, batch, 3, cfg.lru_width), cfg.jdtype),
            }
        else:
            C = min(cfg.window, max_seq)
            shape = (reps, batch, C, cfg.n_kv_heads, cfg.hd)
            cache[f"p{i}"] = {"k": mk(shape, cfg.jdtype),
                              "v": mk(shape, cfg.jdtype)}
    for i in range(tail):
        role = cfg.pattern[i]
        if role == "recurrent":
            cache[f"tail{i}"] = {
                "state": mk((batch, cfg.lru_width), jnp.float32),
                "conv": mk((batch, 3, cfg.lru_width), cfg.jdtype),
            }
        else:
            C = min(cfg.window, max_seq)
            shape = (batch, C, cfg.n_kv_heads, cfg.hd)
            cache[f"tail{i}"] = {"k": mk(shape, cfg.jdtype),
                                 "v": mk(shape, cfg.jdtype)}
    return cache


def _decode_block(p, cfg, x, c, role, pos):
    if role == "recurrent":
        y, ns, ncv = rglru_block(p["rglru"], cfg, x, state=c["state"],
                                 conv_state=c["conv"], decode=True)
        x = x + y
        nc = {"state": ns, "conv": ncv}
    else:
        h = L.rmsnorm(x, p["pre_attn"], cfg.norm_eps)
        h, ck, cv = L.attention_decode(p["attn"], cfg, h, c["k"], c["v"],
                                       pos, window=cfg.window)
        x = x + h
        nc = {"k": ck, "v": cv}
    x = x + L.mlp(p["mlp"], L.rmsnorm(x, p["pre_mlp"], cfg.norm_eps))
    return x, nc


def decode_step(params, cfg: ModelConfig, cache, token, pos):
    x = L.embed(params, cfg, token)
    P = len(cfg.pattern)
    reps, tail = cfg.n_layers // P, cfg.n_layers % P

    def body(xc, blk_and_cache):
        blk, caches = blk_and_cache
        new = {}
        for i, role in enumerate(cfg.pattern):
            xc, nc = _decode_block(blk[f"p{i}"], cfg, xc, caches[f"p{i}"],
                                   role, pos)
            new[f"p{i}"] = nc
        return xc, new

    scan_cache = {k: v for k, v in cache.items() if k.startswith("p")}
    if reps:
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], scan_cache))
    else:
        new_cache = {}
    for i in range(tail):
        x, nc = _decode_block(params["tail"][f"p{i}"], cfg, x,
                              cache[f"tail{i}"], cfg.pattern[i], pos)
        new_cache[f"tail{i}"] = nc
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(params, cfg, x), new_cache

"""Architecture registry: uniform API over the six model families."""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import dense, encdec, hybrid, layers as L, moe, ssm


_FAMILY = {
    "dense": dense, "vlm": dense, "moe": moe, "ssm": ssm,
    "hybrid": hybrid, "audio": encdec,
}

ARCH_IDS = [
    "gemma3-27b", "mixtral-8x7b", "mamba2-1.3b", "kimi-k2-1t-a32b",
    "recurrentgemma-2b", "qwen2-vl-2b", "gemma3-12b", "whisper-medium",
    "yi-9b", "command-r-35b",
]


def family_module(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def model_spec(cfg: ModelConfig) -> dict:
    return family_module(cfg).model_spec(cfg)


def init_params(cfg: ModelConfig, rng) -> dict:
    return L.init_tree(model_spec(cfg), rng, cfg.jdtype)


def abstract_params(cfg: ModelConfig) -> dict:
    return L.abstract_tree(model_spec(cfg), cfg.jdtype)


def logical_axes(cfg: ModelConfig) -> dict:
    return L.logical_tree(model_spec(cfg))


def forward(params, cfg: ModelConfig, batch: dict, return_hidden=False):
    """batch: {tokens, positions?, patch_embeds?, frames?} -> (logits, extras)"""
    mod = family_module(cfg)
    kwargs = {}
    if cfg.family == "vlm" and "patch_embeds" in batch:
        kwargs["patch_embeds"] = batch["patch_embeds"]
    if cfg.family == "audio":
        kwargs["frames"] = batch["frames"]
    return mod.forward(params, cfg, batch["tokens"],
                       positions=batch.get("positions"),
                       return_hidden=return_hidden, **kwargs)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, abstract=False):
    return family_module(cfg).init_cache(cfg, batch, max_seq, abstract)


def decode_step(params, cfg: ModelConfig, cache, token, pos):
    return family_module(cfg).decode_step(params, cfg, cache, token, pos)


def load_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def n_params(cfg: ModelConfig) -> int:
    spec = model_spec(cfg)
    leaves = jax.tree.leaves(spec, is_leaf=L.is_leaf)
    total = 0
    for lf in leaves:
        n = 1
        for d in lf.shape:
            n *= d
        total += n
    return total


def n_active_params(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE: top_k of n_experts)."""
    total = n_params(cfg)
    if cfg.n_experts and cfg.top_k:
        expert_p = 3 * cfg.d_model * cfg.moe_d_ff * cfg.n_experts \
            * cfg.n_layers
        active = expert_p * cfg.top_k // cfg.n_experts
        return total - expert_p + active
    return total

"""Jit'd wrappers for the Pallas kernels (interpret=True on CPU)."""
import functools

import jax

from .flash_attention import flash_attention
from .rmsnorm import rmsnorm

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())
INTERPRET = not _ON_TPU


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm_op(x, scale, eps=1e-6, block_rows=256):
    return rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                   interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention_op(q, k, v, causal=True, block_q=128, block_k=128):
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=INTERPRET)

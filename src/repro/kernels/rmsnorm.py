"""Fused RMSNorm Pallas TPU kernel.

HBM -> VMEM tiling: rows are processed in blocks of ``block_rows`` with the
full feature dim resident in VMEM (d_model up to ~8192 fits comfortably:
block_rows*D*4B << 128 MiB VMEM when block_rows <= 256). The reduction, the
rsqrt, and the (1+scale) multiply fuse into one pass over HBM — on TPU this
turns three HBM round-trips (square+mean, normalize, scale) into one.

Feature dim is padded to the 128-lane boundary by construction (all
assigned configs have d_model % 128 == 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)            # (block_rows, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(jnp.float32))) \
        .astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., D) -> same shape; scale: (D,)."""
    orig_shape = x.shape
    D = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, D)
    br = min(block_rows, rows)
    while rows % br:
        br //= 2
    br = max(br, 1)
    grid = (rows // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct((rows, D), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),       # row tile in VMEM
            pl.BlockSpec((D,), lambda i: (0,)),            # scale resident
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)

"""Flash-attention forward Pallas TPU kernel (online softmax).

Grid: (batch*heads, q_blocks). Each program holds one (block_q, hd) query
tile in VMEM and streams K/V tiles of (block_k, hd) from HBM, maintaining
the running max / normalizer (m, l) of the online-softmax recurrence — the
TPU adaptation of the FlashAttention schedule: instead of CUDA warps and
shared-memory tiles, tiles are MXU-aligned (block_q, block_k multiples of
128 when the sequence allows) VMEM blocks, and the inner K loop is a
``lax.fori_loop`` inside the kernel body so the working set stays
O(block_q * (hd + block_k)).

Causal masking skips fully-masked K tiles via the loop upper bound.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool,
                  scale: float):
    _, bq, hd = q_ref.shape
    Sk = k_ref.shape[1]
    # size-1 leading slices (not int indices): int ref-indices break the
    # interpret-mode discharge rule on older jax (0.4.x)
    q = pl.load(q_ref, (pl.dslice(0, 1), slice(None), slice(None)))[0] \
        .astype(jnp.float32) * scale
    iq = pl.program_id(1)

    def body(ik, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(ik * block_k, block_k),
                            slice(None)))[0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(ik * block_k, block_k),
                            slice(None)))[0].astype(jnp.float32)
        s = q @ k.T                                      # (bq, bk)
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
            kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, hd), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    if causal:
        # K tiles strictly above the diagonal are skipped entirely
        n_k = ((iq + 1) * bq + block_k - 1) // block_k
    else:
        n_k = Sk // block_k
    acc, m, l = jax.lax.fori_loop(0, n_k, body, (acc0, m0, l0))
    out = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
    pl.store(o_ref, (pl.dslice(0, 1), slice(None), slice(None)), out[None])


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, scale=None,
                    interpret: bool = False):
    """q,k,v: (B, S, H, hd) (same head count; expand GQA beforehand)."""
    B, S, H, hd = q.shape
    scale = scale or hd ** -0.5
    bq = min(block_q, S)
    bk = min(block_k, S)
    while S % bq:
        bq //= 2
    while S % bk:
        bk //= 2
    # fold batch and heads into the grid's first axis
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    grid = (B * H, S // bq)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_k=bk, causal=causal,
                          scale=scale),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)

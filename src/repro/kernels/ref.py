"""Pure-jnp oracles for the Pallas kernels."""
import jax
import jax.numpy as jnp


def rmsnorm_ref(x, scale, eps=1e-6):
    """x: (..., D); scale: (D,). Gemma-style (1+scale) RMSNorm."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal=True, scale=None):
    """q,k,v: (B, S, H, hd) (same H). Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    scale = scale or hd ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)) \
        .astype(q.dtype)

"""Pallas TPU kernels for the framework's compute hot-spots.

The paper's contribution is verification tooling (no kernel-level claims);
these kernels are the framework's optional fast paths, written for TPU
(pl.pallas_call + BlockSpec VMEM tiling) and validated on CPU with
interpret=True against the pure-jnp oracles in ref.py.
"""
from . import ops, ref

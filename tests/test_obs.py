"""repro.obs: span nesting is well-formed, exports load as Chrome trace
JSON, pool-worker spans merge onto the parent timeline with their own
pids, and observability is behaviour-neutral — certificates and lemma
stats are byte-identical with tracing on or off and across worker
counts."""
import json
import multiprocessing
import os
import time

import pytest

from repro import obs
from repro.api import Suite, verify
from repro.launch.verify import main as verify_main
from repro.obs import trace as obs_trace
from repro.obs.inspect import lemma_totals, obligation_rows, render, report
from repro.obs.metrics import MetricsRegistry
from repro.obs.metrics import render as render_metrics
from repro.runtime import RuntimeTask, SupervisedPool


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    """A test that fails mid-span must not leave its tracer installed."""
    yield
    obs_trace.install(None)


def _nap(t):
    time.sleep(t)
    return t


def _rendezvous_nap(started, n, hold):
    """Check in with our pid, wait until ``n`` distinct worker pids have,
    then hold the worker busy — forces every pool worker to run a task
    regardless of boot-order races, so the distinct-pid assertion below
    is deterministic."""
    started[os.getpid()] = True
    deadline = time.monotonic() + 30.0
    while len(started) < n and time.monotonic() < deadline:
        time.sleep(0.01)
    time.sleep(hold)
    return os.getpid()


def _spans(events):
    return [e for e in events if e.get("ph") == "X"]


# ---------------------------------------------------------------------------
# spans: nesting, export formats
# ---------------------------------------------------------------------------

def test_span_nesting_well_formed():
    tracer = obs_trace.start("t")
    with obs.span("outer", cat="engine", tag=1):
        with obs.span("inner_a"):
            time.sleep(0.001)
        with obs.span("inner_b"):
            time.sleep(0.001)
    obs_trace.stop()
    spans = {e["name"]: e for e in _spans(tracer.events)}
    outer, a, b = spans["outer"], spans["inner_a"], spans["inner_b"]
    assert outer["args"]["depth"] == 0 and outer["args"]["tag"] == 1
    assert a["args"]["depth"] == b["args"]["depth"] == 1
    assert outer["pid"] == a["pid"] == b["pid"] == tracer.pid
    # same-thread intervals: children inside the parent, siblings disjoint
    for inner in (a, b):
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert a["ts"] + a["dur"] <= b["ts"]


def test_module_level_api_is_noop_when_off(tmp_path):
    assert obs_trace.current() is None
    with obs.span("nothing"):            # must not raise or record
        obs.event("nothing.event")
        obs.counter("nothing.counter", n=1)
        obs.complete("nothing.span", 1.0, 2.0)
    assert obs_trace.current() is None


def test_chrome_trace_loads_and_has_engine_spans(tmp_path):
    tracer = obs_trace.start("main")
    rep = verify("tp_layer")
    obs_trace.stop()
    assert rep.ok

    path = tmp_path / "trace.json"
    tracer.write_chrome(str(path))
    obj = json.loads(path.read_text())
    assert obj["displayTimeUnit"] == "ms"
    evs = obj["traceEvents"]
    assert evs and evs[0]["ph"] == "M"   # process_name metadata leads
    for e in evs:
        assert {"name", "ph", "ts", "pid"} <= set(e)
    names = {e["name"] for e in evs}
    assert {"capture", "infer", "saturate", "extract",
            "saturate.batch"} <= names
    assert any(n.startswith("op:") for n in names)

    # both export formats round-trip through the inspection loader
    jl = tmp_path / "trace.jsonl"
    tracer.write_jsonl(str(jl))
    assert len(obs_trace.load_events(str(path))) == len(evs)
    assert len(obs_trace.load_events(str(jl))) == \
        len([e for e in evs if e["ph"] != "M"])


# ---------------------------------------------------------------------------
# pool: worker-side spans merge, queue/run split
# ---------------------------------------------------------------------------

def test_worker_spans_merge_with_distinct_pids():
    # spawn, like test_runtime's pool tests: the suite runs jax (pallas
    # interpret) in-process earlier, and fork-starting warm workers after
    # that wedges them in the initializer's first jax op
    tracer = obs_trace.start("main")
    with multiprocessing.get_context("spawn").Manager() as mgr:
        started = mgr.dict()
        tasks = [RuntimeTask(key=f"t{i}", fn=_rendezvous_nap,
                             args=(started, 2, 0.2), budget_s=120.0)
                 for i in range(2)]
        with SupervisedPool(2, mp_method="spawn") as pool:
            outcomes = pool.execute(tasks)
    obs_trace.stop()
    assert all(o.ok for o in outcomes.values())

    task_spans = [e for e in _spans(tracer.events) if e["name"] == "task"]
    assert len(task_spans) == 2
    pids = {e["pid"] for e in task_spans}
    assert len(pids) == 2 and tracer.pid not in pids

    # the supervisor reconstructs every task's run interval (and its
    # queue wait, when it waited) on the parent timeline
    runs = [e for e in tracer.events if e.get("name") == "run"]
    assert {(e.get("args") or {}).get("key")
            for e in runs} == {"t0", "t1"}
    for o in outcomes.values():
        ti = o.timing_info()
        assert set(ti) == {"queue_s", "run_s"}
        assert ti["run_s"] >= 0.2 and ti["queue_s"] >= 0.0


# ---------------------------------------------------------------------------
# behaviour-neutrality: tracing must not change what the engine computes
# ---------------------------------------------------------------------------

def test_certificate_byte_identical_tracing_on_off():
    off = verify("tp_layer")
    tracer = obs_trace.start("main")
    on = verify("tp_layer")
    obs_trace.stop()
    assert tracer.events                 # tracing actually recorded spans
    assert off.ok and on.ok
    assert json.dumps(off.r_o, sort_keys=True) == \
        json.dumps(on.r_o, sort_keys=True)
    for k in ("lemmas", "lemma_fires", "gs_ops", "gd_ops", "egraph_nodes"):
        assert off.stats[k] == on.stats[k], k


def test_lemma_stats_deterministic_across_worker_counts():
    with Suite(cases=["tp_layer"], degrees=(2,)) as s:
        seq = s.run(workers=0)
        # spawn: fork-starting warm workers wedges after in-process pallas
        par = s.run(workers=2, timeout_s=120.0, mp_method="spawn")
    a = seq.reports[0].stats["lemmas"]
    b = par.reports[0].stats["lemmas"]
    assert a and a == b
    for row in a.values():
        assert set(row) == {"calls", "hits", "fires"}
        assert row["hits"] <= row["calls"]
    # the suite aggregates the runtime's queue/run split alongside
    assert par.summary()["runtime"]["tasks"] == 1
    assert "runtime" not in json.dumps(par.stable_summary())


# ---------------------------------------------------------------------------
# inspection: renderer + metrics registry
# ---------------------------------------------------------------------------

def test_inspect_render_names_top_lemma(tmp_path, capsys):
    tracer = obs_trace.Tracer("main")
    tracer.event("saturate.batch", cat="engine",
                 fires={"concat_merge": 5, "slice_cover": 1},
                 ms={"concat_merge": 2.0, "slice_cover": 1.0})
    tracer.complete("queue", 10.0, 10.5, key="ob1")
    tracer.complete("run", 10.5, 11.0, key="ob1", status="ok")

    totals = lemma_totals(tracer.events)
    assert totals["concat_merge"] == {"fires": 5, "ms": 2.0}
    rows = obligation_rows(tracer.events)
    assert rows[0]["key"] == "ob1"
    assert rows[0]["queue_ms"] == pytest.approx(500.0)
    assert rows[0]["run_ms"] == pytest.approx(500.0)

    out = render(tracer.events)
    assert "ob1" in out and "queue" in out
    assert out.endswith("top lemma: concat_merge")

    # CLI wrapper: 0 on a readable trace, 1 on an empty one
    p = tmp_path / "t.json"
    tracer.write_chrome(str(p))
    assert report(str(p)) == 0
    assert "top lemma: concat_merge" in capsys.readouterr().out
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert report(str(empty)) == 1


def test_metrics_registry_and_render():
    reg = MetricsRegistry()
    reg.counter("cache.hits").inc()
    reg.counter("cache.hits").inc(2)
    h = reg.histogram("pool.queue_s")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["counters"] == {"cache.hits": 3}
    hs = snap["histograms"]["pool.queue_s"]
    assert hs["count"] == 4 and hs["sum"] == 10.0
    assert hs["min"] == 1.0 and hs["max"] == 4.0
    text = render_metrics(reg)
    assert text.startswith("-- metrics --")
    assert "cache.hits" in text and "pool.queue_s" in text
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "histograms": {}}
    assert "(no metrics recorded)" in render_metrics(reg)


def test_histogram_reservoir_is_deterministic():
    a, b = MetricsRegistry(), MetricsRegistry()
    for reg in (a, b):
        h = reg.histogram("x")
        for i in range(3 * h.SAMPLE + 7):    # wraps the ring twice
            h.observe(i % 97)
    assert a.snapshot() == b.snapshot()


# ---------------------------------------------------------------------------
# CLI: --trace / --metrics
# ---------------------------------------------------------------------------

def _case_envelope(capsys, argv):
    try:
        verify_main(argv)
    except SystemExit as e:
        assert e.code in (None, 0)
    return json.loads(capsys.readouterr().out)


def _stable_report(env):
    rep = json.loads(json.dumps(env["report"]))
    rep.pop("wall_s", None)
    rep.pop("runtime", None)
    stats = rep.get("stats") or {}
    stats.pop("time_s", None)
    stats.pop("phase_s", None)
    return json.dumps(rep, sort_keys=True)


def test_cli_trace_does_not_change_envelope_or_certificate(tmp_path, capsys):
    plain = _case_envelope(capsys, ["--case", "tp_layer", "--json"])
    traced = _case_envelope(
        capsys, ["--case", "tp_layer", "--json",
                 "--trace", str(tmp_path / "t.json")])
    # the pinned four-key schema-v2 envelope with or without --trace
    assert set(plain) == set(traced) == \
        {"schema_version", "kind", "timing", "report"}
    assert _stable_report(plain) == _stable_report(traced)


def test_cli_trace_and_metrics_flags(tmp_path, capsys):
    trace_path = tmp_path / "trace.json"
    verify_main(["--case", "tp_layer", "--json",
                 "--trace", str(trace_path), "--metrics"])
    cap = capsys.readouterr()
    env = json.loads(cap.out)
    # "metrics" joins the envelope only under the flag
    assert set(env) == {"schema_version", "kind", "timing", "report",
                        "metrics"}
    assert env["metrics"]["counters"].get("engine.runs", 0) >= 1
    assert "-- metrics --" in cap.err and "[obs] wrote" in cap.err

    assert trace_path.exists()
    assert (tmp_path / "trace.json.jsonl").exists()
    events = obs_trace.load_events(str(trace_path))
    assert any(e.get("name") == "infer" for e in events)
    assert "top lemma:" in render(events)
    assert obs_trace.current() is None   # the CLI uninstalled its tracer

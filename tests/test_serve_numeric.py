"""Numeric cross-check for the serving reference (train/serve.py): for
every family that implements decode_step, scanning decode_step over the
prompt (sequential_prefill) must produce the same logits as the parallel
prefill forward (prefill_logits) — including gemma3's sliding-window +
global dual cache, where the ring buffer must wrap (S > window) without
drifting off the full-sequence attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.train.serve import prefill_logits, sequential_prefill

pytestmark = pytest.mark.slow

# one architecture per family module; gemma3 is the dual-cache case the
# serving path exists for (5:1 local:global pattern, ring-buffer local KV)
FAMILY_ARCHS = [
    ("dense", "gemma3-12b"),
    ("moe", "mixtral-8x7b"),
    ("ssm", "mamba2-1.3b"),
    ("hybrid", "recurrentgemma-2b"),
    ("vlm", "qwen2-vl-2b"),
    ("audio", "whisper-medium"),
]

B, S = 2, 32


def _inputs(cfg, rng):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    frames = None
    if cfg.family == "audio":
        frames = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)
    return tokens, frames


@pytest.mark.parametrize("family,arch_id", FAMILY_ARCHS,
                         ids=[a for _, a in FAMILY_ARCHS])
def test_sequential_prefill_matches_parallel(family, arch_id):
    cfg = registry.load_config(arch_id).reduced()
    assert cfg.family == family
    if family == "dense":
        # the dual-cache case: local layers must wrap their ring buffer
        assert {"local", "global"} <= set(cfg.pattern)
        assert 0 < cfg.window < S
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    tokens, frames = _inputs(cfg, np.random.default_rng(7))

    batch = {"tokens": tokens}
    if frames is not None:
        batch["frames"] = frames
    want = prefill_logits(params, cfg, batch)
    _, got = sequential_prefill(params, cfg, tokens, max_seq=S,
                                frames=frames)
    assert got.shape == want.shape == (B, S, cfg.vocab)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    # the decode path must also agree on what it would emit next
    assert bool(jnp.all(jnp.argmax(got[:, -1], -1)
                        == jnp.argmax(want[:, -1], -1)))

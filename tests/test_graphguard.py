"""GraphGuard verification suite: the paper's 6-bug case study (§6.2),
positive certificates with numeric replay, and engine unit/property tests."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:  # property tests are skipped when hypothesis is absent (dev-only dep)
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    given = settings = st = None

from repro.core import (capture, capture_spmd, check_refinement, expand_spmd,
                        RefinementError)
from repro.core.egraph import EGraph
from repro.core.lemmas import all_lemmas
from repro.core.profile import CONFIG, set_optimizations
from repro.core import terms as T
from repro.core.terms import eval_term
from repro.core.symbolic import AffExpr, ScalarSolver
from repro.dist import strategies as S
from repro.launch.verify import run_case, CASES


def _run(case, bug=None, degree=2):
    return run_case(case, bug=bug, degree=degree, quiet=True)


# ---------------------------------------------------------------------------
# Positive certificates (refinement holds) + numeric replay
# ---------------------------------------------------------------------------

CLEAN_CASES = ["tp_layer", "sp_pad", "ep_moe", "sp_moe", "ln_grad",
               "sp_rope", "aux_loss", "grad_accum"]
# grad_accum was the last documented completeness gap; the constrained
# dus_concat lemma closed it (EXPERIMENTS.md §Gaps), retiring the old
# test_incomplete_clean_case xfail.


@pytest.mark.parametrize("case", CLEAN_CASES)
def test_clean_case_certificate(case):
    cert = _run(case)
    assert cert.r_o, case
    for expr in cert.r_o.values():
        assert expr.is_clean()


def test_certificate_numeric_replay_tp():
    """Executable R_o: distributed eval + certificate == sequential eval."""
    seq_fn, dist_fn, axes, specs, avals, names = S.tp_transformer_layer()
    gs = capture(seq_fn, avals, names)
    cap = capture_spmd(dist_fn, axes, specs, avals, names)
    gd, r_i = expand_spmd(cap)
    cert = check_refinement(gs, gd, r_i)
    rng = np.random.default_rng(0)
    vals = [rng.normal(size=a.shape).astype(np.float32) * 0.3 for a in avals]
    ref = np.asarray(seq_fn(*[jnp.asarray(v) for v in vals]))
    # evaluate the expanded multi-rank graph with numpy
    env = dict(gd.consts)
    for name, spec, v in zip(names, specs, vals):
        ent = tuple(spec) + (None,) * (v.ndim - len(tuple(spec)))
        for r in range(2):
            piece = v
            for d, ax in enumerate(ent):
                if ax is not None:
                    n = v.shape[d] // 2
                    piece = np.take(piece, range(r * n, (r + 1) * n), axis=d)
            env[f"{name}@tp{r}"] = piece
    for nm, term in gd.defs:
        env[nm] = eval_term(term, env)
    out = cert.reconstruct(env)
    got = list(out.values())[0]
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# The 6-bug case study (paper §6.2)
# ---------------------------------------------------------------------------

BUGS_DETECTED_BY_ERROR = ["rope_offset", "aux_scale", "pad_slice",
                          "sharded_expert", "grad_accum"]


@pytest.mark.parametrize("bug", BUGS_DETECTED_BY_ERROR)
def test_bug_detected(bug):
    builder, _ = S.BUG_CASES[bug]
    seq_fn, dist_fn, axes, specs, avals, names = builder(degree=2, bug=bug)
    gs = capture(seq_fn, avals, names)
    cap = capture_spmd(dist_fn, axes, specs, avals, names)
    gd, r_i = expand_spmd(cap)
    with pytest.raises(RefinementError) as exc:
        check_refinement(gs, gd, r_i)
    # actionable output: the error names an operator and its index
    assert "operator" in str(exc.value) or "output" in str(exc.value)


def test_bug5_unexpected_relation():
    """Paper bug 5: no error is raised — the certificate's relation differs
    from the user's expectation (identity vs cross-rank add)."""
    cert_ok = _run("ln_grad")
    (expr_ok,) = cert_ok.r_o.values()
    builder, _ = S.BUG_CASES["ln_no_allreduce"]
    seq_fn, dist_fn, axes, specs, avals, names = builder(
        degree=2, bug="ln_no_allreduce")
    gs = capture(seq_fn, avals, names)
    cap = capture_spmd(dist_fn, axes, specs, avals, names)
    gd, r_i = expand_spmd(cap)
    cert_bug = check_refinement(gs, gd, r_i)
    (expr_bug,) = cert_bug.r_o.values()
    # correct: grad maps to a single (already all-reduced) output tensor;
    # buggy: reconstruction needs a cross-rank add the implementation skipped
    assert expr_ok.op == "tensor"
    assert expr_bug.op == "add", expr_bug


# ---------------------------------------------------------------------------
# Engine unit + property tests
# ---------------------------------------------------------------------------

def test_paper_running_example():
    """Figure 2: C = matmul(A,B) under TP -> sum(C1,C2) and concat(D1,D2)."""
    eg = EGraph()
    A1 = T.tensor("A1@d", (4, 3)); A2 = T.tensor("A2@d", (4, 3))
    B1 = T.tensor("B1@d", (3, 5)); B2 = T.tensor("B2@d", (3, 5))
    cA = eg.add_term(T.tensor("A", (4, 6)))
    eg.merge(cA, eg.add_term(T.concat([A1, A2], 1)))
    cB = eg.add_term(T.tensor("B", (6, 5)))
    eg.merge(cB, eg.add_term(T.concat([B1, B2], 0)))
    eg.rebuild()
    cC = eg.add_term(T.matmul(T.tensor("A", (4, 6)), T.tensor("B", (6, 5))))
    for i, (x, y) in enumerate([(A1, B1), (A2, B2)]):
        eg.merge(eg.add_term(T.tensor(f"C{i}@d", (4, 5))),
                 eg.add_term(T.matmul(x, y)))
    eg.rebuild()
    eg.saturate(all_lemmas())
    ce = eg.extract_clean(cC, lambda n: n.endswith("@d"))
    assert ce is not None and ce.is_clean()
    assert ce.op == "add"


def test_saturate_after_interleaved_merges():
    """Regression for the saturation-loop cleanup: interleaving merges with
    saturation rounds must keep class ids canonical and still reach the
    rewrite fixpoint (the old loop re-canonicalized ids twice; the batch
    dedupe now does it once)."""
    eg = EGraph()
    x1 = T.tensor("x1@d", (2, 3)); x2 = T.tensor("x2@d", (2, 3))
    cX = eg.add_term(T.tensor("X", (4, 3)))
    eg.merge(cX, eg.add_term(T.concat([x1, x2], 0)))
    eg.saturate(all_lemmas())
    # now merge in a second representation mid-flight and saturate again
    cY = eg.add_term(T.ew1("tanh", T.tensor("X", (4, 3))))
    eg.merge(eg.add_term(T.tensor("Y", (4, 3))), cY)
    eg.rebuild()
    eg.saturate(all_lemmas())
    ce = eg.extract_clean(cY, lambda n: n.endswith("@d"))
    assert ce is None  # tanh is not clean — but pieces must exist:
    got = eg.extract_any(cY, lambda n: n.endswith("@d"))
    assert got is not None
    for c in (cX, cY):
        r = eg.find(c)
        assert eg.find(r) == r and r in eg.classes


def test_incremental_extraction_after_feasibility_merge():
    """Regression: a merge that folds an infeasible class into a feasible
    one must re-seed the *parents* of the merged class — the winner's own
    best does not improve, so the improvement cascade alone never reaches
    them and the cached extraction would stay infeasible."""
    eg = EGraph()
    x = T.tensor("x", (2,))
    a = T.tensor("a@d", (2,))
    cQ = eg.add_term(T.concat([x, a], 0))
    leaf_ok = lambda n: n.endswith("@d")
    assert eg.extract_clean(cQ, leaf_ok) is None   # x is not a @d leaf
    eg.merge(eg.add_term(x), eg.add_term(a))       # now x == a@d
    eg.rebuild()
    ce = eg.extract_clean(cQ, leaf_ok)             # cached, incremental
    assert ce is not None and ce.is_clean()
    try:
        set_optimizations(False)
        sweep = eg.extract_clean(cQ, leaf_ok)
    finally:
        set_optimizations(True)
    assert ce == sweep


def test_certificate_stats_phases():
    """Certificate.stats carries per-phase timings and engine counters."""
    cert = _run("tp_layer")
    for phase in ("saturate", "frontier", "extract"):
        assert phase in cert.stats["phase_s"], cert.stats["phase_s"]
        assert cert.stats["phase_s"][phase] >= 0.0
    assert cert.stats["counters"].get("lemma_calls", 0) > 0
    assert "opt" in cert.stats and "lemma_fires" in cert.stats


def test_optimizations_behaviour_preserving():
    """Dispatch/rebuild/extraction optimizations must not change results:
    identical certificates on a clean case, same localized operator on a
    bug case."""
    try:
        set_optimizations(True)
        cert_on = _run("sp_moe", degree=4)
        set_optimizations(False)
        cert_off = _run("sp_moe", degree=4)
        assert cert_on.r_o == cert_off.r_o
        assert cert_on.relation == cert_off.relation

        builder, _ = S.BUG_CASES["pad_slice"]
        seq_fn, dist_fn, axes, specs, avals, names = builder(
            degree=2, bug="pad_slice")
        gs = capture(seq_fn, avals, names)
        cap = capture_spmd(dist_fn, axes, specs, avals, names)
        gd, r_i = expand_spmd(cap)
        errs = []
        for flag in (True, False):
            set_optimizations(flag)
            with pytest.raises(RefinementError) as exc:
                check_refinement(gs, gd, r_i)
            errs.append((exc.value.op_index, exc.value.op_name,
                         exc.value.out_name))
        assert errs[0] == errs[1]
    finally:
        set_optimizations(True)


if st is not None:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(2, 6), st.integers(2, 6), st.integers(1, 3),
           st.integers(0, 10**6))
    def test_matmul_block_lemma_sound(m, k, n, seed):
        """Property: the block-matmul rewrite preserves numeric value."""
        rng = np.random.default_rng(seed)
        a = rng.normal(size=(m, 2 * k)).astype(np.float32)
        b = rng.normal(size=(2 * k, n)).astype(np.float32)
        lhs = T.matmul(T.tensor("a", a.shape), T.tensor("b", b.shape))
        rhs = T.add(
            T.matmul(T.slice_(T.tensor("a", a.shape), (0, 0), (m, k)),
                     T.slice_(T.tensor("b", b.shape), (0, 0), (k, n))),
            T.matmul(T.slice_(T.tensor("a", a.shape), (0, k), (m, 2 * k)),
                     T.slice_(T.tensor("b", b.shape), (k, 0), (2 * k, n))))
        env = {"a": a, "b": b}
        np.testing.assert_allclose(eval_term(lhs, env), eval_term(rhs, env),
                                   rtol=1e-4, atol=1e-4)

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.integers(-50, 50), min_size=1, max_size=5),
           st.integers(1, 4), st.integers(0, 10**6))
    def test_egraph_merge_find_invariants(vals, nmerge, seed):
        """Property: union-find stays canonical under arbitrary merges."""
        eg = EGraph()
        cids = [eg.add_term(T.tensor(f"x{i}", (abs(v) % 4 + 1,)))
                for i, v in enumerate(vals)]
        rng = np.random.default_rng(seed)
        for _ in range(nmerge):
            i, j = rng.integers(0, len(cids), 2)
            a, b = cids[i], cids[j]
            if eg.info(a).shape == eg.info(b).shape:
                eg.merge(a, b)
        eg.rebuild()
        for c in cids:
            r = eg.find(c)
            assert eg.find(r) == r
            assert r in eg.classes
else:  # pragma: no cover — visible skip so the gap is not silent
    @pytest.mark.skip(reason="hypothesis not installed (see "
                             "requirements-dev.txt)")
    def test_property_suite_requires_hypothesis():
        pass


def test_nary_add_normal_form():
    """The flattened n-ary add normal form replaces assoc/comm saturation:
    any binary grouping and any permutation of the same addends meet in
    one canonical class — without generative regrouping."""
    eg = EGraph()
    a = T.tensor("a@d", (2,)); b = T.tensor("b@d", (2,)); c = T.tensor("c@d", (2,))
    c1 = eg.add_term(T.add(T.add(a, b), c))          # ((a+b)+c)
    c2 = eg.add_term(T.add(a, T.add(c, b)))          # (a+(c+b))
    c3 = eg.add_term(T.add_n([c, b, a]))             # flat, permuted
    eg.rebuild()
    eg.saturate(all_lemmas())
    assert eg.find(c1) == eg.find(c2) == eg.find(c3)
    ce = eg.extract_clean(c1, lambda n: n.endswith("@d"))
    assert ce is not None and ce.op == "add"
    # extraction prefers the flat n-ary node (one op) to a binary chain
    assert len(ce.args) == 3


def test_add_n_flattens_and_evaluates():
    """add_n builds the flat normal form at construction and eval_term
    handles arbitrary arity."""
    xs = [T.tensor(f"x{i}", (3,)) for i in range(5)]
    t = T.add_n([T.add(xs[0], xs[1]), xs[2], T.add_n(xs[3:])])
    assert t.op == "add" and len(t.args) == 5        # fully flattened
    env = {f"x{i}": np.full((3,), float(i)) for i in range(5)}
    np.testing.assert_allclose(eval_term(t, env), np.full((3,), 10.0))
    assert T.add_n([xs[0]]) is xs[0]                 # 1-ary collapses


def test_dus_concat_lemma():
    """A complete dus chain over a zero-init buffer rewrites as the concat
    of its updates (the grad_accum gap closer) — and an *incomplete* chain
    does not."""
    eg = EGraph()
    zeros = T.broadcast(T.lit(0.0), (4, 3), ())
    u0 = T.tensor("u0@d", (2, 3)); u1 = T.tensor("u1@d", (2, 3))
    full = T.dus(T.dus(zeros, u0, (0, 0)), u1, (2, 0))
    c_full = eg.add_term(full)
    partial = T.dus(zeros, u0, (0, 0))               # half-covered buffer
    c_part = eg.add_term(partial)
    eg.rebuild()
    eg.saturate(all_lemmas())
    ce = eg.extract_clean(c_full, lambda n: n.endswith("@d"))
    assert ce is not None and ce.op == "concat"
    assert [a.name for a in ce.args] == ["u0@d", "u1@d"]
    # the incomplete chain must not collapse to a concat of updates only;
    # dus_unfold may soundly express it as u0 ++ zeros-suffix
    ce_p = eg.extract_clean(c_part, lambda n: n.endswith("@d"))
    if ce_p is not None:
        assert not all(a.op == "tensor" for a in ce_p.args)
        env_p = {"u0@d": 3 * np.ones((2, 3))}
        np.testing.assert_allclose(eval_term(ce_p, env_p),
                                   eval_term(partial, env_p))
    # numeric soundness of the rewrite
    env = {"u0@d": np.ones((2, 3)), "u1@d": 2 * np.ones((2, 3))}
    np.testing.assert_allclose(eval_term(ce, env), eval_term(full, env))


def test_dus_concat_rejects_full_buffer_write():
    """Soundness regression: a chain whose head write covers the *full*
    buffer must NOT rewrite as a concat of the (dead) inner tiles — the
    buffer's value is just the head update (dus_full's job)."""
    eg = EGraph()
    zeros = T.broadcast(T.lit(0.0), (2, 4), ())
    u1 = T.tensor("u1@d", (2, 2))
    u_full = T.tensor("uf@d", (2, 4))
    chain = T.dus(T.dus(zeros, u1, (0, 2)), u_full, (0, 0))
    c = eg.add_term(chain)
    eg.rebuild()
    eg.saturate(all_lemmas())
    ce = eg.extract_clean(c, lambda n: n.endswith("@d"))
    # dus_full rewrites the head to u_full; no concat may survive
    assert ce is not None and ce.op == "tensor" and ce.name == "uf@d"
    env = {"u1@d": np.ones((2, 2)), "uf@d": 7 * np.ones((2, 4))}
    np.testing.assert_allclose(eval_term(ce, env), eval_term(chain, env))


def test_dus_concat_out_of_order_chain_sorts_by_position():
    """servecheck's batched read writes cache rows out of order (positions
    rotate per decode step: 2, 3, 0, 1).  The chain still exactly tiles the
    buffer, so dus_concat must fire — with pieces sorted by *position*, not
    write order."""
    eg = EGraph()
    zeros = T.broadcast(T.lit(0.0), (4, 3), ())
    us = [T.tensor(f"u{i}@d", (1, 3)) for i in range(4)]
    chain = zeros
    for pos in (2, 3, 0, 1):
        chain = T.dus(chain, us[pos], (pos, 0))
    c = eg.add_term(chain)
    eg.rebuild()
    eg.saturate(all_lemmas())
    ce = eg.extract_clean(c, lambda n: n.endswith("@d"))
    assert ce is not None and ce.op == "concat"
    assert [a.name for a in ce.args] == ["u0@d", "u1@d", "u2@d", "u3@d"]
    env = {f"u{i}@d": (i + 1) * np.ones((1, 3)) for i in range(4)}
    np.testing.assert_allclose(eval_term(ce, env), eval_term(chain, env))


def test_dus_concat_bails_on_chain_not_starting_at_zero():
    """Soundness regression (the servecheck proofs lean on this bail): a
    chain whose tiles cover only [2, 6) of a 6-row buffer must NOT rewrite
    as the bare concat of its updates — rows [0, 2) are still the zero
    init.  Whatever the engine does extract must stay numerically equal to
    the original chain (dus_unfold may legitimately express it as
    zeros-prefix ++ updates)."""
    eg = EGraph()
    zeros = T.broadcast(T.lit(0.0), (6, 3), ())
    u0 = T.tensor("u0@d", (2, 3)); u1 = T.tensor("u1@d", (2, 3))
    chain = T.dus(T.dus(zeros, u0, (2, 0)), u1, (4, 0))
    c = eg.add_term(chain)
    eg.rebuild()
    eg.saturate(all_lemmas())
    ce = eg.extract_clean(c, lambda n: n.endswith("@d"))
    if ce is not None:
        # the unsound flat rewrite would be concat(u0, u1) — shape (4, 3)
        assert not (ce.op == "concat"
                    and all(a.op == "tensor" for a in ce.args))
        env = {"u0@d": np.ones((2, 3)), "u1@d": 2 * np.ones((2, 3))}
        got, want = eval_term(ce, env), eval_term(chain, env)
        assert got.shape == want.shape == (6, 3)
        np.testing.assert_allclose(got, want)


def test_reduce_reshape_lemma():
    """reduce_sum(reshape(x, (-1,)), (0,)) == reduce_sum(x, (0, 1)) — the
    segment lemma that closed the aux_loss completeness gap."""
    eg = EGraph()
    x = T.tensor("x@d", (4, 3))
    flat = T.reshape(x, (12,))
    c_seq = eg.add_term(T.reduce_("reduce_sum", flat, (0,)))
    c_dist = eg.add_term(T.reduce_("reduce_sum", x, (0, 1)))
    eg.rebuild()
    eg.saturate(all_lemmas())
    assert eg.find(c_seq) == eg.find(c_dist)


def test_scalar_factor_lemma_constrained():
    """div distributes into an existing add only when a per-addend scaled
    node already exists (constrained, paper §4.3.2) — and the equality it
    installs lets extraction reach the per-rank pieces."""
    eg = EGraph()
    a = T.tensor("a", ())
    b = T.tensor("b", ())
    four = T.lit(4.0)
    # G_s side: (a + b) / 4;  G_d side: per-rank p_i := x_i / 4 (the
    # pre-existing scaled nodes the constraint requires)
    c_whole = eg.add_term(T.ew2("div", T.add(a, b), four))
    eg.merge(eg.add_term(T.tensor("p0@d", ())),
             eg.add_term(T.ew2("div", a, four)))
    eg.merge(eg.add_term(T.tensor("p1@d", ())),
             eg.add_term(T.ew2("div", b, four)))
    eg.rebuild()
    eg.saturate(all_lemmas())
    ce = eg.extract_clean(c_whole, lambda n: n.endswith("@d"))
    assert ce is not None and ce.op == "add"
    # numeric soundness: reconstructing through the certificate matches
    env = {"p0@d": np.float32(3.0 / 4.0), "p1@d": np.float32(5.0 / 4.0)}
    np.testing.assert_allclose(eval_term(ce, env), (3.0 + 5.0) / 4.0)


def test_affine_solver():
    s = ScalarSolver()
    x = AffExpr.var("x")
    assert (x + 1 - x).as_int() == 1
    assert s.eq(2 * x + 2, 2 * (x + 1)) is True
    assert s.eq(x, x + 1) is False
    assert s.eq(x, 2 * x) is None       # unknown without bounds
    s.assume_range("x", 1, None)
    assert s.lt(x, 2 * x) is True


def test_scaling_with_degree():
    """Fig.5 analogue sanity: verification works at degrees 2 and 4."""
    for deg in (2, 4):
        cert = _run("sp_moe", degree=deg)
        assert cert.r_o


def test_spmd_expansion_semantics():
    """all_gather/psum/reduce_scatter expansion matches numpy semantics."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    def dist(x):
        g = jax.lax.all_gather(x, "tp", axis=0, tiled=True)
        s = jax.lax.psum(x, "tp")
        rs = jax.lax.psum_scatter(g, "tp", scatter_dimension=0, tiled=True)
        return g, s, rs

    avals = [jax.ShapeDtypeStruct((4, 3), jnp.float32)]
    cap = capture_spmd(dist, {"tp": 2}, [P("tp", None)], avals, ["x"])
    gd, r_i = expand_spmd(cap)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(4, 3)).astype(np.float32)
    env = {"x@tp0": x[:2], "x@tp1": x[2:]}
    for nm, term in gd.defs:
        env[nm] = eval_term(term, env)
    outs = gd.outputs
    g0 = env[outs[0]]
    np.testing.assert_allclose(g0, x, rtol=1e-6)           # gather = full x
    s0 = env[outs[2]]
    np.testing.assert_allclose(s0, x[:2] + x[2:], rtol=1e-6)  # psum
    rs0 = env[outs[4]]
    np.testing.assert_allclose(rs0, (x + x)[:2], rtol=1e-6)   # reduce-scatter

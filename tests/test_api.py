"""repro.api surface: registry semantics, verify() round-trips over every
registered strategy, Report serialization, and Suite determinism across
worker counts and engine-optimization settings."""
import json
import multiprocessing
import os
import time

import pytest

from repro.api import (BugSpec, DuplicateStrategyError, Report, StrategySpec,
                       Suite, axis_degrees, build_spec, bug_host,
                       degree_token, get_strategy, list_bugs,
                       list_strategies, normalize_degree, parse_degree,
                       register_strategy, verify)
from repro.api.spec import task_id
from repro.api.registry import _REGISTRY
from repro.api.spec import EXPECTED_VERDICT
from repro.launch.verify import CASES, run_case

ALL_CASES = list_strategies()
ALL_BUGS = sorted(list_bugs())

HAVE_FORK = "fork" in multiprocessing.get_all_start_methods()


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_registry_covers_paper_matrix():
    assert set(ALL_CASES) == {"tp_layer", "sp_rope", "sp_pad", "ep_moe",
                              "aux_loss", "sp_moe", "grad_accum", "ln_grad",
                              "fsdp_mlp", "pp_stage", "tp_dp_2d"}
    assert set(ALL_BUGS) == {"rope_offset", "aux_scale", "pad_slice",
                             "sharded_expert", "grad_accum",
                             "ln_no_allreduce", "stale_shard",
                             "rs_wrong_axis", "drop_microbatch",
                             "psum_wrong_axis"}
    # the 2D-mesh case declares per-axis tuple degrees, incl. the 16-rank
    # (4, 4) mesh the n-ary add normal form made tractable
    assert get_strategy("tp_dp_2d").degrees == ((2, 2), (2, 4), (4, 2),
                                                (4, 4))


def test_duplicate_registration_raises():
    with pytest.raises(DuplicateStrategyError):
        @register_strategy("tp_layer")
        def tp_again(degree=2, bug=None):  # pragma: no cover — never built
            raise AssertionError


def test_duplicate_bug_name_raises():
    """A shadowed bug name would re-host the bug past the wrong-host
    guard, silently verifying the clean graph."""
    with pytest.raises(DuplicateStrategyError, match="rope_offset"):
        @register_strategy("_thief", bugs=[BugSpec("rope_offset")])
        def _thief(degree=2, bug=None):  # pragma: no cover — never built
            raise AssertionError
    assert "_thief" not in list_strategies()


def test_register_rejects_bad_expectation():
    with pytest.raises(ValueError):
        register_strategy("nope", expected="refinement_error")
    with pytest.raises(ValueError):
        BugSpec("b", expected="certificate")


def test_unknown_names_raise():
    with pytest.raises(KeyError):
        get_strategy("no_such_case")
    with pytest.raises(KeyError):
        build_spec("no_such_case")
    with pytest.raises(KeyError):
        bug_host("no_such_bug")


@pytest.mark.parametrize("api_call", [
    lambda: verify("tp_layer", bug="rope_offset"),
    lambda: build_spec("tp_layer", bug="rope_offset"),
    lambda: run_case("tp_layer", bug="rope_offset", quiet=True),
])
def test_wrong_host_bug_guard(api_call):
    """Running a bug under the wrong case would silently verify the clean
    graph — the guard must fire through every entry point."""
    with pytest.raises(ValueError, match="belongs to case"):
        api_call()


def test_legacy_cases_view_mirrors_registry():
    assert set(CASES) == set(ALL_CASES)
    seq_fn, dist_fn, axes, specs, avals, names = CASES["tp_layer"](degree=2)
    assert callable(seq_fn) and callable(dist_fn)
    assert axes == {"tp": 2} and names == ["x", "w1", "w2"]


# ---------------------------------------------------------------------------
# StrategySpec
# ---------------------------------------------------------------------------

def test_spec_is_frozen_and_stamped():
    spec = build_spec("sp_rope", degree=4, bug="rope_offset")
    assert isinstance(spec, StrategySpec)
    assert (spec.name, spec.degree, spec.bug) == ("sp_rope", 4, "rope_offset")
    assert spec.expected == "refinement_error"
    assert spec.task_id() == "sp_rope@deg4+rope_offset"
    with pytest.raises(Exception):      # dataclasses.FrozenInstanceError
        spec.degree = 2


def test_spec_iterates_as_legacy_6tuple():
    spec = build_spec("ep_moe")
    tup = tuple(spec)
    assert len(tup) == 6
    assert tup[2] == {"ep": 2} and tup[5] == ["x", "w"]
    assert spec.as_tuple()[0] is spec.seq_fn


# ---------------------------------------------------------------------------
# multi-axis degree plumbing
# ---------------------------------------------------------------------------

def test_degree_normalization_and_tokens():
    assert normalize_degree(4) == 4
    assert normalize_degree([2, 4]) == (2, 4)
    assert normalize_degree((4,)) == 4          # 1-tuple collapses to int
    assert degree_token(4) == "4"
    assert degree_token([4, 2]) == "4x2"
    assert task_id("tp_dp_2d", (2, 4)) == "tp_dp_2d@deg2x4"
    assert task_id("tp_dp_2d", (2, 4), "psum_wrong_axis") == \
        "tp_dp_2d@deg2x4+psum_wrong_axis"


def test_parse_degree_cli_values():
    """`--degrees` accepts ints and per-axis `NxM` values (argparse type)."""
    assert parse_degree("4") == 4
    assert parse_degree("2x4") == (2, 4)
    assert parse_degree("2x2x2") == (2, 2, 2)
    for bad in ("x", "2x", "a", "2xa", "", "0", "-2", "2x0", "2x-1"):
        with pytest.raises(ValueError, match="bad degree"):
            parse_degree(bad)


def test_tuple_degree_rejected_for_single_axis_cases():
    """A per-axis tuple on a single-axis case must be a clear error, not an
    opaque TypeError inside the builder — and the Suite fails fast on it
    instead of aborting mid-matrix."""
    with pytest.raises(ValueError, match="single-axis"):
        build_spec("tp_layer", degree=(2, 4))
    with pytest.raises(ValueError, match="single-axis"):
        verify("sp_moe", degree=(2, 2))
    with pytest.raises(ValueError, match="single-axis"):
        Suite(degrees=[(2, 4)])
    with pytest.raises(ValueError, match="2.*-axis degrees"):
        build_spec("tp_dp_2d", degree=(2, 2, 2))   # wrong arity


def test_axis_degrees_broadcast_and_mismatch():
    assert axis_degrees(4, 2) == (4, 4)         # scalar broadcasts
    assert axis_degrees((4, 2), 2) == (4, 2)
    with pytest.raises(ValueError, match="2 entries for a 3-axis"):
        axis_degrees((4, 2), 3)


def test_multiaxis_spec_stamping_and_legacy_tuple():
    """A 2D-mesh spec carries its per-axis degree (normalized to a tuple)
    and still unpacks as the legacy 6-tuple."""
    spec = build_spec("tp_dp_2d", degree=[4, 2])      # list normalizes
    assert spec.degree == (4, 2)
    assert spec.task_id() == "tp_dp_2d@deg4x2"
    seq_fn, dist_fn, axes, specs, avals, names = spec
    assert callable(seq_fn) and callable(dist_fn)
    assert axes == {"dp": 4, "tp": 2}
    assert names == ["x", "w1", "w2"]
    # scalar degree broadcasts to both mesh axes
    assert build_spec("tp_dp_2d", degree=2).mesh_axes == {"dp": 2, "tp": 2}


def test_multiaxis_report_json_roundtrip():
    report = verify("tp_dp_2d", degree=(2, 2))
    assert report.ok and report.degree == (2, 2)
    back = Report.from_json(json.loads(json.dumps(report.to_json())))
    assert back.degree == (2, 2)                 # list -> tuple on the way in
    assert back.task_id() == report.task_id() == "tp_dp_2d@deg2x2"


def test_suite_sweeps_tuple_degrees_from_registry():
    tasks = Suite(cases=["tp_dp_2d"], include_bugs=True).tasks()
    ids = [t.task_id() for t in tasks]
    assert ids == ["tp_dp_2d@deg2x2", "tp_dp_2d@deg2x2+psum_wrong_axis",
                   "tp_dp_2d@deg2x4", "tp_dp_2d@deg2x4+psum_wrong_axis",
                   "tp_dp_2d@deg4x2", "tp_dp_2d@deg4x2+psum_wrong_axis",
                   "tp_dp_2d@deg4x4", "tp_dp_2d@deg4x4+psum_wrong_axis"]


# ---------------------------------------------------------------------------
# the FSDP / pipeline / 2D-mesh families (bug detection at degree 2 and 4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("degree", [2, 4])
def test_fsdp_bugs_detected(degree):
    clean = verify("fsdp_mlp", degree=degree)
    assert clean.ok and clean.verdict == "certificate"
    stale = verify("fsdp_mlp", degree=degree, bug="stale_shard")
    assert stale.ok and stale.verdict == "refinement_error"
    assert stale.localization["op_name"] == "matmul"
    # wrong scatter axis: clean certificate, but R_o assembles the grad
    # shards along dim 1 instead of dim 0 (paper bug 5 detection mode)
    wrong = verify("fsdp_mlp", degree=degree, bug="rs_wrong_axis")
    assert wrong.ok and wrong.verdict == "certificate"
    assert wrong.r_o != clean.r_o
    (grad_out,) = [k for k, v in wrong.r_o.items() if "dim=1" in v]
    assert "dim=0" in clean.r_o[grad_out]


@pytest.mark.parametrize("degree", [2, 4])
def test_pp_dropped_microbatch_detected(degree):
    clean = verify("pp_stage", degree=degree)
    assert clean.ok and clean.verdict == "certificate"
    # the whole pipeline's output lives on the last stage's rank
    assert list(clean.r_o.values())[0].endswith(f"@pp{degree - 1}")
    bug = verify("pp_stage", degree=degree, bug="drop_microbatch")
    assert bug.ok and bug.verdict == "refinement_error"


def test_tp_dp_2d_wrong_axis_detected():
    bug = verify("tp_dp_2d", degree=(2, 2), bug="psum_wrong_axis")
    assert bug.ok and bug.verdict == "refinement_error"


@pytest.mark.slow
@pytest.mark.parametrize("degree", [(2, 4), (4, 2), (4, 4)])
def test_tp_dp_2d_degree4_axes(degree):
    """Degree 4 on either (or both) mesh axes certifies and catches the
    wrong-axis psum — (4, 4) was a scale gap until the n-ary add normal
    form replaced assoc/comm saturation."""
    clean = verify("tp_dp_2d", degree=degree)
    assert clean.ok and clean.verdict == "certificate"
    bug = verify("tp_dp_2d", degree=degree, bug="psum_wrong_axis")
    assert bug.ok and bug.verdict == "refinement_error"


# ---------------------------------------------------------------------------
# verify() round-trips the whole registry (no hand-copied lists)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", ALL_CASES)
def test_verify_roundtrip_every_strategy(case):
    entry = get_strategy(case)
    report = verify(case, degree=2)
    assert report.ok, (report.verdict, report.expected, report.error)
    assert report.verdict == EXPECTED_VERDICT[entry.expected]
    if report.verdict == "certificate":
        assert report.r_o and all(isinstance(v, str)
                                  for v in report.r_o.values())
        assert report.stats["egraph_nodes"] > 0
        assert report.certificate is not None
    else:
        assert report.localization is not None
        assert report.localization["op_index"] >= 0


@pytest.mark.parametrize("bug", ALL_BUGS)
def test_verify_every_bug_through_registry(bug):
    host, bspec = list_bugs()[bug]
    report = verify(host, degree=2, bug=bug)
    assert report.ok, (bug, report.verdict, report.expected)
    if bspec.expected == "refinement_error":
        assert report.verdict == "refinement_error"
        assert report.localization["op_name"]
    else:                                # paper bug 5: clean-but-unexpected
        assert report.verdict == "certificate"
        clean = verify(host, degree=2)
        assert report.r_o != clean.r_o   # the unexpected relation


def test_verify_rejects_selectors_with_prebuilt_spec():
    spec = build_spec("sp_moe", degree=4)
    assert verify(spec).ok                    # spec alone is fine
    with pytest.raises(ValueError, match="already built"):
        verify(spec, degree=8)
    with pytest.raises(ValueError, match="already built"):
        verify(spec, bug="rope_offset")


def test_suite_rejects_bad_bug_filters():
    with pytest.raises(KeyError, match="unknown bug"):
        Suite(bugs=["rope_offzet"])
    with pytest.raises(ValueError, match="never run"):
        Suite(cases=["tp_layer"], bugs=["rope_offset"])


def test_report_json_roundtrip():
    report = verify("tp_layer")
    blob = json.dumps(report.to_json(), sort_keys=True)
    back = Report.from_json(json.loads(blob))
    assert back.to_json() == report.to_json()
    assert back.certificate is None      # live object never serialized


def test_engine_opts_restored_after_verify():
    from repro.core.profile import CONFIG
    before = CONFIG.as_dict()
    verify("ln_grad", engine_opts={"optimizations": False})
    assert CONFIG.as_dict() == before
    with pytest.raises(ValueError, match="unknown engine_opts"):
        verify("ln_grad", engine_opts={"max_nodez": 5})


# ---------------------------------------------------------------------------
# Suite
# ---------------------------------------------------------------------------

def test_suite_matrix_shape():
    suite = Suite(include_bugs=True)
    tasks = suite.tasks()
    by_id = [t.task_id() for t in tasks]
    assert len(by_id) == len(set(by_id))
    # bugs ride along only under their host case, at the host's degrees
    for t in tasks:
        if t.bug is not None:
            assert bug_host(t.bug) == t.case
        assert t.degree in get_strategy(t.case).degrees
    # grad_accum caps at degree 4 (batch divisibility)
    assert "grad_accum@deg8" not in by_id
    assert "ln_grad@deg2+ln_no_allreduce" in by_id


def test_suite_sequential_clean_matrix():
    result = Suite(degrees=(2,)).run(workers=0)
    assert len(result) == len(ALL_CASES) and result.ok
    md = result.to_markdown()
    assert "tp_layer@deg2" in md
    blob = json.dumps(result.to_json())
    assert "certificate" in blob


def test_suite_matches_checked_in_golden():
    """The CI gate in scripts/ci.sh `suite`, as a unit test: every
    registered strategy must still produce its golden verdict + R_o."""
    golden_path = os.path.join(os.path.dirname(__file__), "golden",
                               "suite_degree2.json")
    with open(golden_path) as f:
        golden = json.load(f)
    got = Suite(degrees=(2,)).run(workers=0).stable_summary()
    assert got == golden


@pytest.mark.slow
@pytest.mark.skipif(not HAVE_FORK, reason="needs fork start method")
def test_suite_deterministic_across_workers_and_opt():
    """Certificates must be byte-identical for any worker count and any
    GRAPHGUARD_OPT setting (extends the engine-ablation invariant to the
    parallel runner)."""
    cases = ["tp_layer", "sp_moe", "ln_grad"]
    summaries = []
    for opts in (True, False):
        for workers in (0, 2):
            with Suite(cases=cases, degrees=(2,),
                       engine_opts={"optimizations": opts}) as s:
                summaries.append(
                    json.dumps(s.run(workers=workers).stable_summary(),
                               sort_keys=True))
    assert len(set(summaries)) == 1, "results varied with workers/opt"


@pytest.mark.slow
@pytest.mark.skipif(not HAVE_FORK, reason="needs fork start method")
def test_suite_per_task_timeout():
    """A wedged task is reported as verdict=timeout without sinking the
    rest of the matrix, and the poisoned pool is discarded."""
    @register_strategy("_sleepy", degrees=(2,))
    def _sleepy(degree=2, bug=None):
        time.sleep(30)               # pragma: no cover — killed by timeout
        raise AssertionError
    try:
        with Suite(cases=["_sleepy", "ln_grad"], degrees=(2,)) as s:
            result = s.run(workers=2, timeout_s=2.0)
        by_case = {r.case: r for r in result}
        assert by_case["_sleepy"].verdict == "timeout"
        assert not by_case["_sleepy"].ok
        assert by_case["ln_grad"].verdict == "certificate"
        assert not result.ok
    finally:
        _REGISTRY.pop("_sleepy", None)

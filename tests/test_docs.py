"""Documentation gates: the lemma catalog, the CLI reference, and the
docstring ruleset are enforced here so docs cannot drift from code."""
import os
import re
import subprocess
import sys

from repro.core.lemmas import LEMMAS, all_lemmas

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _read(*parts):
    with open(os.path.join(ROOT, *parts), encoding="utf-8") as f:
        return f.read()


# ---------------------------------------------------------------------------
# docs/LEMMAS.md — every lemma has a catalog entry, and vice versa
# ---------------------------------------------------------------------------

def _catalog_names():
    return set(re.findall(r"^### `([a-z0-9_]+)`", _read("docs", "LEMMAS.md"),
                          flags=re.MULTILINE))


def test_every_lemma_is_catalogued():
    documented = _catalog_names()
    missing = {l.name for l in LEMMAS} - documented
    assert not missing, f"lemmas without a docs/LEMMAS.md entry: {missing}"


def test_no_stale_catalog_entries():
    stale = _catalog_names() - {l.name for l in all_lemmas()}
    assert not stale, f"docs/LEMMAS.md entries for unknown lemmas: {stale}"


def test_lemma_entries_state_trigger_ops_and_source():
    doc = _read("docs", "LEMMAS.md")
    for lemma in LEMMAS:
        m = re.search(rf"^### `{lemma.name}`([^\n]*)", doc, flags=re.M)
        heading = m.group(1)
        assert "ops:" in heading and "source:" in heading, lemma.name
        assert getattr(lemma, "source", "builtin") in heading, lemma.name


# ---------------------------------------------------------------------------
# docs/CLI.md — the --help block tracks the real argparse surface
# ---------------------------------------------------------------------------

def test_cli_help_block_in_sync():
    r = subprocess.run([sys.executable,
                        os.path.join(ROOT, "scripts", "check_cli_docs.py")],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_doc_covers_all_paths_and_exit_codes():
    doc = _read("docs", "CLI.md")
    for flag in ("--case", "--model", "--train", "--serve", "--fn",
                 "--json", "--list"):
        assert flag in doc, flag
    for env in ("GRAPHGUARD_OPT", "GRAPHGUARD_CACHE_DIR", "GRAPHGUARD_CHAOS"):
        assert env in doc, env
    assert '"schema_version": 2' in doc


# ---------------------------------------------------------------------------
# docstring ruleset over repro.core + repro.api
# ---------------------------------------------------------------------------

def test_docstring_coverage_gate():
    r = subprocess.run([sys.executable,
                        os.path.join(ROOT, "scripts", "check_docstrings.py")],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# ARCHITECTURE.md — package sections and live cross-links
# ---------------------------------------------------------------------------

def test_architecture_covers_every_subsystem():
    doc = _read("ARCHITECTURE.md")
    for pkg in ("repro.core", "repro.api", "repro.runtime",
                "repro.modelcheck", "repro.gradcheck", "repro.servecheck",
                "repro.obs"):
        assert pkg in doc, pkg


def test_architecture_links_resolve():
    doc = _read("ARCHITECTURE.md")
    for target in set(re.findall(r"\]\(([^)#]+)\)", doc)):
        if "://" in target:
            continue
        assert os.path.exists(os.path.join(ROOT, target)), \
            f"ARCHITECTURE.md links to missing path {target}"


# ---------------------------------------------------------------------------
# docs/OBSERVABILITY.md — metric names and span taxonomy track the code
# ---------------------------------------------------------------------------

def _source_metric_names():
    names = set()
    for dirpath, _dirs, files in os.walk(os.path.join(ROOT, "src", "repro")):
        for fn in files:
            if not fn.endswith(".py"):
                continue
            with open(os.path.join(dirpath, fn), encoding="utf-8") as f:
                src = f.read()
            names |= set(re.findall(
                r'REGISTRY\.(?:counter|histogram)\(\s*"([a-z_.]+)"', src))
    return names


def test_observability_doc_covers_every_live_metric():
    doc = _read("docs", "OBSERVABILITY.md")
    documented = set(re.findall(r"`([a-z_]+\.[a-z_]+)`", doc))
    live = _source_metric_names()
    assert live, "no REGISTRY.counter/histogram call sites found in src"
    missing = live - documented
    assert not missing, \
        f"metrics without a docs/OBSERVABILITY.md entry: {missing}"


def test_observability_doc_names_key_spans():
    doc = _read("docs", "OBSERVABILITY.md")
    for name in ("capture", "infer", "saturate", "extract", "task",
                 "queue", "run", "saturate.batch", "cache.probe",
                 "task.retry", "task.timeout", "pool.degraded"):
        assert f"`{name}`" in doc, name

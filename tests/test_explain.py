"""Proof provenance (repro.core.explain): certificate lemma chains
replay outside the e-graph, failure frontiers name the stuck operator,
and explanations are behaviour-neutral — certificates stay byte-identical
with recording off, and the chains themselves are byte-identical across
worker counts and the GRAPHGUARD_OPT engine modes."""
import gzip
import json
import os

import pytest

from repro.api import verify
from repro.core.explain import (aggregate_explanations, check_explanation,
                                explanation_steps, render_narrative)
from repro.core.profile import explain_enabled
from repro.gradcheck import check_train
from repro.launch.verify import main as verify_main
from repro.modelcheck import check_model
from repro.servecheck import check_serve


def _expl(case, **kw):
    rep = verify(case, engine_opts={"explain": True}, **kw)
    assert rep.verdict == "certificate"
    assert rep.explanation is not None
    return rep.explanation


# -- behaviour neutrality -----------------------------------------------------

def test_off_report_has_no_explanation_key():
    rep = verify("tp_layer")
    assert rep.explanation is None
    assert "explanation" not in rep.to_json()


def test_off_on_certificates_identical():
    off = verify("tp_layer")
    on = verify("tp_layer", engine_opts={"explain": True})
    assert off.r_o == on.r_o
    for k in ("egraph_nodes", "gs_ops", "gd_ops", "lemma_fires"):
        assert off.stats[k] == on.stats[k]


def test_off_family_reports_have_no_explanation_key():
    rep = check_train("dp")
    assert rep.explanation is None
    assert "explanation" not in rep.to_json()
    assert all("explanation" not in r for r in rep.reports.values())


def test_explain_enabled_override_beats_env(monkeypatch):
    monkeypatch.setenv("GRAPHGUARD_EXPLAIN", "1")
    assert explain_enabled() is True
    assert explain_enabled(False) is False
    monkeypatch.delenv("GRAPHGUARD_EXPLAIN")
    assert explain_enabled() is False
    assert explain_enabled(True) is True


def test_engine_token_isolates_explain_cache_entries():
    from repro.runtime.cache import _engine_token
    assert _engine_token({"explain": True}) != _engine_token(None)
    assert _engine_token({"explain": True}).endswith(":xp")


# -- certificate chains + replay ----------------------------------------------

@pytest.mark.parametrize("case", ["tp_layer", "fsdp_mlp", "sp_moe",
                                  "tp_dp_2d", "grad_accum"])
def test_chain_replays_outside_egraph(case):
    expl = _expl(case)
    assert expl["kind"] == "certificate"
    assert expl["total_steps"] >= 1
    res = check_explanation(expl)
    assert res["ok"], res["failures"]
    assert res["checked_steps"] >= expl["total_steps"]


def test_replay_rejects_tampered_step():
    expl = json.loads(json.dumps(_expl("tp_layer")))   # deep copy
    # corrupt one chain step's rhs term: flip its op name
    (out,) = [o for o in expl["outputs"].values() if o["steps"]][:1]
    step = out["steps"][0]
    step["rhs"]["op"] = "add" if step["rhs"]["op"] != "add" else "mul"
    res = check_explanation(expl)
    assert not res["ok"]
    assert res["failures"]


def test_chain_deterministic_across_opt_modes():
    from repro.core.profile import CONFIG, set_optimizations
    saved = CONFIG.as_dict()
    try:
        set_optimizations(True)
        on = _expl("tp_dp_2d")
        set_optimizations(False)
        off = _expl("tp_dp_2d")
    finally:
        set_optimizations(True, **saved)
    assert json.dumps(on, sort_keys=True) == json.dumps(off, sort_keys=True)


def test_chain_deterministic_across_hash_seeds():
    # member sets iterate in hash order; the engine sorts them
    # structurally (egraph._node_key) so the journal — and the chain —
    # survive hash randomization.  Must spawn fresh interpreters: the
    # seed is fixed per process.
    import subprocess
    import sys
    prog = ("import json,sys; sys.path.insert(0, 'src'); "
            "from repro.api import verify; "
            "print(json.dumps(verify('tp_dp_2d', "
            "engine_opts={'explain': True}).explanation, sort_keys=True))")
    outs = []
    for seed in ("1", "2"):
        env = dict(os.environ, PYTHONHASHSEED=seed)
        outs.append(subprocess.run(
            [sys.executable, "-c", prog], env=env, capture_output=True,
            text=True, cwd=os.path.dirname(os.path.dirname(
                os.path.abspath(__file__)))).stdout)
    assert outs[0] and outs[0] == outs[1]


def test_chain_deterministic_across_worker_counts():
    r1 = check_model("gpt", "dp2", workers=0,
                     engine_opts={"explain": True})
    r2 = check_model("gpt", "dp2", workers=2,
                     engine_opts={"explain": True})
    assert r1.verdict == r2.verdict == "certificate"
    assert json.dumps(r1.explanation, sort_keys=True) \
        == json.dumps(r2.explanation, sort_keys=True)
    for key in r1.reports:
        assert json.dumps(r1.reports[key].get("explanation"),
                          sort_keys=True) \
            == json.dumps(r2.reports[key].get("explanation"),
                          sort_keys=True)


# -- failure frontier ---------------------------------------------------------

def test_failure_frontier_names_stuck_op():
    rep = verify("sp_rope", bug="rope_offset",
                 engine_opts={"explain": True})
    assert rep.verdict == "refinement_error"
    expl = rep.explanation
    assert expl is not None and expl["kind"] == "failure_frontier"
    assert expl["stuck_op"]["op_name"]
    narrative = "\n".join(expl["narrative"])
    assert "stuck at" in narrative
    assert "lemma" in narrative
    assert render_narrative(expl) == expl["narrative"]


def test_failure_frontier_in_family_report():
    rep = check_train("dp_accum", bug="accum_no_rescale",
                      engine_opts={"explain": True})
    assert rep.ok
    frontiers = [r.get("explanation") for r in rep.reports.values()
                 if (r.get("explanation") or {}).get("kind")
                 == "failure_frontier"]
    assert len(frontiers) == 1
    assert frontiers[0]["stuck_op"]["op_name"]


# -- aggregation --------------------------------------------------------------

def test_aggregate_explanations_rolls_up():
    rep = check_serve("tp_decode", engine_opts={"explain": True})
    agg = rep.explanation
    assert agg is not None and agg["kind"] == "summary"
    assert agg["total_steps"] == sum(
        explanation_steps(r.get("explanation"))
        for r in rep.reports.values())
    assert set(agg["per_obligation"]) == set(rep.reports)
    assert aggregate_explanations({"a": {}, "b": {"x": 1}}) is None
    assert render_narrative(agg)[-1].startswith("total chain steps:")


# -- CLI envelope -------------------------------------------------------------

def test_cli_envelope_explanation_key(capsys):
    with pytest.raises(SystemExit):
        # clean --json run exits via return, but argparse-free paths
        # return None; guard either way
        verify_main(["--case", "sp_rope", "--bug", "rope_offset",
                     "--explain", "--json"])
    env = json.loads(capsys.readouterr().out)
    assert "explanation" in env
    assert env["explanation"]["kind"] == "failure_frontier"
    assert "explanation" not in env["report"]


def test_cli_envelope_without_explain_flag(capsys):
    verify_main(["--case", "tp_layer", "--json"])
    env = json.loads(capsys.readouterr().out)
    assert "explanation" not in env
    assert "explanation" not in env["report"]


# -- obs: gzip traces + json report -------------------------------------------

def test_trace_gzip_roundtrip(tmp_path):
    from repro.obs import trace as obs_trace
    tracer = obs_trace.Tracer("test")
    with tracer.span("outer", cat="engine", k=1):
        tracer.event("explain", cat="engine", outputs=2, steps=5)
    chrome = str(tmp_path / "t.json.gz")
    jsonl = str(tmp_path / "t.jsonl.gz")
    tracer.write_chrome(chrome)
    tracer.write_jsonl(jsonl)
    with gzip.open(chrome, "rt") as f:
        assert "traceEvents" in json.load(f)
    evs = obs_trace.load_events(chrome)
    assert any(e.get("name") == "explain" for e in evs)
    evs2 = obs_trace.load_events(jsonl)
    assert any(e.get("name") == "outer" for e in evs2)


def test_obs_report_json_stable(tmp_path, capsys):
    from repro.obs import trace as obs_trace
    from repro.obs.inspect import report, to_json_report
    tracer = obs_trace.Tracer("test")
    tracer.event("explain", cat="engine", outputs=1, steps=3)
    with tracer.span("explain.build", cat="engine"):
        pass
    path = str(tmp_path / "t.jsonl")
    tracer.write_jsonl(path)
    rc = report(path, as_json=True)
    assert rc == 0
    out = json.loads(capsys.readouterr().out)
    assert out["explanations"]["steps"] == 3
    assert out["explanations"]["explanations"] == 1
    # stable key order: serialization is sort_keys, so a round-trip
    # through to_json_report is deterministic
    evs = obs_trace.load_events(path)
    assert json.dumps(to_json_report(evs), sort_keys=True) \
        == json.dumps(to_json_report(evs), sort_keys=True)


def test_cli_trace_gz_sibling(tmp_path, capsys):
    path = str(tmp_path / "run.json.gz")
    verify_main(["--case", "tp_layer", "--json", "--trace", path])
    capsys.readouterr()
    assert os.path.exists(path)
    assert os.path.exists(str(tmp_path / "run.jsonl.gz"))

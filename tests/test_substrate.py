"""Substrate tests: data pipeline determinism, checkpoint round-trip,
optimizer behaviour, loss decreases on a tiny model."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data.pipeline import SyntheticTextDataset
from repro.models import registry
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainConfig, make_train_step


def test_pipeline_deterministic_and_sharded():
    a = SyntheticTextDataset(vocab=100, seq_len=16, batch=4, seed=7)
    b = SyntheticTextDataset(vocab=100, seq_len=16, batch=4, seed=7)
    np.testing.assert_array_equal(a.batch_at(3)["tokens"],
                                  b.batch_at(3)["tokens"])
    s0 = SyntheticTextDataset(vocab=100, seq_len=16, batch=4, seed=7,
                              n_shards=2, shard=0)
    s1 = SyntheticTextDataset(vocab=100, seq_len=16, batch=4, seed=7,
                              n_shards=2, shard=1)
    assert not np.array_equal(s0.batch_at(0)["tokens"],
                              s1.batch_at(0)["tokens"])


def test_checkpoint_roundtrip():
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": (np.ones(3, np.int32), np.zeros(2))}}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 12, tree)
        assert latest_step(d) == 12
        step, back = restore_checkpoint(d, 12, tree)
    assert step == 12
    np.testing.assert_array_equal(back["a"], tree["a"])
    np.testing.assert_array_equal(back["b"]["c"][0], tree["b"]["c"][0])


def test_adamw_moves_params_toward_gradient():
    params = {"w": jnp.ones((4,))}
    state = adamw.init(params)
    grads = {"w": jnp.ones((4,))}
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, weight_decay=0.0)
    new, state, gnorm = adamw.update(grads, state, params, cfg)
    assert float(gnorm) > 0
    assert np.all(np.asarray(new["w"]) < 1.0)


def test_loss_decreases_tiny_gpt():
    cfg = registry.load_config("gpt").reduced()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    step = jax.jit(make_train_step(
        cfg, TrainConfig(optimizer=AdamWConfig(lr=3e-3, warmup_steps=5))))
    ds = SyntheticTextDataset(vocab=cfg.vocab, seq_len=32, batch=4)
    losses = []
    for i in range(30):
        params, opt, m = step(params, opt, ds.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses


def test_grad_accum_matches_full_batch():
    """Microbatched grads == full-batch grads (the verified property)."""
    cfg = registry.load_config("gpt").reduced()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    ds = SyntheticTextDataset(vocab=cfg.vocab, seq_len=16, batch=4)
    batch = ds.batch_at(0)
    o1 = adamw.init(params)
    o2 = adamw.init(params)
    s1 = jax.jit(make_train_step(cfg, TrainConfig(microbatches=1)))
    s2 = jax.jit(make_train_step(cfg, TrainConfig(microbatches=2)))
    p1, _, m1 = s1(params, o1, batch)
    p2, _, m2 = s2(params, o2, batch)
    l1 = jax.tree.leaves(p1)
    l2 = jax.tree.leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)

"""Per-architecture smoke tests: reduced variants of each assigned config
run one forward and one train step on CPU; outputs have the right shapes and
no NaNs. Decode smoke: one serve_step against a fresh cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.models.config import InputShape
from repro.train.loop import TrainConfig, make_train_step, make_loss_fn
from repro.optim import adamw

# heavy: one forward + one train step per architecture; excluded from the
# quick gate via `-m "not slow"` (see Makefile `quick` target)
pytestmark = pytest.mark.slow

ARCHS = registry.ARCH_IDS + ["gpt"]


def _small_batch(cfg, B=2, S=32):
    rng = np.random.default_rng(0)
    batch = {}
    if cfg.family == "vlm":
        vt = cfg.vision_tokens
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S - vt)), jnp.int32)
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, vt, cfg.d_model)), jnp.float32)
    elif cfg.family == "audio":
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32)
    return batch


@pytest.fixture(scope="module", params=ARCHS)
def arch(request):
    cfg = registry.load_config(request.param).reduced()
    return cfg


def test_forward_shapes_no_nan(arch):
    cfg = arch
    B, S = 2, 32
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    batch = _small_batch(cfg, B, S)
    logits, _ = registry.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab), logits.shape
    assert not bool(jnp.isnan(logits).any()), f"NaNs in {cfg.name} logits"


def test_train_step_decreases_or_finite(arch):
    cfg = arch
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    step = jax.jit(make_train_step(cfg))
    batch = _small_batch(cfg)
    params, opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), cfg.name
    assert float(metrics["grad_norm"]) > 0.0


def test_decode_step(arch):
    cfg = arch
    B, max_seq = 2, 32
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    cache = registry.init_cache(cfg, B, max_seq)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = jax.jit(
        lambda p, c, t: registry.decode_step(p, cfg, c, t, 3))(
            params, cache, tok)
    assert logits.shape == (B, 1, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    # cache structure preserved
    jax.tree.map(lambda a, b: None, cache, new_cache)

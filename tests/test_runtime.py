"""Tests for repro.runtime — the fault-tolerant execution layer.

Three layers of coverage:

* cache units: journal roundtrip, torn/garbage line recovery, compaction,
  engine-fingerprint rotation, ``resolve_cache`` semantics, commit policy;
* pool units: per-task budgets (no shared-deadline starvation), crash
  quarantine with victim-only attribution, bounded retry recovery,
  in-process degradation, chaos containment;
* scheduler integration: Suite / check_model / check_train under injected
  faults — only the afflicted task errors, everything else stays
  byte-identical, and a warm cache resumes re-proving only what's missing.
"""
import json
import multiprocessing
import os
import time

import pytest

from repro.api import Suite, build_spec
from repro.runtime import (CertificateCache, DEFAULT_CACHE_DIR, PoolUnavailable,
                           RuntimeTask, SupervisedPool, cacheable_report,
                           chaos, execute_inline, obligation_cache_key,
                           resolve_cache, run_tasks, strategy_cache_key)
from repro.runtime.cache import ENV_CACHE_DIR, _line_for

HAVE_FORK = "fork" in multiprocessing.get_all_start_methods()
needs_fork = pytest.mark.skipif(not HAVE_FORK,
                                reason="needs fork start method")


@pytest.fixture(autouse=True)
def _no_ambient_chaos(monkeypatch):
    """Chaos/cache env must never leak between tests (or in from the
    invoking shell)."""
    for var in (chaos.ENV_SPEC, chaos.ENV_TARGET, chaos.ENV_SEED,
                ENV_CACHE_DIR):
        monkeypatch.delenv(var, raising=False)


# module-level so pool workers can pickle them ------------------------------

def _report(tag):
    return {"verdict": "certificate", "tag": tag}


def _nondeterministic_report(tag):
    return {"verdict": "error", "tag": tag}


def _sleep_report(tag, seconds):
    time.sleep(seconds)
    return {"verdict": "certificate", "tag": tag}


def _boom(tag):
    raise RuntimeError(f"synthetic failure for {tag}")


def _wedge_forever():
    time.sleep(3600)


def _task(key, fn=_report, args=None, **kw):
    kw.setdefault("budget_s", 30.0)      # bound the worst case: a wedged
    return RuntimeTask(key=key, fn=fn, args=args or (key,), **kw)


# these tasks never touch jax, so pool tests skip the jax warm-up
# initializer (warm=False) — forked workers stay pure-python
POOL_KW = {"warm": False}


# ---------------------------------------------------------------------------
# certificate cache
# ---------------------------------------------------------------------------

class TestCertificateCache:
    def test_roundtrip_and_stats(self, tmp_path):
        c = CertificateCache(tmp_path / "c")
        assert c.get("k1") is None           # miss
        c.put("k1", {"verdict": "certificate", "r_o": {"y": "x"}})
        assert c.get("k1") == {"verdict": "certificate", "r_o": {"y": "x"}}
        assert "k1" in c and len(c) == 1
        s = c.stats()
        assert (s["hits"], s["misses"], s["entries"]) == (1, 1, 1)
        # a fresh handle on the same directory sees the committed entry
        c2 = CertificateCache(tmp_path / "c")
        assert c2.get("k1")["r_o"] == {"y": "x"}
        assert c2.recovered_corrupt == 0

    def test_get_returns_defensive_copy(self, tmp_path):
        c = CertificateCache(tmp_path / "c")
        c.put("k", {"verdict": "certificate", "r_o": {"y": "x"}})
        c.get("k")["r_o"]["y"] = "tampered"
        assert c.get("k")["r_o"] == {"y": "x"}

    def test_torn_tail_line_recovered(self, tmp_path):
        c = CertificateCache(tmp_path / "c")
        for i in range(3):
            c.put(f"k{i}", {"verdict": "certificate", "i": i})
        # simulate the writer dying mid-append: cut the last line in half
        raw = open(c.journal_path, "rb").read()
        torn_at = len(raw) - (len(raw) - raw[:-1].rfind(b"\n") - 1) // 2
        with open(c.journal_path, "wb") as f:
            f.write(raw[:torn_at])
        c2 = CertificateCache(tmp_path / "c")
        assert c2.recovered_corrupt == 1
        assert len(c2) == 2 and "k2" not in c2
        assert c2.get("k0") == {"verdict": "certificate", "i": 0}

    def test_garbage_and_bad_digest_lines_skipped(self, tmp_path):
        c = CertificateCache(tmp_path / "c")
        c.put("good", {"verdict": "certificate"})
        with open(c.journal_path, "ab") as f:
            f.write(b"\x00\xffnot even text\n")
            # right shape, wrong digest (bit rot on the payload)
            line = _line_for("evil", {"verdict": "certificate"})
            f.write(line[:17] + b"X" + line[18:])
        c2 = CertificateCache(tmp_path / "c")
        assert c2.recovered_corrupt == 2
        assert len(c2) == 1 and "evil" not in c2

    def test_compact_drops_corruption(self, tmp_path):
        c = CertificateCache(tmp_path / "c")
        c.put("a", {"verdict": "certificate"})
        c.put("b", {"verdict": "certificate"})
        with open(c.journal_path, "ab") as f:
            f.write(b"garbage line\n")
        c.compact()
        lines = open(c.journal_path, "rb").read().splitlines()
        assert len(lines) == 2               # one clean line per live key
        c2 = CertificateCache(tmp_path / "c")
        assert len(c2) == 2 and c2.recovered_corrupt == 0

    def test_engine_fingerprint_rotation(self, tmp_path):
        d = tmp_path / "c"
        c = CertificateCache(d)
        c.put("k", {"verdict": "certificate"})
        meta = json.load(open(d / "meta.json"))
        meta["engine"] = "0" * len(meta["engine"])
        json.dump(meta, open(d / "meta.json", "w"))
        # a different engine must not reuse these proofs: journal rotates
        # aside instead of being reinterpreted
        c2 = CertificateCache(d)
        assert len(c2) == 0
        assert os.path.exists(str(d / "journal.jsonl") + ".stale")
        # the rewritten meta makes a third open warm again
        c2.put("k", {"verdict": "certificate"})
        assert len(CertificateCache(d)) == 1

    def test_resolve_cache_semantics(self, tmp_path, monkeypatch):
        assert resolve_cache(False) is None
        assert resolve_cache(None) is None           # no env, no cache
        monkeypatch.setenv(ENV_CACHE_DIR, str(tmp_path / "env"))
        assert resolve_cache(None).dir == str(tmp_path / "env")
        assert resolve_cache(False) is None          # False beats the env
        monkeypatch.chdir(tmp_path)
        assert resolve_cache(True).dir == DEFAULT_CACHE_DIR
        c = resolve_cache(tmp_path / "explicit")
        assert isinstance(c, CertificateCache)
        assert resolve_cache(c) is c                 # instance passthrough

    def test_cache_keys_embed_engine_limits(self):
        k = obligation_cache_key("blk-abc123")
        assert k.startswith("ob:blk-abc123:mn")
        assert obligation_cache_key("blk-abc123", {"max_nodes": 7}) \
            == "ob:blk-abc123:mn7"
        s2 = strategy_cache_key(build_spec("tp_layer", degree=2))
        assert s2 != strategy_cache_key(build_spec("sp_rope", degree=2))
        assert s2 != strategy_cache_key(build_spec("tp_layer", degree=2),
                                        {"max_nodes": 7})
        assert s2 == strategy_cache_key(build_spec("tp_layer", degree=2))

    def test_commit_policy_only_deterministic_verdicts(self):
        assert cacheable_report({"verdict": "certificate"})
        assert cacheable_report({"verdict": "refinement_error"})
        assert not cacheable_report({"verdict": "error"})
        assert not cacheable_report({"verdict": "timeout"})
        assert not cacheable_report("certificate")   # not a report dict


# ---------------------------------------------------------------------------
# chaos config
# ---------------------------------------------------------------------------

class TestChaos:
    def test_parse_spec(self):
        cfg = chaos.parse_spec("crash:0.3, hang:0.1", target="tp", seed=7)
        assert cfg.p("crash") == 0.3 and cfg.p("hang") == 0.1
        assert cfg.p("exit") == 0.0
        with pytest.raises(ValueError, match="unknown chaos mode"):
            chaos.parse_spec("explode:1")
        with pytest.raises(ValueError, match="not mode:prob"):
            chaos.parse_spec("crash")
        with pytest.raises(ValueError, match="must be in"):
            chaos.parse_spec("crash:1.5")

    def test_should_is_deterministic_and_targeted(self):
        cfg = chaos.parse_spec("crash:1", target="victim")
        assert chaos.should("crash", "the-victim-task", cfg=cfg)
        assert not chaos.should("crash", "innocent", cfg=cfg)
        assert not chaos.should("hang", "the-victim-task", cfg=cfg)
        half = chaos.parse_spec("crash:0.5", seed=3)
        draws = [chaos.should("crash", "k", a, half) for a in range(64)]
        assert draws == [chaos.should("crash", "k", a, half)
                         for a in range(64)]          # replayable
        assert any(draws) and not all(draws)          # attempt-varying

    def test_maybe_fault_is_noop_outside_workers(self, monkeypatch):
        monkeypatch.setenv(chaos.ENV_SPEC, "crash:1,exit:1,hang:1")
        chaos.maybe_fault("anything")    # would SIGSEGV us in a worker
        assert chaos.load_config().p("crash") == 1.0


# ---------------------------------------------------------------------------
# pool semantics
# ---------------------------------------------------------------------------

class TestPool:
    def test_inline_execution(self):
        out = execute_inline([_task("a"), _task("b")])
        assert out["a"].ok and out["a"].value == _report("a")
        assert out["b"].ok
        assert out["a"].runtime_info() == {}   # happy path stays silent

    def test_inline_task_error_contained(self):
        out = execute_inline([_task("bad", fn=_boom), _task("good")])
        assert out["bad"].status == "error"
        assert "synthetic failure" in out["bad"].error
        assert out["good"].ok                  # neighbour unaffected

    @needs_fork
    def test_pool_matches_inline(self):
        tasks = [_task(f"t{i}") for i in range(4)]
        pooled = run_tasks(tasks, workers=2, **POOL_KW)
        inline = run_tasks(tasks, workers=0)
        for k in inline:
            assert pooled[k].ok and pooled[k].value == inline[k].value
            assert pooled[k].runtime_info() == inline[k].runtime_info() == {}

    def test_duplicate_keys_rejected(self):
        with SupervisedPool(2, warm=False) as pool:
            with pytest.raises(ValueError, match="duplicate task keys"):
                pool.execute([_task("dup"), _task("dup")])

    @needs_fork
    def test_per_task_budget_not_shared(self):
        """Regression for the shared-deadline starvation bug: one slow
        task exhausts only its own budget — queued siblings still get
        their full budget and finish."""
        tasks = [_task("slow", fn=_sleep_report, args=("slow", 30.0),
                       budget_s=1.5)]
        tasks += [_task(f"quick{i}", budget_s=30.0) for i in range(3)]
        out = run_tasks(tasks, workers=2, **POOL_KW)
        assert out["slow"].status == "timeout"
        assert "budget" in out["slow"].error
        assert 1.0 <= out["slow"].wall_s < 10.0    # measured, not assumed
        for i in range(3):
            q = out[f"quick{i}"]
            assert q.ok and q.attempts == 1

    @needs_fork
    def test_crash_blamed_on_victim_only(self, monkeypatch):
        monkeypatch.setenv(chaos.ENV_SPEC, "crash:1")
        monkeypatch.setenv(chaos.ENV_TARGET, "victim")
        out = run_tasks([_task("victim"), _task("bystander-a"),
                         _task("bystander-b")], workers=2, **POOL_KW)
        v = out["victim"]
        assert v.status == "error" and v.attempts == 3
        assert "all 3 attempts" in v.error and "SIGSEGV" in v.error
        for k in ("bystander-a", "bystander-b"):
            assert out[k].ok and out[k].value == _report(k)

    @needs_fork
    def test_hard_exit_cause_reported(self, monkeypatch):
        monkeypatch.setenv(chaos.ENV_SPEC, "exit:1")
        monkeypatch.setenv(chaos.ENV_TARGET, "victim")
        out = run_tasks([_task("victim"), _task("ok")], workers=2,
                        **POOL_KW)
        assert out["victim"].status == "error"
        assert "exit code 3" in out["victim"].error
        assert out["ok"].ok

    @needs_fork
    def test_transient_crash_recovers_with_retry(self, monkeypatch):
        """A fault on the first attempt only: the quarantine retry gets a
        clean result and reports attempts > 1."""
        def cfg(seed):
            return chaos.parse_spec("crash:0.5", target="flaky", seed=seed)
        seed = next(s for s in range(1000)
                    if chaos.should("crash", "flaky", 1, cfg(s))
                    and not chaos.should("crash", "flaky", 2, cfg(s)))
        monkeypatch.setenv(chaos.ENV_SPEC, "crash:0.5")
        monkeypatch.setenv(chaos.ENV_TARGET, "flaky")
        monkeypatch.setenv(chaos.ENV_SEED, str(seed))
        out = run_tasks([_task("flaky")], workers=2, **POOL_KW)
        assert out["flaky"].ok and out["flaky"].value == _report("flaky")
        assert out["flaky"].attempts == 2
        assert out["flaky"].runtime_info() == {"attempts": 2}

    @needs_fork
    def test_wedged_worker_startup_times_out(self):
        """Liveness regression: a worker that wedges before its first
        heartbeat (e.g. on a fork-inherited lock) must burn the task's
        budget from executor pick-up, not hang execute() forever."""
        with SupervisedPool(2, warm=False) as pool:
            pool._initializer = _wedge_forever
            out = pool.execute([_task("stuck", budget_s=2.0)])
        assert out["stuck"].status == "timeout"
        assert "wedged during startup" in out["stuck"].error
        assert out["stuck"].wall_s >= 1.5

    def test_degrades_inline_when_pool_unavailable(self, monkeypatch):
        pool = SupervisedPool(2, warm=False)

        def no_pool(size):
            raise PoolUnavailable("no child processes on this host")
        monkeypatch.setattr(pool, "_make_executor", no_pool)
        try:
            out = pool.execute([_task("a"), _task("b")])
        finally:
            pool.shutdown()
        for k in ("a", "b"):
            assert out[k].ok and out[k].value == _report(k)
            assert "no child processes" in out[k].degraded_reason
            assert "degraded_reason" in out[k].runtime_info()

    def test_worker_chaos_never_fires_in_process(self, monkeypatch):
        # inline (workers <= 1) must survive crash:1 — a worker-side fault
        # fired in-process would take down the caller, the exact failure
        # the runtime exists to contain
        monkeypatch.setenv(chaos.ENV_SPEC, "crash:1,exit:1,hang:1")
        out = run_tasks([_task("a")], workers=0)
        assert out["a"].ok

    @needs_fork
    def test_pool_cache_hit_skips_execution(self, tmp_path):
        cache = CertificateCache(tmp_path / "c")
        sentinel = {"verdict": "certificate", "tag": "from-cache"}
        cache.put("ck-hit", sentinel)
        out = run_tasks([_task("hit", cache_key="ck-hit"),
                         _task("miss", cache_key="ck-miss")],
                        workers=2, cache=cache, **POOL_KW)
        assert out["hit"].value == sentinel
        assert out["hit"].cache == "hit" and out["hit"].attempts == 0
        assert out["miss"].cache == "miss"
        assert cache.get("ck-miss") == _report("miss")   # committed

    def test_nondeterministic_verdicts_never_cached(self, tmp_path):
        cache = CertificateCache(tmp_path / "c")
        out = execute_inline([_task("e", fn=_nondeterministic_report,
                                    cache_key="ck-e")], cache=cache)
        assert out["e"].ok and out["e"].cache == "miss"
        assert "ck-e" not in cache           # error verdicts must re-prove


# ---------------------------------------------------------------------------
# scheduler integration: faults stay contained, certificates stay identical
# ---------------------------------------------------------------------------

SUITE_CASES = ("tp_layer", "sp_rope")


def _suite_summaries(result):
    return {r.task_id(): json.dumps(r.stable_summary(), sort_keys=True)
            for r in result}


class TestSchedulerFaults:
    @pytest.mark.slow
    def test_suite_crash_survivors_identical(self, monkeypatch):
        """The crash-afflicted task fails alone with the crash attributed,
        and every survivor is byte-identical to a fault-free run.  Spawn
        workers: a fork pool created this deep into a jax-threaded pytest
        session can wedge on a fork-inherited lock (that containment path
        is covered by test_wedged_worker_startup_times_out)."""
        baseline = Suite(cases=SUITE_CASES, degrees=(2,)).run(workers=0)
        monkeypatch.setenv(chaos.ENV_SPEC, "crash:1")
        monkeypatch.setenv(chaos.ENV_TARGET, "tp_layer@deg2")
        with Suite(cases=SUITE_CASES, degrees=(2,)) as s:
            hit = s.run(workers=2, timeout_s=60.0, mp_method="spawn")
        by = {r.task_id(): r for r in hit}
        victim = by["tp_layer@deg2"]
        assert victim.verdict == "error" and not victim.ok
        assert "SIGSEGV" in victim.error
        assert victim.runtime["attempts"] == 3
        base = _suite_summaries(baseline)
        assert _suite_summaries(hit)["sp_rope@deg2"] == base["sp_rope@deg2"]

    def test_suite_cache_warm_run_identical(self, tmp_path):
        d = tmp_path / "c"
        cold = Suite(cases=SUITE_CASES, degrees=(2,)).run(workers=0, cache=d)
        assert cold.cache["misses"] == 2 and cold.cache["hits"] == 0
        warm = Suite(cases=SUITE_CASES, degrees=(2,)).run(workers=0, cache=d)
        assert warm.cache["hits"] == 2 and warm.cache["misses"] == 0
        assert _suite_summaries(warm) == _suite_summaries(cold)
        for r in warm:
            assert r.runtime == {"cache": "hit"}

    def test_modelcheck_cache_resume_reproves_only_damaged(self, tmp_path):
        from repro.modelcheck import check_model
        d = tmp_path / "c"
        cold = check_model("gpt", "dp2", workers=0, cache=d)
        assert cold.verdict == "certificate"
        assert cold.cache["misses"] == cold.unique_obligations
        # tear the last journal line (writer crashed mid-commit)
        cache = CertificateCache(d)
        raw = open(cache.journal_path, "rb").read()
        with open(cache.journal_path, "wb") as f:
            f.write(raw[:-10])
        warm = check_model("gpt", "dp2", workers=0, cache=d)
        assert warm.cache["hits"] == cold.unique_obligations - 1
        assert warm.cache["misses"] == 1     # only the torn entry re-proved
        assert warm.cache["recovered_corrupt"] == 1
        assert {k: v["r_o"] for k, v in warm.reports.items()} \
            == {k: v["r_o"] for k, v in cold.reports.items()}

    @pytest.mark.slow
    def test_modelcheck_crash_localized_to_obligation(self, monkeypatch):
        from repro.modelcheck import check_model
        from repro.modelcheck.decompose import decompose
        clean = check_model("gpt", "dp2", workers=0)
        victim = decompose("gpt", "dp2").obset.keys_in_order()[1]
        monkeypatch.setenv(chaos.ENV_SPEC, "crash:1")
        monkeypatch.setenv(chaos.ENV_TARGET, victim)
        rep = check_model("gpt", "dp2", workers=2)
        assert rep.verdict == "error" and not rep.ok
        errored = {b.obligation for b in rep.blocks if b.verdict == "error"}
        assert errored == {victim}           # blame lands on the victim only
        for key, nested in rep.reports.items():
            if key != victim:
                assert nested["verdict"] == clean.reports[key]["verdict"]
                assert nested["r_o"] == clean.reports[key]["r_o"]

    @pytest.mark.slow
    def test_gradcheck_hang_times_out_one_param(self, monkeypatch):
        from repro.gradcheck import check_train
        monkeypatch.setenv(chaos.ENV_SPEC, "hang:1")
        monkeypatch.setenv(chaos.ENV_TARGET, ":w1")
        rep = check_train("dp_accum", workers=2, timeout_s=4.0)
        assert not rep.ok and rep.verdict != "certificate"
        assert rep.failing_params == ["w1"]
        assert rep.reports["w1"]["verdict"] == "timeout"
        assert "budget" in rep.reports["w1"]["error"]
        assert rep.reports["w2"]["verdict"] == "certificate"

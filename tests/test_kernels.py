"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm


@pytest.mark.parametrize("shape", [(4, 128), (2, 16, 256), (1, 7, 384),
                                   (3, 5, 8, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape), dtype)
    s = jnp.asarray(rng.normal(size=shape[-1:]) * 0.1, dtype)
    got = rmsnorm(x, s, interpret=True)
    want = ref.rmsnorm_ref(x, s)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,H,hd", [(1, 128, 2, 64), (2, 256, 1, 32),
                                      (1, 64, 4, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, S, H, hd, causal, dtype):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_blocks_sweep():
    rng = np.random.default_rng(2)
    B, S, H, hd = 1, 128, 1, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    for bq in (32, 64, 128):
        for bk in (32, 64, 128):
            got = flash_attention(q, k, v, causal=True, block_q=bq,
                                  block_k=bk, interpret=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=2e-4, rtol=2e-4)

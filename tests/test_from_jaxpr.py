"""Generic jaxpr frontend: cross-checks against the registered frontend
(byte-identical certificates), strict-mode UnsupportedPrimitive contracts,
verify_functions verdicts, and the `--fn` CLI path."""
import json

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.api import (build_spec, function_spec, run_functions, run_spec,
                       verify_functions)
from repro.core import (SUPPORTED_PRIMITIVES, UnsupportedPrimitive,
                        capture_function, capture_spmd_function,
                        normalize_mesh)
from repro.core.from_jaxpr import default_input_names, source_location
from repro.launch.verify import main as verify_main

# ---------------------------------------------------------------------------
# cross-check: capturing the registry's real jax functions through the
# generic frontend yields byte-identical certificates to run_spec
# ---------------------------------------------------------------------------

CROSS_CHECK_CASES = ["tp_layer", "sp_rope", "ep_moe", "aux_loss",
                     "grad_accum", "ln_grad", "fsdp_mlp", "tp_dp_2d"]


@pytest.mark.parametrize("case", CROSS_CHECK_CASES)
def test_byte_identical_certificates(case):
    spec = build_spec(case)
    golden = run_spec(spec).to_json()
    cert = run_functions(spec.seq_fn, spec.dist_fn, spec.mesh_axes,
                         spec.in_specs, spec.avals,
                         spec.input_names).to_json()
    assert json.dumps(cert["r_o"], sort_keys=True) == \
        json.dumps(golden["r_o"], sort_keys=True)
    # same engine work, not just the same final relation
    for key in ("egraph_nodes", "gs_ops", "gd_ops"):
        assert cert["stats"][key] == golden["stats"][key]


def test_cross_check_covers_at_least_six_cases():
    assert len(CROSS_CHECK_CASES) >= 6


# ---------------------------------------------------------------------------
# strict-mode contract: UnsupportedPrimitive names the eqn and its source
# ---------------------------------------------------------------------------

def _ssm(x, a):
    def step(h, xt):
        h = a * h + xt          # ssm-style recurrence -> lax.scan
        return h, h
    _, ys = jax.lax.scan(step, jnp.zeros_like(x[0]), x)
    return ys


def test_over_budget_scan_names_primitive_and_source():
    avals = [jax.ShapeDtypeStruct((16, 4), jnp.float32),
             jax.ShapeDtypeStruct((4,), jnp.float32)]
    with pytest.raises(UnsupportedPrimitive) as ei:
        capture_function(_ssm, avals)
    err = ei.value
    assert err.primitive == "scan"
    assert "test_from_jaxpr.py" in err.source       # the user's source line
    assert "unroll budget" in err.reason
    assert "strict=False" in str(err)


def test_unknown_primitive_raises_strict_and_is_opaque_lenient():
    def f(x):
        return jnp.sort(x, axis=0)
    avals = [jax.ShapeDtypeStruct((8,), jnp.float32)]
    with pytest.raises(UnsupportedPrimitive) as ei:
        capture_function(f, avals)
    assert ei.value.primitive == "sort"
    assert "test_from_jaxpr.py" in ei.value.source
    g = capture_function(f, avals, strict=False)    # lenient: opaque term
    assert any("opaque:sort" in repr(t) for _, t in g.defs)


def test_strict_spmd_capture_raises_too():
    def f(x):
        return jax.lax.psum(jnp.sort(x, axis=0), "tp")
    avals = [jax.ShapeDtypeStruct((8,), jnp.float32)]
    with pytest.raises(UnsupportedPrimitive):
        capture_spmd_function(f, {"tp": 2}, [P("tp")], avals)


def test_strict_hook_is_scoped():
    # after a strict failure the lenient path must be back to normal
    def f(x):
        return jnp.sort(x, axis=0)
    avals = [jax.ShapeDtypeStruct((8,), jnp.float32)]
    with pytest.raises(UnsupportedPrimitive):
        capture_function(f, avals)
    g = capture_function(f, avals, strict=False)
    assert any("opaque:" in repr(t) for _, t in g.defs)


def test_supported_primitives_is_a_real_vocabulary():
    assert {"dot_general", "psum", "all_gather", "reduce_sum",
            "concatenate", "tanh", "add"} <= SUPPORTED_PRIMITIVES
    assert "sort" not in SUPPORTED_PRIMITIVES


def test_source_location_is_best_effort():
    class NoInfo:
        source_info = None
    assert source_location(NoInfo()) == "<unknown>"


# ---------------------------------------------------------------------------
# verify_functions verdicts
# ---------------------------------------------------------------------------

def _seq_mlp(x, w1, w2):
    return jnp.tanh(x @ w1) @ w2


def _dist_mlp(x, w1, w2):
    return jax.lax.psum(jnp.tanh(x @ w1) @ w2, "tp")


def _dist_mlp_halved(x, w1, w2):
    return jax.lax.psum(jnp.tanh(x @ w1) @ w2, "tp") * 0.5


_MLP_AVALS = [jax.ShapeDtypeStruct(s, jnp.float32)
              for s in ((4, 8), (8, 8), (8, 8))]
_MLP_SPECS = (P(), P(None, "tp"), P("tp", None))


def test_verify_functions_certificate():
    r = verify_functions(_seq_mlp, _dist_mlp, {"tp": 2}, _MLP_SPECS,
                         avals=_MLP_AVALS)
    assert r.verdict == "certificate" and r.ok
    assert r.r_o                      # non-empty clean output relation
    assert r.case == "_dist_mlp" and r.degree == 2


def test_verify_functions_refinement_error_localizes():
    r = verify_functions(_seq_mlp, _dist_mlp_halved, {"tp": 2}, _MLP_SPECS,
                         avals=_MLP_AVALS, name="halved")
    assert r.verdict == "refinement_error" and not r.ok
    assert r.case == "halved"
    assert "op_index" in r.localization and "op_name" in r.localization


def test_verify_functions_unsupported_becomes_error_verdict():
    def dist_sorted(x, w1, w2):
        return jax.lax.psum(jnp.sort(jnp.tanh(x @ w1) @ w2, axis=0), "tp")
    r = verify_functions(_seq_mlp, dist_sorted, {"tp": 2}, _MLP_SPECS,
                         avals=_MLP_AVALS)
    assert r.verdict == "error"
    assert "UnsupportedPrimitive" in r.error and "sort" in r.error


def test_example_args_instead_of_avals():
    args = [jnp.zeros(a.shape, a.dtype) for a in _MLP_AVALS]
    r = verify_functions(_seq_mlp, _dist_mlp, {"tp": 2}, _MLP_SPECS,
                         example_args=args)
    assert r.verdict == "certificate"


def test_caller_mistakes_raise_not_verdict():
    with pytest.raises(ValueError):   # both avals and example_args
        verify_functions(_seq_mlp, _dist_mlp, {"tp": 2}, _MLP_SPECS,
                         avals=_MLP_AVALS, example_args=_MLP_AVALS)
    with pytest.raises(ValueError):   # neither
        verify_functions(_seq_mlp, _dist_mlp, {"tp": 2}, _MLP_SPECS)
    with pytest.raises(ValueError):   # in_specs arity mismatch
        verify_functions(_seq_mlp, _dist_mlp, {"tp": 2}, (P(),),
                         avals=_MLP_AVALS)


def test_function_spec_defaults():
    spec = function_spec(_seq_mlp, _dist_mlp, {"tp": 2}, _MLP_SPECS,
                         avals=_MLP_AVALS)
    assert spec.name == "_dist_mlp" and spec.degree == 2
    assert spec.input_names == ("x", "w1", "w2")    # from the signature
    spec2d = function_spec(_seq_mlp, _dist_mlp, {"dp": 2, "tp": 2},
                           (P(), P(None, "tp"), P("tp", None)),
                           avals=_MLP_AVALS, name="mlp2d")
    assert spec2d.name == "mlp2d" and spec2d.degree == (2, 2)


def test_default_input_names_fallback():
    assert default_input_names(_seq_mlp, 3) == ["x", "w1", "w2"]
    assert default_input_names(lambda *a: a, 2) == ["arg0", "arg1"]


def test_normalize_mesh_forms():
    assert normalize_mesh({"tp": 2}) == {"tp": 2}
    assert normalize_mesh([("dp", 2), ("tp", 4)]) == {"dp": 2, "tp": 4}
    with pytest.raises(ValueError):
        normalize_mesh({"tp": 0})
    with pytest.raises(TypeError):
        normalize_mesh(42)


# ---------------------------------------------------------------------------
# the --fn CLI path (schema-v2 JSON envelope, exit codes)
# ---------------------------------------------------------------------------

def _fn_cli(capsys, argv):
    try:
        verify_main(argv)
        rc = 0
    except SystemExit as e:
        rc = int(e.code or 0)
    return rc, capsys.readouterr().out


def test_cli_fn_example_task(capsys):
    rc, out = _fn_cli(capsys, ["--fn",
                               "examples/verify_your_own_fn.py:make_task",
                               "--json"])
    assert rc == 0
    env = json.loads(out)
    assert env["schema_version"] == 2 and env["kind"] == "fn"
    assert env["report"]["verdict"] == "certificate"
    assert env["report"]["case"] == "my_tp_mlp"


def test_cli_fn_bad_target_is_harness_error(capsys):
    rc, _ = _fn_cli(capsys, ["--fn", "examples/no_such_file.py:make_task"])
    assert rc == 2
    rc, _ = _fn_cli(capsys, ["--fn", "not-a-target"])
    assert rc == 2


def test_cli_fn_excludes_case_flags(capsys):
    rc, _ = _fn_cli(capsys, ["--fn",
                             "examples/verify_your_own_fn.py:make_task",
                             "--case", "tp_layer"])
    assert rc == 2

"""repro.gradcheck: train-step strategies certify per-parameter, injected
gradient bugs localize to the offending parameter, relations transpose
from the forward specs, and the versioned CLI --json envelope is stable
across all three paths (case / --model / --train)."""
import json

import pytest

from repro.api import check_train_task, list_train_tasks
from repro.gradcheck import (TrainReport, capture_grad, check_train,
                             expected_grad_relation, get_train_strategy,
                             grad_collective, list_train_bugs,
                             list_train_strategies, register_train_strategy)
from repro.launch.verify import main as verify_main

ALL_TRAIN = list_train_strategies()
ALL_TRAIN_BUGS = sorted(list_train_bugs())


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_train_registry_covers_strategies_and_bugs():
    assert set(ALL_TRAIN) == {"dp", "dp_accum", "fsdp", "tp_dp_2d"}
    assert set(ALL_TRAIN_BUGS) == {"accum_no_rescale", "stale_grad_shard",
                                   "grad_psum_wrong_axis"}
    assert list_train_tasks() == tuple(f"train@{s}" for s in ALL_TRAIN)
    # the 16-rank mesh the n-ary add normal form made tractable is swept
    assert (4, 4) in get_train_strategy("tp_dp_2d").degrees


def test_train_registry_guards():
    with pytest.raises(KeyError, match="unknown train strategy"):
        get_train_strategy("no_such")
    with pytest.raises(ValueError, match="belongs to train strategy"):
        get_train_strategy("dp").build(bug="accum_no_rescale")
    with pytest.raises(ValueError, match="not hosted"):
        check_train("dp", bug="stale_grad_shard")
    with pytest.raises(ValueError, match="single-axis"):
        check_train("dp", degree=(2, 2))
    with pytest.raises(ValueError, match="already registered"):
        register_train_strategy("dp")(lambda degree=2, bug=None: {})
    with pytest.raises(KeyError, match="bad train task"):
        check_train_task("dp")                 # missing the train@ prefix


# ---------------------------------------------------------------------------
# backward capture
# ---------------------------------------------------------------------------

def test_capture_grad_backward_graph():
    """capture_grad traces the backward of a loss into a sequential Graph:
    the w2 gradient of sum(tanh(x@w1)@w2) is a transposed-matmul program
    whose single output has w2's shape."""
    from repro.gradcheck.obligations import _AVALS, _NAMES, _loss

    g = capture_grad(_loss, _AVALS, _NAMES, wrt=2)
    assert g.n_ops > 0 and len(g.outputs) == 1
    assert g.shapes[g.outputs[0]] == tuple(_AVALS[2].shape)
    ops = {t.op for _, t in g.defs} | {
        op for _, t in g.defs for op in t.ops_used()}
    assert "matmul" in ops and "transpose" in ops   # the AD transpose


# ---------------------------------------------------------------------------
# relation transposition
# ---------------------------------------------------------------------------

def test_grad_collective_transposition():
    from jax.sharding import PartitionSpec as P
    mesh = {"dp": 2}
    # replicated param, dp-sharded data -> psum over dp
    assert grad_collective(P(), P("dp", None), mesh) == ("psum", ("dp",))
    # dp-sharded param, dp-sharded data -> reduce_scatter (ZeRO)
    assert grad_collective(P("dp", None), P("dp", None), mesh) == \
        ("reduce_scatter", ("dp",))
    # replicated data -> nothing owed
    assert grad_collective(P(), P(), mesh) == ("identity", ())
    # 2D mesh: tp-sharded param, dp-sharded data -> psum over dp only
    assert grad_collective(P(None, "tp"), P("dp", None),
                           {"dp": 2, "tp": 2}) == ("psum", ("dp",))


def test_expected_grad_relation_terms():
    from jax.sharding import PartitionSpec as P
    # replicated parameter: identity at replica coordinate 0
    t = expected_grad_relation("g", (4, 4), "f", P(), {"dp": 2})
    assert str(t) == "g@dp0"
    # sharded parameter: the concat of shards (the transposed forward map)
    t = expected_grad_relation("g", (2, 4), "f", P("dp", None), {"dp": 2})
    assert str(t) == "concat(g@dp0, g@dp1, dim=0)"


# ---------------------------------------------------------------------------
# clean certification + bug localization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ALL_TRAIN)
def test_train_strategy_certifies(strategy):
    report = check_train(strategy)
    assert report.ok and report.verdict == "certificate", \
        (strategy, report.failing_params)
    assert not report.failing_params
    for p in report.params:
        assert p.verdict == "certificate" and p.relation_ok
        assert p.collective.startswith(("psum", "reduce_scatter"))


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ALL_TRAIN)
def test_train_strategy_certifies_at_all_degrees(strategy):
    for degree in get_train_strategy(strategy).degrees:
        report = check_train(strategy, degree=degree)
        assert report.ok, (strategy, degree, report.failing_params)


@pytest.mark.parametrize("bug", ALL_TRAIN_BUGS)
def test_train_bug_localizes_to_parameter(bug):
    host, bspec = list_train_bugs()[bug]
    target = get_train_strategy(host).bug_params[bug]
    report = check_train(host, bug=bug)
    assert report.ok, (bug, report.verdict, report.failing_params)
    assert report.verdict == "refinement_error"
    # sharp localization: exactly the offending parameter fails, the
    # sibling parameter's gradient still certifies
    assert report.failing_params == [target] == [report.bug_param]
    by_param = {p.param: p for p in report.params}
    assert by_param[target].verdict == "refinement_error"
    assert by_param[target].localized_op
    for p in report.params:
        if p.param != target:
            assert p.verdict == "certificate" and p.relation_ok


def test_train_report_json_roundtrip():
    report = check_train("dp")
    blob = json.dumps(report.to_json(), sort_keys=True)
    back = TrainReport.from_json(json.loads(blob))
    assert back.stable_summary() == report.stable_summary()
    assert back.task_id() == report.task_id() == "train@dp@deg2"
    md = report.to_markdown()
    assert "psum(dp)" in md and "certificate" in md


def test_check_train_task_api():
    report = check_train_task("train@fsdp", degree=2)
    assert report.ok and report.verdict == "certificate"
    assert {p.collective for p in report.params} == {"reduce_scatter(dp)"}


# ---------------------------------------------------------------------------
# the versioned --json envelope across all three CLI paths
# ---------------------------------------------------------------------------

def _envelope(capsys, argv):
    try:
        verify_main(argv)
    except SystemExit as e:               # bug paths exit(1) by design
        assert e.code in (None, 0, 1)
    return json.loads(capsys.readouterr().out)


@pytest.mark.parametrize("kind,argv", [
    ("case", ["--case", "tp_layer", "--json"]),
    ("model", ["--model", "gpt", "--plan", "dp2", "--json"]),
    ("train", ["--train", "dp", "--json"]),
])
def test_json_envelope_all_paths(capsys, kind, argv):
    """Every CLI path emits the same versioned envelope: schema_version,
    kind, per-phase timing, report — and the envelope byte-identically
    survives a json.loads -> json.dumps round trip."""
    env = _envelope(capsys, argv)
    assert env["schema_version"] == 2
    assert env["kind"] == kind
    assert set(env) == {"schema_version", "kind", "timing", "report"}
    # timing.phase_s keys are the engine's stable phase names
    phases = env["timing"].get("phase_s") or env["timing"].get("phase_s_sum")
    assert phases is not None
    assert set(phases) <= {"saturate", "rebuild", "frontier", "extract"}
    assert {"saturate", "extract"} <= set(phases)
    blob = json.dumps(env, indent=2, sort_keys=True)
    assert json.dumps(json.loads(blob), indent=2, sort_keys=True) == blob


def _stable_envelope(env):
    """Strip timing-dependent fields, keep every certificate byte."""
    env = json.loads(json.dumps(env))     # deep copy
    env.pop("timing", None)
    rep = env["report"]
    for k in ("wall_s", "workers", "timing", "pool"):
        rep.pop(k, None)
    for nested in (rep.get("reports") or {}).values():
        nested.pop("stats", None)
        nested.pop("wall_s", None)
    rep.pop("stats", None)
    return json.dumps(env, sort_keys=True)


def test_train_envelope_identical_across_worker_counts(capsys):
    """The --train envelope's stable content (verdicts, certificates,
    relations) must be byte-identical for any worker count."""
    a = _envelope(capsys, ["--train", "dp_accum", "--json", "--workers", "1"])
    b = _envelope(capsys, ["--train", "dp_accum", "--json", "--workers", "2"])
    assert a["report"]["workers"] != b["report"]["workers"]
    assert _stable_envelope(a) == _stable_envelope(b)


def test_cli_list_kind_tags(capsys):
    verify_main(["--list"])
    out = capsys.readouterr().out
    assert "[case]" in out and "[model]" in out and "[train]" in out
    assert "train@dp_accum" in out
    assert "accum_no_rescale" in out

"""repro.modelcheck: whole-model verification, obligation dedup, stitching.

Covers the subsystem contract end to end: decomposition shape, the dedup
cache (layer-count invariance + byte-identical certificates on cache
hits), seam checking, whole-model certificates, injected-bug localization
to the offending block, scheduler determinism across worker counts, the
model-task registry entries, and the CLI envelope.
"""
import dataclasses
import json

import pytest

from repro.api import check_model_task, list_model_tasks
from repro.core import capture_chain
from repro.models.registry import load_config
from repro.modelcheck import (ModelCheckError, ObligationSet, check_model,
                              decompose, expected_output_relation,
                              supported_models)
from repro.modelcheck.blocks import layer_obligation
from repro.sharding.specs import DEFAULT_PLANS, parse_plan


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------

def test_parse_plan():
    plan = parse_plan("dp2xtp2")
    assert plan.mesh_axes == {"dp": 2, "tp": 2}
    assert plan.degree == (2, 2)
    assert parse_plan("dp4").mesh_axes == {"dp": 4}
    with pytest.raises(ValueError):
        parse_plan("dp1")            # size-1 axis: drop it instead
    with pytest.raises(ValueError):
        parse_plan("zz2")            # unknown axis
    with pytest.raises(ValueError):
        parse_plan("dp2xdp2")        # duplicate axis


def test_plan_rules_drive_specs():
    plan = parse_plan("dp2xtp2")
    assert tuple(plan.spec_for(("batch", "seq", "embed"))) == \
        ("dp", None, None)
    assert tuple(plan.spec_for(("embed", "heads"))) == (None, "tp")
    # a dp-only plan leaves tensor dims unsharded
    assert set(parse_plan("dp2").spec_for(("embed", "heads"))) <= {None}


# ---------------------------------------------------------------------------
# decomposition + dedup
# ---------------------------------------------------------------------------

def test_decompose_gpt_block_structure():
    dec = decompose("gpt", "dp2xtp2")
    names = [n for n, _ in dec.obset.blocks]
    assert names[0] == "embed" and names[-1] == "head"
    assert len(names) == load_config("gpt").n_layers + 2
    # 12 identical layers + embed + head -> exactly 3 unique obligations
    assert dec.n_unique == 3
    assert dec.dedup_ratio == pytest.approx(14 / 3)


def test_dedup_is_layer_count_invariant():
    """Two configs differing ONLY in n_layers must produce the same
    unique-obligation key set (the satellite acceptance)."""
    cfg = load_config("gpt")
    small = dataclasses.replace(cfg, n_layers=2)
    big = dataclasses.replace(cfg, n_layers=9)
    k_small = set(decompose(small, "dp2xtp2").obset.unique)
    k_big = set(decompose(big, "dp2xtp2").obset.unique)
    assert k_small == k_big
    assert decompose(big, "dp2xtp2").total_blocks == 11


def test_pattern_roles_split_obligations():
    """gemma3's 5:1 local:global pattern yields two distinct layer
    obligations — the dedup key sees the mask structure, not the index."""
    dec = decompose("gemma3-12b", "dp2")
    kinds = {}
    for _, key in dec.obset.blocks:
        kinds.setdefault(key, 0)
        kinds[key] += 1
    layer_keys = [k for k in kinds if k.startswith("block-")]
    assert len(layer_keys) == 2          # local + global
    assert sorted(kinds[k] for k in layer_keys) == [
        load_config("gemma3-12b").n_layers // 6,
        5 * load_config("gemma3-12b").n_layers // 6]


def test_bug_splits_dedup_class():
    dec = decompose("gpt", "dp2xtp2", bug="wrong_spec", bug_layer=3)
    assert dec.n_unique == 4             # embed, clean layer, bug layer, head
    _, bug_key = dec.obset.blocks[4]     # block 4 == layer3
    assert dec.obset.block_indices(bug_key) == [4]


def test_unsupported_family_raises():
    with pytest.raises(ModelCheckError, match="unknown model"):
        decompose("nope", "dp2")
    with pytest.raises(ModelCheckError, match="bug_layer"):
        decompose("gpt", "dp2", bug="wrong_spec", bug_layer=99)


@pytest.mark.parametrize("model,family,why_fragment", [
    ("mamba2-1.3b", "ssm", "cumsum lemma"),
    ("recurrentgemma-2b", "hybrid", "RG-LRU"),
    ("whisper-medium", "audio", "encoder-decoder"),
])
def test_unsupported_family_error_is_actionable(model, family, why_fragment):
    """The unsupported-config error must name the config's actual family,
    the reason that family is blocked, and what IS checkable."""
    with pytest.raises(ModelCheckError) as ei:
        decompose(model, "dp2")
    msg = str(ei.value)
    assert f"family `{family}`" in msg
    assert why_fragment in msg
    assert "supported families: ['dense', 'moe', 'vlm']" in msg
    for mid in supported_models():
        assert mid in msg


def test_obligation_key_ignores_fn_identity():
    """Keys hash structure, not callables: rebuilding the same obligation
    yields the same key even though the closures differ."""
    cfg, plan = load_config("gpt"), parse_plan("dp2xtp2")
    a = layer_obligation(cfg, plan)
    b = layer_obligation(cfg, plan)
    assert a.seq_fn is not b.seq_fn and a.key == b.key
    assert layer_obligation(cfg, plan, role="local").key != a.key


# ---------------------------------------------------------------------------
# whole-model verification
# ---------------------------------------------------------------------------

def test_gpt_whole_model_certificate():
    """The acceptance run: a clean whole-model certificate with strictly
    fewer unique obligations than blocks, every seam matching the
    spec-promised relation."""
    report = check_model("gpt", "dp2xtp2", workers=0)
    assert report.verdict == "certificate" and report.ok
    assert report.unique_obligations < report.total_blocks
    assert report.dedup_ratio > 1.0
    assert all(b.seam_ok for b in report.blocks)
    assert report.gs_ops_total > 0
    # dedup bookkeeping: later layers are cache hits
    layer_blocks = [b for b in report.blocks if b.name.startswith("layer")]
    assert not layer_blocks[0].cached
    assert all(b.cached for b in layer_blocks[1:])


def test_cache_hit_certificate_byte_identical():
    """All deduped blocks resolve to one nested report: the certificate a
    cache hit returns is byte-identical to the verified one (the satellite
    acceptance)."""
    report = check_model("gpt", "dp2", workers=0)
    layers = [b for b in report.blocks if b.name.startswith("layer")]
    keys = {b.obligation for b in layers}
    assert len(keys) == 1                # one obligation backs every layer
    (key,) = keys
    blob = json.dumps(report.reports[key], sort_keys=True)
    for b in layers:                     # every block, hit or not, sees the
        assert json.dumps(                # same serialized certificate
            report.reports[b.obligation], sort_keys=True) == blob


def test_injected_bug_localizes_to_block():
    report = check_model("gpt", "dp2xtp2", bug="wrong_spec", bug_layer=2,
                         workers=0)
    assert report.verdict == "refinement_error" and report.ok
    assert report.failing_blocks == [3]  # embed is block 0
    bad = report.blocks[3]
    assert bad.name == "layer2" and not bad.cached
    loc = report.reports[bad.obligation]["localization"]
    assert loc["op_name"]                # a concrete operator is named


def test_moe_model_certificate():
    report = check_model("mixtral-8x7b", "tp2", workers=0)
    assert report.verdict == "certificate" and report.ok
    assert report.unique_obligations == 3


def test_seam_relation_shapes():
    """expected_output_relation builds the nested concat the plan promises."""
    from repro.core.terms import pretty
    t = expected_output_relation("y", (2, 4, 8), "f",
                                 parse_plan("dp2xtp2").spec_for(
                                     ("batch", "seq", "embed")),
                                 {"dp": 2, "tp": 2})
    assert pretty(t, 999) == "concat(y@dp0,tp0, y@dp1,tp0, dim=0)"
    t = expected_output_relation("y", (2, 4, 8), "f",
                                 parse_plan("dp2").spec_for(
                                     ("batch", "seq", "embed")),
                                 {"dp": 2})
    assert pretty(t, 999) == "concat(y@dp0, y@dp1, dim=0)"


def test_scheduler_pool_matches_inprocess():
    seq = check_model("gpt", "dp2", workers=0)
    par = check_model("gpt", "dp2", workers=2)
    assert seq.stable_summary() == par.stable_summary()
    for key in seq.reports:
        assert seq.reports[key]["r_o"] == par.reports[key]["r_o"]


def test_model_report_json_roundtrip():
    from repro.modelcheck import ModelReport
    report = check_model("gpt", "dp2", workers=0)
    d = report.to_json()
    assert d["schema_version"] >= 1
    assert "timing" in d and "phase_s_sum" in d["timing"]
    back = ModelReport.from_json(json.loads(json.dumps(d)))
    assert back.stable_summary() == report.stable_summary()


# ---------------------------------------------------------------------------
# registry entries + CLI
# ---------------------------------------------------------------------------

def test_model_task_registry():
    tasks = list_model_tasks()
    assert f"gpt@{DEFAULT_PLANS[0]}" in tasks
    assert all("@" in t for t in tasks)
    assert set(t.split("@", 1)[0] for t in tasks) == set(supported_models())
    with pytest.raises(KeyError):
        check_model_task("gpt")          # missing @plan


def test_check_model_task_runs():
    report = check_model_task("gpt@dp2", workers=0)
    assert report.verdict == "certificate"


def test_cli_model_json_envelope(capsys):
    from repro.launch.verify import main
    rc = main(["--model", "gpt", "--plan", "dp2", "--workers", "0",
               "--json"])
    assert not rc
    env = json.loads(capsys.readouterr().out)
    assert env["schema_version"] == 2 and env["kind"] == "model"
    assert env["report"]["verdict"] == "certificate"
    assert "phase_s_sum" in env["timing"]


def test_cli_case_json_envelope(capsys):
    from repro.launch.verify import main
    main(["--case", "tp_layer", "--json"])
    env = json.loads(capsys.readouterr().out)
    assert env["schema_version"] == 2 and env["kind"] == "case"
    assert env["report"]["verdict"] == "certificate"
    assert set(env["timing"]) == {"wall_s", "infer_s", "phase_s"}
    assert env["timing"]["phase_s"].get("saturate", 0) >= 0


# ---------------------------------------------------------------------------
# capture_chain (named-block sequence capture)
# ---------------------------------------------------------------------------

def test_capture_chain_threads_names_and_avals():
    import jax
    import jax.numpy as jnp

    def blk(x, w):
        return jnp.tanh(x @ w)

    aval = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    graphs, carry_avals, carry_names = capture_chain(
        [("b0", blk, [aval], ["w"]), ("b1", blk, [aval], ["w"])],
        [aval], ["x"])
    assert [n for n, _ in graphs] == ["b0", "b1"]
    g0, g1 = graphs[0][1], graphs[1][1]
    assert g0.inputs == ["x", "b0.w"]
    assert g1.inputs == ["b0.out0", "b1.w"]   # seam: names thread
    assert carry_names == ["b1.out0"]
    assert tuple(carry_avals[0].shape) == (4, 4)
    assert g0.n_ops == g1.n_ops == 2


def test_sequential_chain_op_count():
    dec = decompose("gpt", "dp2")
    graphs, _, names = dec.sequential_chain()
    assert len(graphs) == dec.total_blocks
    assert names == ["head.out0"]
    total = sum(g.n_ops for _, g in graphs)
    assert total > 14 * 10               # a real model, not a stub

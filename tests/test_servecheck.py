"""repro.servecheck: serving strategies certify that sharded KV-cache
decode refines full-sequence prefill, decode steps dedup by position
class (N steps -> O(1) obligations), injected serving bugs localize to
exactly their decode step, and the reports are deterministic across
worker counts and replayable from the persistent certificate cache."""
import json

import pytest

from repro.api import check_serve_task, list_serve_tasks
from repro.launch.verify import main as verify_main
from repro.runtime import CertificateCache, serve_cache_key
from repro.servecheck import (ServeReport, check_serve, get_serve_strategy,
                              list_serve_bugs, list_serve_strategies,
                              register_serve_strategy)

ALL_SERVE = list_serve_strategies()
ALL_SERVE_BUGS = sorted(list_serve_bugs())


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_serve_registry_covers_strategies_and_bugs():
    assert set(ALL_SERVE) == {"tp_decode", "sp_cache", "batched_decode"}
    assert set(ALL_SERVE_BUGS) == {"stale_cache_shard", "pos_off_by_one",
                                   "cache_gather_wrong_axis"}
    assert list_serve_tasks() == tuple(f"serve@{s}" for s in ALL_SERVE)
    # every strategy is swept at two degrees (tentpole acceptance)
    assert get_serve_strategy("tp_decode").degrees == (2, 4)
    assert get_serve_strategy("sp_cache").degrees == (2, 4)
    assert get_serve_strategy("batched_decode").degrees == ((2, 2), (2, 4))


def test_serve_registry_guards():
    with pytest.raises(KeyError, match="unknown serve strategy"):
        get_serve_strategy("no_such")
    # a bug run on a non-host strategy would silently certify the clean
    # path — both the build and check_serve entry points must refuse
    with pytest.raises(ValueError, match="belongs to serve strategy"):
        get_serve_strategy("tp_decode").build(bug="pos_off_by_one")
    with pytest.raises(ValueError, match="not hosted"):
        check_serve("tp_decode", bug="pos_off_by_one")
    with pytest.raises(ValueError, match="single-axis"):
        check_serve("tp_decode", degree=(2, 2))
    with pytest.raises(ValueError, match="dividing"):
        check_serve("tp_decode", degree=3)
    with pytest.raises(ValueError, match="dividing"):
        check_serve("sp_cache", degree=3)
    with pytest.raises(ValueError, match="dp must be 2"):
        check_serve("batched_decode", degree=(4, 2))
    # the wrong-axis gather only type-checks on a square mesh
    with pytest.raises(ValueError, match="square mesh"):
        check_serve("batched_decode", degree=(2, 4),
                    bug="cache_gather_wrong_axis")
    with pytest.raises(ValueError, match="already registered"):
        register_serve_strategy("tp_decode", n_steps=1)(
            lambda degree=2, bug=None: {})
    with pytest.raises(KeyError, match="bad serve task"):
        check_serve_task("tp_decode")          # missing the serve@ prefix


# ---------------------------------------------------------------------------
# position-class dedup: N decode steps -> O(1) obligations
# ---------------------------------------------------------------------------

def test_position_class_dedup_counts():
    # tp_decode: 8 steps collapse to first/mid/last + the read
    obs = get_serve_strategy("tp_decode").build(degree=2)
    assert (obs.total_blocks, obs.n_unique) == (9, 4)
    # sp_cache deg2: local offsets lfirst/lmid/llast + the read
    obs = get_serve_strategy("sp_cache").build(degree=2)
    assert (obs.total_blocks, obs.n_unique) == (9, 4)
    # sp_cache deg4: 2-row shards have no lmid class
    obs = get_serve_strategy("sp_cache").build(degree=4)
    assert (obs.total_blocks, obs.n_unique) == (9, 3)
    # batched_decode: rotated positions — every step its own class
    # (the documented contrast case: dedup ratio 1)
    obs = get_serve_strategy("batched_decode").build(degree=(2, 2))
    assert (obs.total_blocks, obs.n_unique) == (5, 5)


def test_bug_splits_its_position_class():
    """Injecting a bug changes the step's structure fingerprint, splitting
    it out of its class — that split is what localization rides on."""
    clean = get_serve_strategy("tp_decode").build(degree=2)
    bugged = get_serve_strategy("tp_decode").build(
        degree=2, bug="stale_cache_shard")
    assert bugged.n_unique == clean.n_unique + 1
    # and only the bugged step moved: step2 / step4 still share step3's
    # old class key in the clean set but not with bugged step3
    key = dict(bugged.blocks)
    assert key["step3"] != key["step2"] == key["step4"]


# ---------------------------------------------------------------------------
# clean certification + bug localization
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tp_report():
    return check_serve("tp_decode")


def test_tp_decode_certifies(tp_report):
    r = tp_report
    assert r.ok and r.verdict == "certificate", r.failing_steps
    assert not r.failing_steps
    assert (r.total_steps, r.unique_obligations) == (9, 4)
    assert r.dedup_ratio == 2.25
    for s in r.steps:
        assert s.verdict == "certificate" and s.relation_ok
    # class siblings replay their class representative's obligation
    assert sum(s.cached for s in r.steps) == 5


@pytest.mark.parametrize("strategy", ["sp_cache", "batched_decode"])
def test_other_strategies_certify(strategy):
    r = check_serve(strategy)
    assert r.ok and r.verdict == "certificate", (strategy, r.failing_steps)
    for s in r.steps:
        assert s.verdict == "certificate" and s.relation_ok


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ALL_SERVE)
def test_serve_strategy_certifies_at_all_degrees(strategy):
    # degrees[0] is covered by the fast tests above
    for degree in get_serve_strategy(strategy).degrees[1:]:
        r = check_serve(strategy, degree=degree)
        assert r.ok and r.verdict == "certificate", \
            (strategy, degree, r.failing_steps)


@pytest.mark.parametrize("bug", ALL_SERVE_BUGS)
def test_serve_bug_localizes_to_step(bug):
    host, bspec = list_serve_bugs()[bug]
    target = get_serve_strategy(host).bug_steps[bug]
    r = check_serve(host, bug=bug, workers=1)
    assert r.ok, (bug, r.verdict, r.failing_steps)
    assert r.verdict == bspec.expected
    # sharp localization: exactly the injected step fails; its
    # position-class siblings (same class, no bug) stay clean
    assert r.failing_steps == [f"step{target}"] and r.bug_step == target
    by_step = {s.step: s for s in r.steps}
    bad = by_step[f"step{target}"]
    if bspec.expected == "refinement_error":
        assert bad.verdict == "refinement_error" and bad.localized_op
    else:                         # the seam-check (silent misplacement) mode
        assert bad.verdict == "certificate" and not bad.relation_ok
    for s in r.steps:
        if s.step != bad.step:
            assert s.verdict == "certificate" and s.relation_ok


def test_wrong_axis_seam_detail():
    """cache_gather_wrong_axis still *refines* (each request's cache is
    reconstructible from the ranks that computed it) — the nested report
    must show a certificate whose seam comparison failed, which is the
    paper's silent-misplacement detection mode."""
    r = check_serve("batched_decode", bug="cache_gather_wrong_axis",
                    degree=(2, 2), workers=1)
    key = dict(r.steps and [(s.step, s.obligation) for s in r.steps])["step1"]
    rep = r.reports[key]
    assert rep["verdict"] == "certificate"
    seams = rep["seams"]
    assert any(not s["ok"] for s in seams)
    for s in seams:
        if not s["ok"]:
            assert s["expected"] != s["got"]


# ---------------------------------------------------------------------------
# report serialization + determinism + cache replay
# ---------------------------------------------------------------------------

def test_serve_report_json_roundtrip(tp_report):
    blob = json.dumps(tp_report.to_json(), sort_keys=True)
    back = ServeReport.from_json(json.loads(blob))
    assert back.stable_summary() == tp_report.stable_summary()
    assert back.task_id() == tp_report.task_id() == "serve@tp_decode@deg2"
    md = tp_report.to_markdown()
    assert "certificate" in md and "| read |" in md and "dedup 2.25x" in md


def test_serve_report_identical_across_worker_counts():
    a = check_serve("batched_decode", workers=1)
    b = check_serve("batched_decode", workers=2)
    assert a.workers != b.workers
    assert a.stable_summary() == b.stable_summary()
    # the certificates themselves, not just verdicts
    assert {k: v["r_o"] for k, v in a.reports.items()} == \
        {k: v["r_o"] for k, v in b.reports.items()}


def test_serve_cache_key_format():
    k = serve_cache_key("tp_decode", "serve_step-5-deadbeef0123", None)
    assert k == "serve:tp_decode-deadbeef0123:mn400000"
    assert serve_cache_key("tp_decode", "x-abc", {"max_nodes": 500}) \
        == "serve:tp_decode-abc:mn500"


def test_warm_cache_replays_serve_verdicts(tmp_path):
    d = tmp_path / "c"
    cold = check_serve("batched_decode", workers=1, cache=d)
    assert cold.cache["misses"] == cold.unique_obligations
    assert cold.cache["hits"] == 0
    warm = check_serve("batched_decode", workers=1, cache=d)
    assert warm.cache["hits"] == warm.unique_obligations
    assert warm.cache["misses"] == 0
    assert warm.stable_summary() == cold.stable_summary()
    assert {k: v["r_o"] for k, v in warm.reports.items()} == \
        {k: v["r_o"] for k, v in cold.reports.items()}
    # entries are addressed under the serve: namespace, one per obligation
    store = CertificateCache(d)
    assert len(store) == cold.unique_obligations
    for key in cold.reports:
        assert serve_cache_key("batched_decode", key, None) in store


# ---------------------------------------------------------------------------
# api + CLI surface
# ---------------------------------------------------------------------------

def test_check_serve_task_api():
    r = check_serve_task("serve@batched_decode")
    assert r.ok and r.verdict == "certificate"
    assert r.task_id() == "serve@batched_decode@deg2x2"


def _envelope(capsys, argv):
    try:
        verify_main(argv)
    except SystemExit as e:               # bug paths exit(1) by design
        assert e.code in (None, 0, 1)
    return json.loads(capsys.readouterr().out)


def test_json_envelope_serve_path(capsys):
    env = _envelope(capsys, ["--serve", "batched_decode", "--json"])
    assert env["schema_version"] == 2
    assert env["kind"] == "serve"
    assert set(env) == {"schema_version", "kind", "timing", "report"}
    assert env["report"]["ok"] and env["report"]["verdict"] == "certificate"
    blob = json.dumps(env, indent=2, sort_keys=True)
    assert json.dumps(json.loads(blob), indent=2, sort_keys=True) == blob


def test_cli_list_serve_rows(capsys):
    verify_main(["--list"])
    out = capsys.readouterr().out
    assert "[serve]" in out
    assert "serve@tp_decode" in out and "serve@batched_decode" in out
    assert "stale_cache_shard" in out and "cache_gather_wrong_axis" in out

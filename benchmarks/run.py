"""Benchmark harness — one section per paper table/figure.

  fig4      end-to-end verification time per model/strategy   (paper Fig. 4)
  fig5      scaling vs parallelism degree                     (paper Fig. 5)
  fam_scaling  FSDP / pipeline / 2D-mesh family scaling with
            degree (incl. per-axis tuple degrees)
  gradcheck training-step verification per train strategy
            (repro.gradcheck per-parameter gradient obligations)
  suite     repro.api.Suite process-pool runner vs sequential
            run_case looping on the clean degree-2 matrix
  runtime   persistent certificate cache: cold vs warm whole-model
            re-verification (repro.runtime.cache)
  ablation  sp_moe deg 8: optimized engine vs the same commit
            with dispatch/extraction optimizations disabled
  fig6      lemma-library effort: count + complexity          (paper Fig. 6)
  fig7      lemma application counts per case                 (paper Fig. 7)

Prints ``name,us_per_call,derived`` CSV rows (derived = e-graph nodes or
counts, per section) and writes machine-readable ``BENCH_verify.json``
(per-case wall/infer time, e-graph nodes, lemma fires, proof-provenance
chain steps, per-phase timers; warmup + median-of-N repeats) so the perf
trajectory is tracked across PRs.

    python benchmarks/run.py [--smoke] [--repeats N] [--json PATH]
"""
import argparse
import json
import os
import statistics
import sys
import time

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

REPEATS = 3


def _cases():
    from repro.api import verify
    return verify


def _sum_explain_steps(reports):
    """Total proof-provenance chain steps across a scheduler's unique
    obligations (from one untimed explain-on run).

    Chain reconstruction canonicalizes over the term quotient, so the
    count is byte-stable per section and scripts/check_bench.py gates it
    with exact equality — a changed count means the proofs themselves
    changed shape, not that the machine was slow."""
    from repro.core.explain import explanation_steps
    return sum(explanation_steps(rep.get("explanation"))
               for rep in reports.values())


def _sum_lemma_fires(reports):
    """Total lemma fires across a scheduler's unique obligations.

    Saturation is deterministic, so this is byte-stable per section and
    scripts/check_bench.py gates it with exact equality — a changed count
    means the engine did different work, not that the machine was slow."""
    total = 0
    for rep in reports.values():
        fires = (rep.get("stats") or {}).get("lemma_fires") or {}
        total += sum(fires.values())
    return total


def _timed_case(verify, case, degree=2, repeats=None):
    """Warmup once, then median-of-N: returns a JSON-ready record.

    wall_ms includes jax tracing + SPMD expansion (constant per case);
    infer_ms is the relation-inference time the engine work targets.
    Raises if the verdict misses the registry expectation, so a silently
    broken strategy fails the section instead of timing garbage.
    """
    repeats = repeats or REPEATS

    def checked(r):
        assert r.verdict == "certificate", \
            f"{case}@deg{degree}: verdict {r.verdict} " \
            f"(expected {r.expected}) — " \
            f"{r.error or (r.localization or {}).get('op_name')}"
        return r

    checked(verify(case, degree=degree))           # warmup
    walls, infers = [], []
    report = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        report = checked(verify(case, degree=degree))
        walls.append((time.perf_counter() - t0) * 1e3)
        infers.append(report.stats["time_s"] * 1e3)
    # one extra untimed explain-on run: provenance chain length is a
    # determinism signal (gated exactly), not a timing
    from repro.core.explain import explanation_steps
    xrep = checked(verify(case, degree=degree,
                          engine_opts={"explain": True}))
    stats = report.stats
    return {
        "wall_ms": round(statistics.median(walls), 3),
        "infer_ms": round(statistics.median(infers), 3),
        "egraph_nodes": stats["egraph_nodes"],
        "gs_ops": stats["gs_ops"],
        "gd_ops": stats["gd_ops"],
        "lemma_fires": sum(stats["lemma_fires"].values()),
        "explain_steps": explanation_steps(xrep.explanation),
        "phase_ms": {k: round(v * 1e3, 3)
                     for k, v in stats["phase_s"].items()},
        "counters": stats["counters"],
    }


def fig4_verification_time(rows, out, repeats=None):
    """Per-case end-to-end verification time (paper Fig. 4 analogue).
    The paper's models map onto these strategy cases: GPT/Megatron -> TP+SP,
    Qwen2/vLLM -> TP, Llama-3/Neuron -> TP, HF regression -> grad-accum;
    the weight-sharded / pipeline / 2D-mesh families (fsdp_mlp, pp_stage,
    tp_dp_2d) cover the bug-study strategies beyond the paper's case set."""
    verify = _cases()
    sec = out.setdefault("fig4", {})
    for case in ["tp_layer", "sp_pad", "ep_moe", "sp_moe", "ln_grad",
                 "sp_rope", "fsdp_mlp", "pp_stage", "tp_dp_2d"]:
        rec = _timed_case(verify, case, repeats=repeats)
        sec[case] = rec
        rows.append((f"fig4/{case}", rec["wall_ms"] * 1e3,
                     rec["egraph_nodes"]))


def fig5_scaling(rows, out, repeats=None):
    """Verification time vs parallelism degree (2, 4, 8)."""
    verify = _cases()
    sec = out.setdefault("fig5", {})
    for deg in (2, 4, 8):
        rec = _timed_case(verify, "sp_moe", degree=deg, repeats=repeats)
        sec[f"sp_moe_deg{deg}"] = rec
        rows.append((f"fig5/sp_moe_deg{deg}", rec["wall_ms"] * 1e3,
                     rec["egraph_nodes"]))
    for deg in (2, 4):
        try:
            rec = _timed_case(verify, "tp_layer", degree=deg,
                              repeats=repeats)
            nodes = rec["egraph_nodes"]
        except Exception as e:   # completeness gap at this degree — record it
            rec = {"error": type(e).__name__}
            nodes = -1
        sec[f"tp_layer_deg{deg}"] = rec
        rows.append((f"fig5/tp_layer_deg{deg}",
                     rec.get("wall_ms", 0.0) * 1e3, nodes))


def fam_scaling(rows, out, repeats=None):
    """Scaling of the weight-sharded / pipeline / 2D-mesh families with
    degree (per mesh axis for tp_dp_2d) — including the two former scale
    limits the n-ary add normal form closed: ``fsdp_mlp@8`` (was ~21 s of
    assoc/comm tax, now seconds) and the 16-rank ``tp_dp_2d@(4,4)`` (used
    to blow up saturation and false-alarm, now milliseconds)."""
    from repro.api import degree_token
    verify = _cases()
    sec = out.setdefault("fam_scaling", {})
    for case, degrees in [("fsdp_mlp", (2, 4, 8)), ("pp_stage", (2, 4)),
                          ("tp_dp_2d", ((2, 2), (4, 2), (4, 4)))]:
        for deg in degrees:
            rec = _timed_case(verify, case, degree=deg, repeats=repeats)
            key = f"{case}_deg{degree_token(deg)}"
            sec[key] = rec
            rows.append((f"fam_scaling/{key}", rec["wall_ms"] * 1e3,
                         rec["egraph_nodes"]))


def modelcheck_bench(rows, out, repeats=None):
    """Whole-model verification (repro.modelcheck): wall/infer time plus
    unique-obligations vs total-blocks (the dedup ratio is the scale
    story — e.g. kimi's 63 blocks cost 3 verifications).  The case list is
    identical in smoke and full runs so the bench gate
    (scripts/check_bench.py) can require every baseline case."""
    import statistics as _st

    from repro.modelcheck import check_model
    repeats = repeats or REPEATS
    sec = out.setdefault("modelcheck", {})
    cases = [("gpt", "dp2xtp2"), ("gpt", "dp2"),
             ("gemma3-12b", "dp2xtp2"), ("mixtral-8x7b", "tp2")]
    for model, plan in cases:
        def one():
            rep = check_model(model, plan, workers=0)
            assert rep.verdict == "certificate", \
                f"{model}@{plan}: {rep.verdict} (blocks {rep.failing_blocks})"
            return rep
        one()                                          # warmup
        walls, infers, rep = [], [], None
        for _ in range(repeats):
            t0 = time.perf_counter()
            rep = one()
            walls.append((time.perf_counter() - t0) * 1e3)
            infers.append(rep.timing()["infer_s_sum"] * 1e3)
        xrep = check_model(model, plan, workers=0,
                           engine_opts={"explain": True})
        key = f"{model}@{plan}"
        sec[key] = {
            "wall_ms": round(_st.median(walls), 3),
            "infer_ms": round(_st.median(infers), 3),
            "total_blocks": rep.total_blocks,
            "unique_obligations": rep.unique_obligations,
            "dedup_ratio": rep.dedup_ratio,
            "lemma_fires": _sum_lemma_fires(rep.reports),
            "explain_steps": _sum_explain_steps(xrep.reports),
        }
        rows.append((f"modelcheck/{key}", sec[key]["wall_ms"] * 1e3,
                     rep.unique_obligations))


def gradcheck_bench(rows, out, repeats=None):
    """Training-step verification (repro.gradcheck): wall/infer time per
    train strategy — the per-parameter gradient obligations with the
    transposition seam check.  The case list is identical in smoke and
    full runs so the bench gate (scripts/check_bench.py) can require
    every baseline case."""
    import statistics as _st

    from repro.gradcheck import check_train
    repeats = repeats or REPEATS
    sec = out.setdefault("gradcheck", {})
    cases = [("dp", 2), ("dp_accum", 2), ("fsdp", 2), ("tp_dp_2d", (4, 4))]
    for strategy, degree in cases:
        def one():
            rep = check_train(strategy, degree=degree, workers=0)
            assert rep.verdict == "certificate", \
                f"train@{strategy}: {rep.verdict} ({rep.failing_params})"
            return rep
        one()                                          # warmup
        walls, infers, rep = [], [], None
        for _ in range(repeats):
            t0 = time.perf_counter()
            rep = one()
            walls.append((time.perf_counter() - t0) * 1e3)
            infers.append(rep.timing()["infer_s_sum"] * 1e3)
        from repro.api import degree_token
        xrep = check_train(strategy, degree=degree, workers=0,
                           engine_opts={"explain": True})
        key = f"train@{strategy}@deg{degree_token(degree)}"
        sec[key] = {
            "wall_ms": round(_st.median(walls), 3),
            "infer_ms": round(_st.median(infers), 3),
            "params": len(rep.params),
            "lemma_fires": _sum_lemma_fires(rep.reports),
            "explain_steps": _sum_explain_steps(xrep.reports),
        }
        rows.append((f"gradcheck/{key}", sec[key]["wall_ms"] * 1e3,
                     len(rep.params)))


def servecheck_bench(rows, out, repeats=None):
    """Serving-path verification (repro.servecheck): wall/infer time per
    serve strategy — decode-step obligations deduped by position class
    plus the prefill-read chain.  sp_cache is excluded from the timed set
    (its read obligation is ~17 s at degree 2 — tier-1 tests cover it);
    the case list is identical in smoke and full runs so the bench gate
    (scripts/check_bench.py) can require every baseline case."""
    import statistics as _st

    from repro.servecheck import check_serve
    repeats = repeats or REPEATS
    sec = out.setdefault("servecheck", {})
    cases = [("tp_decode", 2), ("batched_decode", (2, 2))]
    for strategy, degree in cases:
        def one():
            rep = check_serve(strategy, degree=degree, workers=0)
            assert rep.verdict == "certificate", \
                f"serve@{strategy}: {rep.verdict} ({rep.failing_steps})"
            return rep
        one()                                          # warmup
        walls, infers, rep = [], [], None
        for _ in range(repeats):
            t0 = time.perf_counter()
            rep = one()
            walls.append((time.perf_counter() - t0) * 1e3)
            infers.append(rep.timing()["infer_s_sum"] * 1e3)
        from repro.api import degree_token
        xrep = check_serve(strategy, degree=degree, workers=0,
                           engine_opts={"explain": True})
        key = f"serve@{strategy}@deg{degree_token(degree)}"
        sec[key] = {
            "wall_ms": round(_st.median(walls), 3),
            "infer_ms": round(_st.median(infers), 3),
            "total_steps": rep.total_steps,
            "unique_obligations": rep.unique_obligations,
            "dedup_ratio": rep.dedup_ratio,
            "lemma_fires": _sum_lemma_fires(rep.reports),
            "explain_steps": _sum_explain_steps(xrep.reports),
        }
        rows.append((f"servecheck/{key}", sec[key]["wall_ms"] * 1e3,
                     rep.unique_obligations))


def suite_runner(rows, out, repeats=None):
    """Suite process-pool runner vs sequential run_case looping.

    Both modes sweep the clean degree-2 matrix (every registered case,
    bug=None).  Sequential = ``Suite.run(workers=0)``, i.e. exactly the
    in-process run_case loop the CLI used to do; parallel = 4 pool
    workers with the warmed, persistent pool (steady state — the first
    parallel sweep, which additionally pays pool spin-up + per-worker
    jax backend init, is reported as ``first_parallel_run_ms``).
    Median + min of N interleaved-ish repeats; the
    section asserts the two modes' stable summaries (verdicts + R_o
    certificates) are identical before reporting any numbers.
    """
    from repro.api import Suite

    # the container CPU is very noisy and each sweep is ~100 ms, so take
    # the min over a larger interleaved sample than the other sections
    repeats = max(repeats or REPEATS, 9)
    with Suite(degrees=(2,)) as suite:
        n_tasks = len(suite.tasks())
        res_seq = suite.run(workers=0)             # warmup sequential
        t0 = time.perf_counter()
        res_par = suite.run(workers=4)             # pool + backend init
        first_par_s = time.perf_counter() - t0
        assert res_seq.stable_summary() == res_par.stable_summary(), \
            "suite results differ between sequential and pool execution"
        seqs, pars = [], []
        for _ in range(repeats):
            seqs.append(suite.run(workers=0).wall_s)
            pars.append(suite.run(workers=4).wall_s)
    seq_ms = min(seqs) * 1e3
    par_ms = min(pars) * 1e3
    out["suite"] = {
        "tasks": n_tasks,
        "workers": 4,
        "sequential_ms": round(seq_ms, 3),
        "workers4_ms": round(par_ms, 3),
        "sequential_ms_median": round(statistics.median(seqs) * 1e3, 3),
        "workers4_ms_median": round(statistics.median(pars) * 1e3, 3),
        "first_parallel_run_ms": round(first_par_s * 1e3, 3),
        "speedup": round(seq_ms / par_ms, 2),
        "results_identical": True,
    }
    rows.append(("suite/clean_deg2/sequential", seq_ms * 1e3, n_tasks))
    rows.append(("suite/clean_deg2/workers4", par_ms * 1e3, n_tasks))
    rows.append(("suite/clean_deg2/speedup_x100", 0.0,
                 int(100 * seq_ms / par_ms)))


def runtime_bench(rows, out, repeats=None):
    """Persistent certificate cache (repro.runtime.cache): cold vs warm
    whole-model re-verification of gpt@dp2xtp2.  The warm number is the
    latency of re-verifying an unchanged model from the journal — the
    pre-launch hot path the cache exists for — and is gated by
    scripts/check_bench.py.  Each repeat uses a fresh cache directory so
    colds stay cold; asserts the warm run is all hits before timing
    counts."""
    import shutil
    import statistics as _st
    import tempfile

    from repro.modelcheck import check_model
    repeats = repeats or REPEATS
    sec = out.setdefault("runtime", {})
    colds, warms, hits = [], [], 0
    for _ in range(repeats):
        d = tempfile.mkdtemp(prefix="graphguard-bench-cache-")
        try:
            t0 = time.perf_counter()
            cold = check_model("gpt", "dp2xtp2", workers=0, cache=d)
            colds.append((time.perf_counter() - t0) * 1e3)
            assert cold.verdict == "certificate" \
                and cold.cache["hits"] == 0, \
                f"cold run not clean: {cold.verdict}, {cold.cache}"
            t0 = time.perf_counter()
            warm = check_model("gpt", "dp2xtp2", workers=0, cache=d)
            warms.append((time.perf_counter() - t0) * 1e3)
            assert warm.cache["misses"] == 0, \
                f"warm run missed the cache: {warm.cache}"
            assert cold.stable_summary() == warm.stable_summary(), \
                "warm certificates differ from cold"
            hits = warm.cache["hits"]
        finally:
            shutil.rmtree(d, ignore_errors=True)
    cold_ms, warm_ms = _st.median(colds), _st.median(warms)
    sec["gpt@dp2xtp2"] = {
        "cold_wall_ms": round(cold_ms, 3),
        "warm_wall_ms": round(warm_ms, 3),
        "obligations": hits,
        "speedup": round(cold_ms / max(warm_ms, 1e-9), 2),
        "results_identical": True,
    }
    rows.append(("runtime/gpt@dp2xtp2/cold", cold_ms * 1e3, hits))
    rows.append(("runtime/gpt@dp2xtp2/warm", warm_ms * 1e3, hits))


def ablation_engine(rows, out, repeats=None):
    """sp_moe at degree 8: optimized engine vs the un-optimized baseline
    (op-indexed dispatch, deferred rebuild, incremental extraction, indexed
    frontier, cached node sets — all toggled together) on the same commit."""
    from repro.core import capture, capture_spmd, check_refinement, expand_spmd
    from repro.core.profile import CONFIG, set_optimizations
    from repro.dist import strategies as S

    saved_flags = CONFIG.as_dict()

    repeats = max(repeats or REPEATS, 5)
    seq_fn, dist_fn, axes, specs, avals, names = S.sp_moe_layer(degree=8)
    gs = capture(seq_fn, avals, names)
    cap = capture_spmd(dist_fn, axes, specs, avals, names)
    gd, r_i = expand_spmd(cap)

    def one(flag):
        set_optimizations(flag)
        cert = check_refinement(gs, gd, r_i)
        return cert.stats["time_s"] * 1e3, cert

    # interleave optimized/baseline runs and take the per-mode minimum so a
    # noisy-neighbour CPU spike cannot land entirely on one mode
    try:
        one(True)
        one(False)                                 # warmup both modes
        opts, bases = [], []
        for _ in range(repeats):
            t, cert_on = one(True)
            opts.append(t)
            t, cert_off = one(False)
            bases.append(t)
    finally:
        # restore whatever mode the process was launched in (GRAPHGUARD_OPT)
        set_optimizations(True, **saved_flags)
    opt_ms, base_ms = min(opts), min(bases)
    assert cert_on.r_o == cert_off.r_o, \
        "optimizations changed the certificate — behaviour not preserved!"
    out["ablation"] = {
        "case": "sp_moe_deg8",
        "optimized_infer_ms": round(opt_ms, 3),
        "baseline_infer_ms": round(base_ms, 3),
        "optimized_infer_ms_median": round(statistics.median(opts), 3),
        "baseline_infer_ms_median": round(statistics.median(bases), 3),
        "speedup": round(base_ms / opt_ms, 2),
        "certificates_identical": True,
    }
    rows.append(("ablation/sp_moe_deg8/optimized", opt_ms * 1e3,
                 cert_on.stats["egraph_nodes"]))
    rows.append(("ablation/sp_moe_deg8/baseline", base_ms * 1e3,
                 cert_off.stats["egraph_nodes"]))
    rows.append(("ablation/sp_moe_deg8/speedup_x100",
                 0.0, int(100 * base_ms / opt_ms)))


def fig6_lemma_effort(rows, out):
    """Lemma library size + complexity (paper Fig. 6: effort to add)."""
    from repro.core.lemmas import all_lemmas
    lemmas = all_lemmas()
    import inspect
    sec = out.setdefault("fig6", {"loc": {}, "source": {}})
    total_loc = 0
    for lem in lemmas:
        loc = len(inspect.getsource(lem.fn).splitlines())
        total_loc += loc
        sec["loc"][lem.name] = loc
        rows.append((f"fig6/loc/{lem.name}", 0.0, loc))
    sec["n_lemmas"] = len(lemmas)
    sec["avg_loc"] = total_loc // max(len(lemmas), 1)
    rows.append(("fig6/n_lemmas", 0.0, len(lemmas)))
    rows.append(("fig6/avg_loc", 0.0, sec["avg_loc"]))
    by_src = {}
    for lem in lemmas:
        by_src[lem.source] = by_src.get(lem.source, 0) + 1
    for src, n in sorted(by_src.items()):
        sec["source"][src] = n
        rows.append((f"fig6/source/{src}", 0.0, n))


def fig7_lemma_heatmap(rows, out):
    """Lemma fire counts per verification case (paper Fig. 7 heatmap)."""
    verify = _cases()
    sec = out.setdefault("fig7", {})
    for case in ["tp_layer", "ep_moe", "sp_moe", "ln_grad"]:
        report = verify(case)
        sec[case] = dict(sorted(report.stats["lemma_fires"].items()))
        for lemma, n in sorted(report.stats["lemma_fires"].items()):
            rows.append((f"fig7/{case}/{lemma}", 0.0, n))


def kernels_bench(rows, out):
    """Pallas kernel wall time (interpret mode on CPU — correctness path)."""
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.rmsnorm import rmsnorm
    from repro.kernels import ref
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
    sec = out.setdefault("kernels", {})
    t0 = time.perf_counter()
    rmsnorm(x, s, interpret=True).block_until_ready()
    dt = (time.perf_counter() - t0) * 1e6
    sec["rmsnorm_interp_us"] = round(dt, 1)
    rows.append(("kernels/rmsnorm_interp", dt, x.size))
    t0 = time.perf_counter()
    ref.rmsnorm_ref(x, s).block_until_ready()
    dt = (time.perf_counter() - t0) * 1e6
    sec["rmsnorm_ref_us"] = round(dt, 1)
    rows.append(("kernels/rmsnorm_ref", dt, x.size))


def _pin_hash_seed() -> None:
    """Re-exec with ``PYTHONHASHSEED=0`` unless already pinned.

    Saturation explores in set-iteration order, so lemma fire counts are
    only run-to-run reproducible under a fixed hash seed — and the
    ``lemma_fires`` determinism gate in scripts/check_bench.py compares
    them with exact equality.  Timings are unaffected either way."""
    if os.environ.get("PYTHONHASHSEED") == "0":
        return
    env = dict(os.environ, PYTHONHASHSEED="0")
    os.execve(sys.executable, [sys.executable] + sys.argv, env)


def main(argv=None) -> None:
    _pin_hash_seed()
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="verification sections only, median-of-3 (stable "
                         "enough for the bench gate without the full run)")
    ap.add_argument("--repeats", type=int, default=REPEATS)
    ap.add_argument("--json", default=None,
                    help="output path (default: BENCH_verify.json, or "
                         "BENCH_verify_smoke.json under --smoke so smoke "
                         "runs never clobber the tracked full artifact)")
    args = ap.parse_args(argv)
    # a single repeat is too noisy to gate on (scripts/check_bench.py
    # compares these medians against BENCH_verify.json)
    repeats = min(3, args.repeats) if args.smoke else args.repeats
    if args.json is None:
        args.json = "BENCH_verify_smoke.json" if args.smoke \
            else "BENCH_verify.json"

    rows = []
    out = {"schema": 2, "repeats": repeats}
    sections = [
        lambda: fig4_verification_time(rows, out, repeats),
        lambda: fig5_scaling(rows, out, repeats),
        lambda: modelcheck_bench(rows, out, repeats),
        lambda: gradcheck_bench(rows, out, repeats),
        lambda: servecheck_bench(rows, out, repeats),
        lambda: runtime_bench(rows, out, repeats),
    ]
    names = ["fig4_verification_time", "fig5_scaling", "modelcheck_bench",
             "gradcheck_bench", "servecheck_bench", "runtime_bench"]
    if not args.smoke:
        sections += [
            lambda: fam_scaling(rows, out, repeats),
            lambda: suite_runner(rows, out, repeats),
            lambda: ablation_engine(rows, out, repeats),
            lambda: fig6_lemma_effort(rows, out),
            lambda: fig7_lemma_heatmap(rows, out),
            lambda: kernels_bench(rows, out),
        ]
        names += ["fam_scaling", "suite_runner", "ablation_engine",
                  "fig6_lemma_effort", "fig7_lemma_heatmap", "kernels_bench"]
    for name, section in zip(names, sections):
        try:
            section()
        except Exception as e:  # noqa: BLE001 — report per-section
            rows.append((f"{name}/ERROR({type(e).__name__})", 0.0, 0))
            out.setdefault("errors", {})[name] = f"{type(e).__name__}: {e}"
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"[bench] wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()

"""Benchmark harness — one section per paper table/figure.

  fig4   end-to-end verification time per model/strategy   (paper Fig. 4)
  fig5   scaling vs parallelism degree                     (paper Fig. 5)
  fig6   lemma-library effort: count + complexity          (paper Fig. 6)
  fig7   lemma application counts per case                 (paper Fig. 7)

Prints ``name,us_per_call,derived`` CSV rows (derived = e-graph nodes or
counts, per section).
"""
import sys
import time

sys.path.insert(0, "src")


def _cases():
    from repro.launch.verify import run_case
    return run_case


def fig4_verification_time(rows):
    """Per-case end-to-end verification time (paper Fig. 4 analogue).
    The paper's models map onto these strategy cases: GPT/Megatron -> TP+SP,
    Qwen2/vLLM -> TP, Llama-3/Neuron -> TP, HF regression -> grad-accum."""
    run_case = _cases()
    for case in ["tp_layer", "sp_pad", "ep_moe", "sp_moe", "ln_grad"]:
        t0 = time.perf_counter()
        cert = run_case(case, quiet=True)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig4/{case}", dt, cert.stats["egraph_nodes"]))


def fig5_scaling(rows):
    """Verification time vs parallelism degree (2, 4, 8)."""
    run_case = _cases()
    for deg in (2, 4, 8):
        t0 = time.perf_counter()
        cert = run_case("sp_moe", degree=deg, quiet=True)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig5/sp_moe_deg{deg}", dt, cert.stats["egraph_nodes"]))
    for deg in (2, 4):
        t0 = time.perf_counter()
        try:
            cert = run_case("tp_layer", degree=deg, quiet=True)
            nodes = cert.stats["egraph_nodes"]
        except Exception:   # completeness gap at this degree — record it
            nodes = -1
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig5/tp_layer_deg{deg}", dt, nodes))


def fig6_lemma_effort(rows):
    """Lemma library size + complexity (paper Fig. 6: effort to add)."""
    from repro.core.lemmas import all_lemmas
    lemmas = all_lemmas()
    import inspect
    total_loc = 0
    for lem in lemmas:
        loc = len(inspect.getsource(lem.fn).splitlines())
        total_loc += loc
        rows.append((f"fig6/loc/{lem.name}", 0.0, loc))
    rows.append(("fig6/n_lemmas", 0.0, len(lemmas)))
    rows.append(("fig6/avg_loc", 0.0, total_loc // max(len(lemmas), 1)))
    by_src = {}
    for lem in lemmas:
        by_src[lem.source] = by_src.get(lem.source, 0) + 1
    for src, n in sorted(by_src.items()):
        rows.append((f"fig6/source/{src}", 0.0, n))


def fig7_lemma_heatmap(rows):
    """Lemma fire counts per verification case (paper Fig. 7 heatmap)."""
    run_case = _cases()
    for case in ["tp_layer", "ep_moe", "sp_moe", "ln_grad"]:
        cert = run_case(case, quiet=True)
        for lemma, n in sorted(cert.stats["lemma_fires"].items()):
            rows.append((f"fig7/{case}/{lemma}", 0.0, n))


def kernels_bench(rows):
    """Pallas kernel wall time (interpret mode on CPU — correctness path)."""
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.rmsnorm import rmsnorm
    from repro.kernels import ref
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
    t0 = time.perf_counter()
    rmsnorm(x, s, interpret=True).block_until_ready()
    rows.append(("kernels/rmsnorm_interp", (time.perf_counter() - t0) * 1e6,
                 x.size))
    t0 = time.perf_counter()
    ref.rmsnorm_ref(x, s).block_until_ready()
    rows.append(("kernels/rmsnorm_ref", (time.perf_counter() - t0) * 1e6,
                 x.size))


def main() -> None:
    rows = []
    for section in (fig4_verification_time, fig5_scaling, fig6_lemma_effort,
                    fig7_lemma_heatmap, kernels_bench):
        try:
            section(rows)
        except Exception as e:  # noqa: BLE001 — report per-section
            rows.append((f"{section.__name__}/ERROR({type(e).__name__})",
                         0.0, 0))
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

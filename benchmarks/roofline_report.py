"""Build the EXPERIMENTS.md roofline table from experiments/dryrun/*.json."""
import glob
import json
import sys


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def main(outdir="experiments/dryrun"):
    rows = []
    skips = []
    for path in sorted(glob.glob(f"{outdir}/*_pod16x16.json")):
        rec = json.load(open(path))
        if "skipped" in rec:
            skips.append((rec["arch"], rec["shape"], rec["skipped"]))
            continue
        r = rec["roofline"]
        e = rec["extrapolated"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "compute": r["compute_s"], "memory": r["memory_s"],
            "coll": r["collective_s"], "dom": r["dominant"],
            "useful": r["useful_ratio"], "mem_gib": r["mem_per_device_gib"],
            "fits": r["fits_hbm"],
            "flops": e["flops"],
        })
    print("| arch | shape | compute | memory | collective | dominant "
          "| useful 6ND/HLO | mem/dev | fits 16G |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute'])} "
              f"| {fmt_s(r['memory'])} | {fmt_s(r['coll'])} | {r['dom']} "
              f"| {r['useful']:.2f} | {r['mem_gib']:.1f}GiB "
              f"| {'Y' if r['fits'] else 'N'} |")
    print()
    print("Skipped (per DESIGN.md):")
    for a, s, why in skips:
        print(f"- {a} x {s}: {why.splitlines()[0]}")
    # multi-pod lowering proof
    mp = sorted(glob.glob(f"{outdir}/*_pod2x16x16.json"))
    ok = sum(1 for p in mp if "skipped" not in json.load(open(p)))
    print(f"\nMulti-pod (2x16x16) lower+compile proofs: {ok} combos compiled "
          f"(+ {len(mp)-ok} documented skips).")


if __name__ == "__main__":
    main(*sys.argv[1:])
